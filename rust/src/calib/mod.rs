//! Fisher calibration: capture KV activations and their loss gradients on a
//! calibration set (paper §3.2.1 / Eq. 6).
//!
//! Mirrors the paper's setup: 16 sequences of eval-context length from the
//! training split of the calibration corpus, one backward pass each through
//! the AOT `calib_grads` artifact; the squared gradients form the diagonal
//! Fisher weights for centroid learning.

use std::path::Path;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::runtime::{Engine, Value};
use crate::tensor::{TensorF, TensorI};

/// Captured calibration tensors, all `[L, B_total, H, T, hd]`.
pub struct CalibData {
    pub k: TensorF,
    pub v: TensorF,
    pub gk: TensorF,
    pub gv: TensorF,
}

/// Concatenate KV-shaped tensors along the batch axis (axis 1).
fn concat_batch(parts: &[TensorF]) -> TensorF {
    assert!(!parts.is_empty());
    let s0 = &parts[0].shape;
    let (l, h, t, hd) = (s0[0], s0[2], s0[3], s0[4]);
    let b_total: usize = parts.iter().map(|p| p.shape[1]).sum();
    let mut out = TensorF::zeros(&[l, b_total, h, t, hd]);
    let inner = h * t * hd;
    let mut b_off = 0;
    for p in parts {
        let b = p.shape[1];
        for li in 0..l {
            let src = li * b * inner;
            let dst = (li * b_total + b_off) * inner;
            out.data[dst..dst + b * inner].copy_from_slice(&p.data[src..src + b * inner]);
        }
        b_off += b;
    }
    out
}

/// Run calibration: `n_seqs` sequences drawn deterministically from the
/// head of `ds`, through `<model>.calib_grads`.
pub fn calibrate(
    engine: &Engine,
    model: &str,
    params: &TensorF,
    ds: &Dataset,
    n_seqs: usize,
) -> Result<CalibData> {
    let art = format!("{model}.calib_grads");
    let spec = engine.manifest.artifact(&art)?.clone();
    let batch = spec.meta.num_or("batch", 4.0) as usize;
    let ctx = spec.meta.num_or("ctx", 128.0) as usize;
    let n_calls = n_seqs.div_ceil(batch);
    anyhow::ensure!(
        ds.len() >= n_calls * batch * ctx,
        "calibration corpus too small"
    );

    let (mut ks, mut vs, mut gks, mut gvs) = (vec![], vec![], vec![], vec![]);
    let mut off = 0;
    for _ in 0..n_calls {
        let mut data = Vec::with_capacity(batch * ctx);
        for _ in 0..batch {
            data.extend_from_slice(&ds.tokens[off..off + ctx]);
            off += ctx;
        }
        let tokens = TensorI::from_vec(&[batch, ctx], data)?;
        let out = engine.run(&art, &[Value::F(params.clone()), Value::I(tokens)])?;
        let mut it = out.into_iter();
        ks.push(it.next().context("k")?.into_f()?);
        vs.push(it.next().context("v")?.into_f()?);
        gks.push(it.next().context("gk")?.into_f()?);
        gvs.push(it.next().context("gv")?.into_f()?);
    }
    Ok(CalibData {
        k: concat_batch(&ks),
        v: concat_batch(&vs),
        gk: concat_batch(&gks),
        gv: concat_batch(&gvs),
    })
}

impl CalibData {
    /// Persist to four raw f32 files + a shape header.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let shape_line = self
            .k
            .shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(",");
        std::fs::write(dir.join("calib_shape.txt"), shape_line)?;
        self.k.write_f32_file(&dir.join("calib_k.bin"))?;
        self.v.write_f32_file(&dir.join("calib_v.bin"))?;
        self.gk.write_f32_file(&dir.join("calib_gk.bin"))?;
        self.gv.write_f32_file(&dir.join("calib_gv.bin"))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<CalibData> {
        let shape: Vec<usize> = std::fs::read_to_string(dir.join("calib_shape.txt"))
            .with_context(|| format!("calibration data in {} (run `cq-serve calibrate`)", dir.display()))?
            .trim()
            .split(',')
            .map(|s| s.parse().context("shape parse"))
            .collect::<Result<_>>()?;
        Ok(CalibData {
            k: TensorF::read_f32_file(&dir.join("calib_k.bin"), &shape)?,
            v: TensorF::read_f32_file(&dir.join("calib_v.bin"), &shape)?,
            gk: TensorF::read_f32_file(&dir.join("calib_gk.bin"), &shape)?,
            gv: TensorF::read_f32_file(&dir.join("calib_gv.bin"), &shape)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_batch_stacks_along_axis1() {
        let mut a = TensorF::zeros(&[2, 1, 1, 2, 2]);
        let mut b = TensorF::zeros(&[2, 2, 1, 2, 2]);
        a.data.iter_mut().for_each(|x| *x = 1.0);
        b.data.iter_mut().for_each(|x| *x = 2.0);
        let c = concat_batch(&[a, b]);
        assert_eq!(c.shape, vec![2, 3, 1, 2, 2]);
        assert_eq!(c.at(&[0, 0, 0, 0, 0]), 1.0);
        assert_eq!(c.at(&[0, 1, 0, 0, 0]), 2.0);
        assert_eq!(c.at(&[1, 0, 0, 1, 1]), 1.0);
        assert_eq!(c.at(&[1, 2, 0, 1, 1]), 2.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("cq_calib_test");
        let t = |seed: f32| {
            let mut x = TensorF::zeros(&[1, 2, 1, 2, 2]);
            x.data.iter_mut().enumerate().for_each(|(i, v)| *v = seed + i as f32);
            x
        };
        let cd = CalibData { k: t(0.0), v: t(100.0), gk: t(200.0), gv: t(300.0) };
        cd.save(&dir).unwrap();
        let re = CalibData::load(&dir).unwrap();
        assert_eq!(re.k, cd.k);
        assert_eq!(re.gv, cd.gv);
    }
}

//! Teacher-forced perplexity under a KV-cache codec.
//!
//! Protocol (per eval batch, through the single `eval_kv` artifact):
//!   1. clean pass (`use_q = 0`) → per-token nll + clean pre-RoPE K / V;
//!   2. codec quantize→dequantize of K and V on the host;
//!   3. quantized pass (`use_q = 1`) → nll under the quantized cache.
//!
//! `PplMode::Fast` substitutes all layers at once (2 executions/batch).
//! `PplMode::Exact` quantizes progressively layer by layer so that layer
//! `l`'s activations are computed *under the already-quantized prefix* —
//! exactly the autoregressive-inference semantics — at L+2 executions/batch
//! (see DESIGN.md §3.1).

use anyhow::{Context, Result};

use crate::quant::{Codec, KvKind};
use crate::runtime::engine::{Arg, DevBuf};
use crate::runtime::{Engine, Value};
use crate::tensor::{TensorF, TensorI};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PplMode {
    Fast,
    Exact,
}

#[derive(Clone, Debug)]
pub struct PplResult {
    pub nll_sum: f64,
    pub tokens: usize,
    /// Mean Frobenius² quantization error of keys / values per batch
    /// (the paper's Fig. 4 right-hand metric).
    pub k_err: f64,
    pub v_err: f64,
}

impl PplResult {
    pub fn ppl(&self) -> f64 {
        (self.nll_sum / self.tokens as f64).exp()
    }
}

struct EvalArt {
    name: String,
    kv_shape: Vec<usize>,
    n_layers: usize,
}

fn eval_art(engine: &Engine, model: &str) -> Result<EvalArt> {
    let name = format!("{model}.eval_kv");
    let spec = engine.manifest.artifact(&name)?;
    let kv_shape = spec.inputs[2].shape.clone();
    Ok(EvalArt { name, n_layers: kv_shape[0], kv_shape })
}

fn run_eval(
    engine: &Engine,
    art: &EvalArt,
    params: &DevBuf,
    tokens: &TensorI,
    khat: &TensorF,
    vhat: &TensorF,
    use_q: &[f32],
) -> Result<(TensorF, TensorF, TensorF)> {
    let toks = Value::I(tokens.clone());
    let kh = Value::F(khat.clone());
    let vh = Value::F(vhat.clone());
    let uq = Value::F(TensorF::from_vec(&[use_q.len()], use_q.to_vec())?);
    let out = engine.executable(&art.name)?.run_mixed(&[
        Arg::B(params),
        Arg::V(&toks),
        Arg::V(&kh),
        Arg::V(&vh),
        Arg::V(&uq),
    ])?;
    let mut it = out.into_iter();
    let nll = it.next().context("nll")?.into_f()?;
    let k = it.next().context("k")?.into_f()?;
    let v = it.next().context("v")?.into_f()?;
    Ok((nll, k, v))
}

/// Evaluate perplexity of `model` under `codec` over `batches`
/// (each `[batch, eval_ctx]`, from `data::eval_batches`).
pub fn perplexity(
    engine: &Engine,
    model: &str,
    params: &TensorF,
    codec: &dyn Codec,
    batches: &[TensorI],
    mode: PplMode,
) -> Result<PplResult> {
    let art = eval_art(engine, model)?;
    let params = engine.upload(&Value::F(params.clone()))?;
    let params = &params;
    let zeros = TensorF::zeros(&art.kv_shape);
    let mut res = PplResult { nll_sum: 0.0, tokens: 0, k_err: 0.0, v_err: 0.0 };

    for tokens in batches {
        // 1. clean pass: nll (unused) + clean K/V.
        let use0 = vec![0.0f32; art.n_layers];
        let (_, k_clean, v_clean) =
            run_eval(engine, &art, params, tokens, &zeros, &zeros, &use0)?;

        let nll = match mode {
            PplMode::Fast => {
                let mut kq = k_clean.clone();
                let mut vq = v_clean.clone();
                codec.apply(KvKind::Key, &mut kq);
                codec.apply(KvKind::Value, &mut vq);
                res.k_err += k_clean.sqdiff(&kq);
                res.v_err += v_clean.sqdiff(&vq);
                let use1 = vec![1.0f32; art.n_layers];
                run_eval(engine, &art, params, tokens, &kq, &vq, &use1)?.0
            }
            PplMode::Exact => {
                // Progressive: layer l's K/V are recomputed under the
                // quantized prefix before being quantized themselves.
                let mut khat = TensorF::zeros(&art.kv_shape);
                let mut vhat = TensorF::zeros(&art.kv_shape);
                let mut use_q = vec![0.0f32; art.n_layers];
                let mut k_cur = k_clean;
                let mut v_cur = v_clean;
                for l in 0..art.n_layers {
                    // Quantize layer l from the current (prefix-consistent) pass.
                    let mut kq = slice_layer(&k_cur, l);
                    let mut vq = slice_layer(&v_cur, l);
                    codec.apply(KvKind::Key, &mut kq);
                    codec.apply(KvKind::Value, &mut vq);
                    res.k_err += slice_layer(&k_cur, l).sqdiff(&kq);
                    res.v_err += slice_layer(&v_cur, l).sqdiff(&vq);
                    paste_layer(&mut khat, &kq, l);
                    paste_layer(&mut vhat, &vq, l);
                    use_q[l] = 1.0;
                    if l + 1 < art.n_layers {
                        let (_, k2, v2) =
                            run_eval(engine, &art, params, tokens, &khat, &vhat, &use_q)?;
                        k_cur = k2;
                        v_cur = v2;
                    }
                }
                run_eval(engine, &art, params, tokens, &khat, &vhat, &use_q)?.0
            }
        };
        res.nll_sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        res.tokens += nll.numel();
    }
    let nb = batches.len().max(1) as f64;
    res.k_err /= nb;
    res.v_err /= nb;
    Ok(res)
}

/// Per-layer quantization sensitivity for the policy allocator
/// (`quant/policy`): the mean per-token nll increase when *only* layer
/// `l`'s cache is quantized by `codec` and every other layer stays clean.
///
/// One clean pass per batch plus one single-layer quantized pass per
/// (layer, batch) — L+1 executions per batch, each reusing the clean
/// pass's K/V so the probe isolates layer `l` exactly.  Negative deltas
/// (sampling noise on insensitive layers) clamp to 0 so
/// [`crate::quant::policy::greedy_allocate`] never rewards quantization.
pub fn layer_sensitivity(
    engine: &Engine,
    model: &str,
    params: &TensorF,
    codec: &dyn Codec,
    batches: &[TensorI],
) -> Result<Vec<f64>> {
    let art = eval_art(engine, model)?;
    let params = engine.upload(&Value::F(params.clone()))?;
    let params = &params;
    let zeros = TensorF::zeros(&art.kv_shape);
    let mut deltas = vec![0.0f64; art.n_layers];
    let mut tokens = 0usize;
    for toks in batches {
        let use0 = vec![0.0f32; art.n_layers];
        let (nll_clean, k_clean, v_clean) =
            run_eval(engine, &art, params, toks, &zeros, &zeros, &use0)?;
        let clean: f64 = nll_clean.data.iter().map(|&x| x as f64).sum();
        tokens += nll_clean.numel();
        for (l, delta) in deltas.iter_mut().enumerate() {
            let mut kl = slice_layer(&k_clean, l);
            let mut vl = slice_layer(&v_clean, l);
            codec.apply(KvKind::Key, &mut kl);
            codec.apply(KvKind::Value, &mut vl);
            let mut khat = TensorF::zeros(&art.kv_shape);
            let mut vhat = TensorF::zeros(&art.kv_shape);
            paste_layer(&mut khat, &kl, l);
            paste_layer(&mut vhat, &vl, l);
            let mut use_q = vec![0.0f32; art.n_layers];
            use_q[l] = 1.0;
            let (nll, _, _) = run_eval(engine, &art, params, toks, &khat, &vhat, &use_q)?;
            *delta += nll.data.iter().map(|&x| x as f64).sum::<f64>() - clean;
        }
    }
    let per_token = tokens.max(1) as f64;
    Ok(deltas.iter().map(|d| (d / per_token).max(0.0)).collect())
}

/// Extract layer `l` of `[L,B,H,T,hd]` as a `[1,B,H,T,hd]` tensor.
fn slice_layer(src: &TensorF, l: usize) -> TensorF {
    let per = src.numel() / src.shape[0];
    let mut shape = src.shape.clone();
    shape[0] = 1;
    TensorF::from_vec(&shape, src.data[l * per..(l + 1) * per].to_vec()).unwrap()
}

/// Write a `[1,B,H,T,hd]` layer slice into layer `l` of `dst`.
fn paste_layer(dst: &mut TensorF, src: &TensorF, l: usize) {
    let per = dst.numel() / dst.shape[0];
    assert_eq!(src.numel(), per);
    dst.data[l * per..(l + 1) * per].copy_from_slice(&src.data);
}

/// FP baseline convenience: perplexity with the identity codec.
pub fn perplexity_fp(
    engine: &Engine,
    model: &str,
    params: &TensorF,
    batches: &[TensorI],
) -> Result<PplResult> {
    perplexity(engine, model, params, &crate::quant::Fp16, batches, PplMode::Fast)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_slice_paste_roundtrip() {
        let mut src = TensorF::zeros(&[3, 1, 1, 2, 2]);
        for (i, x) in src.data.iter_mut().enumerate() {
            *x = i as f32;
        }
        let l1 = slice_layer(&src, 1);
        assert_eq!(l1.shape, vec![1, 1, 1, 2, 2]);
        assert_eq!(l1.data, (4..8).map(|x| x as f32).collect::<Vec<_>>());
        let mut dst = TensorF::zeros(&[3, 1, 1, 2, 2]);
        paste_layer(&mut dst, &l1, 2);
        assert_eq!(dst.data[8..12], l1.data[..]);
    }

    #[test]
    fn ppl_result_math() {
        let r = PplResult { nll_sum: 100.0, tokens: 50, k_err: 0.0, v_err: 0.0 };
        assert!((r.ppl() - (2.0f64).exp()).abs() < 1e-12);
    }
}

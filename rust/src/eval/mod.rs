//! Evaluation harnesses: teacher-forced perplexity and zero-shot accuracy
//! under arbitrary KV-cache codecs (paper Tables 1–4).

pub mod ppl;
pub mod tasks;

pub use ppl::{layer_sensitivity, perplexity, PplMode, PplResult};
pub use tasks::{task_accuracy, TaskKind, TaskSet};

//! Zero-shot multiple-choice suites (the paper's Table 3 analogue).
//!
//! Three synthetic tasks probe regularities the corpus grammar embeds
//! (DESIGN.md §2):
//!   * `agree`    — subject–verb agreement (WinoGrande-style coreference/
//!                  agreement resolution);
//!   * `affinity` — adjective–noun collocation plausibility (PIQA-style
//!                  "which continuation is physically/semantically licensed");
//!   * `arith`    — spelled-out addition (ARC-style factual QA).
//!
//! Scoring follows the standard zero-shot recipe: each option is appended to
//! the prompt and scored by total nll of the option tokens under the model —
//! with the KV cache quantized by the codec under test — and the lowest-nll
//! option wins.  Items are packed into `eval_kv` batches for throughput.

use anyhow::Result;

use crate::data::corpus::{spell_number, COLLOCATIONS, DIGITS, PLACES, PLUR_NOUNS, SING_NOUNS};
use crate::quant::{Codec, KvKind};
use crate::runtime::engine::Arg;
use crate::runtime::{Engine, Value};
use crate::tensor::{TensorF, TensorI};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Agree,
    Affinity,
    Arith,
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Agree => "agree",
            TaskKind::Affinity => "affinity",
            TaskKind::Arith => "arith",
        }
    }
    pub fn all() -> [TaskKind; 3] {
        [TaskKind::Agree, TaskKind::Affinity, TaskKind::Arith]
    }
    pub fn parse(s: &str) -> Option<TaskKind> {
        TaskKind::all().into_iter().find(|t| t.name() == s)
    }
}

/// One multiple-choice item: common prompt + options; `correct` indexes the
/// licensed option.
#[derive(Clone, Debug)]
pub struct Item {
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

/// A generated task set.
pub struct TaskSet {
    pub kind: TaskKind,
    pub items: Vec<Item>,
}

impl TaskSet {
    /// Deterministically generate `n` items (seeded independently of the
    /// corpus streams so no item text appears verbatim in training data at
    /// the same positions).
    pub fn generate(kind: TaskKind, n: usize, seed: u64) -> TaskSet {
        let mut rng = Pcg64::new(seed, 0xbead + kind.name().len() as u64);
        let items = (0..n)
            .map(|_| match kind {
                TaskKind::Agree => agree_item(&mut rng),
                TaskKind::Affinity => affinity_item(&mut rng),
                TaskKind::Arith => arith_item(&mut rng),
            })
            .collect();
        TaskSet { kind, items }
    }
}

fn agree_item(rng: &mut Pcg64) -> Item {
    let singular = rng.next_f64() < 0.5;
    let noun: &str = if singular {
        *rng.choose(SING_NOUNS)
    } else {
        *rng.choose(PLUR_NOUNS)
    };
    let place = rng.choose(PLACES);
    let prompt = format!("The {} of {} ", noun, place);
    let (good, bad) = if singular { ("is", "are") } else { ("are", "is") };
    Item {
        prompt,
        options: vec![format!("{good} notable"), format!("{bad} notable")],
        correct: 0,
    }
}

fn affinity_item(rng: &mut Pcg64) -> Item {
    let (adj, licensed) = rng.choose(COLLOCATIONS);
    let good: &str = *rng.choose(licensed);
    // A noun NOT licensed by this adjective.
    let bad = loop {
        let cand = *rng.choose(SING_NOUNS);
        if !licensed.contains(&cand) {
            break cand;
        }
    };
    Item {
        prompt: format!("Travellers often mention the {} ", adj),
        options: vec![good.to_string(), bad.to_string()],
        correct: 0,
    }
}

fn arith_item(rng: &mut Pcg64) -> Item {
    let a = rng.below(10);
    let b = rng.below(10);
    let good = spell_number(a + b);
    let bad = loop {
        let w = spell_number(rng.below(19));
        if w != good {
            break w;
        }
    };
    Item {
        prompt: format!("In the ledger, {} plus {} equals ", DIGITS[a], DIGITS[b]),
        options: vec![format!("{good}."), format!("{bad}.")],
        correct: 0,
    }
}

/// Accuracy of `model` + `codec` on a task set.
///
/// Every (item, option) pair becomes one row of an `eval_kv` batch, right-
/// padded with newline bytes; the option nll is summed over the option's
/// token positions only.  Quantization uses the same clean-extract → codec →
/// substituted-eval protocol as `ppl` (fast mode).
pub fn task_accuracy(
    engine: &Engine,
    model: &str,
    params: &TensorF,
    codec: &dyn Codec,
    set: &TaskSet,
) -> Result<f64> {
    let art = format!("{model}.eval_kv");
    let spec = engine.manifest.artifact(&art)?.clone();
    let batch = spec.inputs[1].shape[0];
    let ctx = spec.inputs[1].shape[1];
    let kv_shape = spec.inputs[2].shape.clone();
    let n_layers = kv_shape[0];
    let zeros = Value::F(TensorF::zeros(&kv_shape));
    let params_buf = engine.upload(&Value::F(params.clone()))?;
    let exe = engine.executable(&art)?;

    // Flatten (item, option) pairs into rows.
    struct Row {
        item: usize,
        option: usize,
        tokens: Vec<i32>,
        score_from: usize,
        score_to: usize,
    }
    let mut rows = Vec::new();
    for (ii, item) in set.items.iter().enumerate() {
        for (oi, opt) in item.options.iter().enumerate() {
            let prompt_t: Vec<i32> = item.prompt.bytes().map(|b| b as i32).collect();
            let opt_t: Vec<i32> = opt.bytes().map(|b| b as i32).collect();
            let mut tokens = prompt_t.clone();
            tokens.extend_from_slice(&opt_t);
            assert!(tokens.len() <= ctx, "item exceeds eval ctx");
            // nll[j] scores tokens[j+1]; option tokens span
            // [prompt_len, prompt_len+opt_len) -> nll rows prompt_len-1 ..
            let score_from = prompt_t.len() - 1;
            let score_to = tokens.len() - 1;
            tokens.resize(ctx, b'\n' as i32);
            rows.push(Row { item: ii, option: oi, tokens, score_from, score_to });
        }
    }

    // Score batches.
    let mut scores: Vec<Vec<f64>> = set
        .items
        .iter()
        .map(|it| vec![0.0; it.options.len()])
        .collect();
    for chunk in rows.chunks(batch) {
        let mut data = Vec::with_capacity(batch * ctx);
        for r in chunk {
            data.extend_from_slice(&r.tokens);
        }
        // Pad the final partial batch by repeating the last row.
        while data.len() < batch * ctx {
            data.extend_from_slice(&chunk.last().unwrap().tokens);
        }
        let tokens = Value::I(TensorI::from_vec(&[batch, ctx], data)?);

        // Clean extract.
        let use0 = Value::F(TensorF::from_vec(&[n_layers], vec![0.0; n_layers])?);
        let out = exe.run_mixed(&[
            Arg::B(&params_buf),
            Arg::V(&tokens),
            Arg::V(&zeros),
            Arg::V(&zeros),
            Arg::V(&use0),
        ])?;
        let mut k = out[1].as_f()?.clone();
        let mut v = out[2].as_f()?.clone();
        codec.apply(KvKind::Key, &mut k);
        codec.apply(KvKind::Value, &mut v);
        let use1 = Value::F(TensorF::from_vec(&[n_layers], vec![1.0; n_layers])?);
        let k = Value::F(k);
        let v = Value::F(v);
        let out = exe.run_mixed(&[
            Arg::B(&params_buf),
            Arg::V(&tokens),
            Arg::V(&k),
            Arg::V(&v),
            Arg::V(&use1),
        ])?;
        let nll = out[0].as_f()?;
        let per_row = nll.shape[1];
        for (bi, r) in chunk.iter().enumerate() {
            let s: f64 = (r.score_from..r.score_to.min(per_row))
                .map(|j| nll.data[bi * per_row + j] as f64)
                .sum();
            scores[r.item][r.option] = s;
        }
    }

    let correct = set
        .items
        .iter()
        .enumerate()
        .filter(|(ii, item)| {
            let s = &scores[*ii];
            let best = s
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            best == item.correct
        })
        .count();
    Ok(correct as f64 / set.items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = TaskSet::generate(TaskKind::Agree, 10, 1);
        let b = TaskSet::generate(TaskKind::Agree, 10, 1);
        assert_eq!(a.items.len(), 10);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.options, y.options);
        }
    }

    #[test]
    fn agree_items_are_well_formed() {
        for item in TaskSet::generate(TaskKind::Agree, 50, 2).items {
            assert_eq!(item.options.len(), 2);
            assert_eq!(item.correct, 0);
            assert_ne!(item.options[0], item.options[1]);
            let plural = PLUR_NOUNS.iter().any(|n| item.prompt.contains(n));
            if plural {
                assert!(item.options[0].starts_with("are"));
            } else {
                assert!(item.options[0].starts_with("is"));
            }
        }
    }

    #[test]
    fn affinity_distractor_is_unlicensed() {
        for item in TaskSet::generate(TaskKind::Affinity, 50, 3).items {
            let adj = item
                .prompt
                .split_whitespace()
                .last()
                .unwrap()
                .to_string();
            let lic = COLLOCATIONS.iter().find(|(a, _)| *a == adj).unwrap().1;
            assert!(lic.contains(&item.options[0].as_str()));
            assert!(!lic.contains(&item.options[1].as_str()));
        }
    }

    #[test]
    fn arith_items_have_correct_answers() {
        for item in TaskSet::generate(TaskKind::Arith, 50, 4).items {
            // Parse "In the ledger, X plus Y equals ".
            let words: Vec<&str> = item.prompt.split_whitespace().collect();
            let xi = DIGITS.iter().position(|d| *d == words[3]).unwrap();
            let yi = DIGITS.iter().position(|d| *d == words[5]).unwrap();
            assert_eq!(item.options[0], format!("{}.", spell_number(xi + yi)));
            assert_ne!(item.options[0], item.options[1]);
        }
    }
}

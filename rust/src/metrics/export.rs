//! Wire-scrapable metrics snapshots (observability layer 2).
//!
//! [`MetricsSnapshot`] freezes every pool and per-worker counter, gauge,
//! level and raw histogram bucket into plain data, serializable both ways
//! through `util::json` (`{"op":"metrics"}` returns it; tooling can parse
//! it back with [`MetricsSnapshot::from_json`]).  Two snapshots taken over
//! a window derive [`Rates`] (tok/s, chunks/s, requests/s) without the
//! pool having to track windows itself, and [`prometheus_text`] renders a
//! snapshot in Prometheus exposition style for scrape-file pipelines.
//!
//! Snapshots are *names-to-numbers*, not struct mirrors: adding a metric
//! means adding one line to the collectors here, and parsers never break
//! on unknown names.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::{Histogram, PoolMetrics, ServeMetrics};

/// Frozen histogram state: total count, total time, and the non-empty
/// buckets as `(index, count)` against the fixed [`super::NUM_BUCKETS`]
/// log-linear layout.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    pub buckets: Vec<(usize, u64)>,
}

impl HistogramSnapshot {
    pub fn of(h: &Histogram) -> HistogramSnapshot {
        HistogramSnapshot {
            count: h.count(),
            sum_ns: h.sum_ns(),
            buckets: h.nonzero_buckets(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64 / 1e6
    }

    /// Same midpoint estimate as [`Histogram::percentile_ms`], computed
    /// from the frozen buckets.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (self.count as f64 * p).ceil() as u64;
        let mut acc = 0;
        for &(i, n) in &self.buckets {
            acc += n;
            if acc >= target {
                return Histogram::bucket_midpoint_us(i) / 1e3;
            }
        }
        f64::INFINITY
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_ns", Json::Num(self.sum_ns as f64)),
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&(i, n)| {
                            Json::Arr(vec![Json::Num(i as f64), Json::Num(n as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<HistogramSnapshot> {
        let buckets = j
            .req("buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets must be an array"))?
            .iter()
            .map(|pair| {
                let pair = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                    anyhow!("histogram bucket must be an [index, count] pair")
                })?;
                Ok((
                    pair[0].as_usize().ok_or_else(|| anyhow!("bad bucket index"))?,
                    pair[1].as_f64().ok_or_else(|| anyhow!("bad bucket count"))? as u64,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(HistogramSnapshot {
            count: j.num_or("count", 0.0) as u64,
            sum_ns: j.num_or("sum_ns", 0.0) as u64,
            buckets,
        })
    }
}

/// One worker's frozen metrics: named scalars (counters, gauges, levels,
/// derived values) plus named latency histograms.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnapshot {
    pub worker: usize,
    pub scalars: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl WorkerSnapshot {
    pub fn of(worker: usize, m: &ServeMetrics) -> WorkerSnapshot {
        let mut scalars = BTreeMap::new();
        let mut put = |k: &str, v: u64| {
            scalars.insert(k.to_string(), v);
        };
        put("prefill_chunks", m.prefill_chunks.get());
        put("prefill_preemptions", m.prefill_preemptions.get());
        put("prefill_backlog_tokens", m.prefill_backlog_tokens.get());
        put("tokens_out", m.tokens_out.get());
        put("requests_done", m.requests_done.get());
        put("requests_rejected", m.requests_rejected.get());
        put("requests_cancelled", m.requests_cancelled.get());
        put("sessions_evicted", m.sessions_evicted.get());
        put("live_sessions", m.session_tokens.live_sessions() as u64);
        put("cache_reserved_bytes", m.cache_reserved_bytes.get());
        put("cache_released_bytes", m.cache_released_bytes.get());
        put("cache_in_use_bytes", m.cache_bytes_in_use());
        put("cache_peak_bytes", m.cache_peak_bytes.get());
        put("cache_cached_bytes", m.cache_cached_bytes());
        put("cache_frag_bytes", m.cache_frag_bytes.get());
        put("prefix_lookup_tokens", m.prefix_lookup_tokens.get());
        put("prefix_hit_tokens", m.prefix_hit_tokens.get());
        put("prefill_tokens_skipped", m.prefill_tokens_skipped.get());
        put("encode_pool_busy", m.encode_pool_busy.get());
        put("encode_pool_threads", m.encode_pool_threads.get());
        put("blocks_promoted", m.blocks_promoted.get());
        put("blocks_evicted", m.blocks_evicted.get());
        put("bytes_per_token", m.bytes_per_token.get());
        put("fp16_bytes_per_token", m.fp16_bytes_per_token.get());
        put("window_tokens", m.window_tokens.get());
        put("window_retired_tokens", m.window_retired_tokens.get());
        put("block_bytes", m.block_bytes.get());
        put("max_prompt_tokens", m.max_prompt_tokens.get());
        put("loop_iterations", m.phases.iterations.get());
        put("phase_idle_ns", m.phases.idle_ns.get());
        put("phase_prefill_ns", m.phases.prefill_ns.get());
        put("phase_encode_ns", m.phases.encode_ns.get());
        put("phase_decode_ns", m.phases.decode_ns.get());
        put("phase_store_ns", m.phases.store_ns.get());
        put("phase_last_idle_ns", m.phases.last_idle_ns.get());
        put("phase_last_prefill_ns", m.phases.last_prefill_ns.get());
        put("phase_last_encode_ns", m.phases.last_encode_ns.get());
        put("phase_last_decode_ns", m.phases.last_decode_ns.get());
        put("phase_last_store_ns", m.phases.last_store_ns.get());
        put("trace_live", m.trace.live_count() as u64);
        put("trace_finished", m.trace.finished_count() as u64);
        put("trace_crashed", m.trace.crashed_count() as u64);
        put("trace_dropped", m.trace.dropped.get());
        // Per-policy resident bytes export as dynamic `policy_bytes_<name>`
        // scalars — names-to-numbers, so parsers need no schema change.
        for (name, bytes) in m.policy_bytes.snapshot() {
            put(&format!("policy_bytes_{name}"), bytes);
        }

        let mut histograms = BTreeMap::new();
        for (name, h) in [
            ("queue_wait", &m.queue_wait),
            ("prefill_latency", &m.prefill_latency),
            ("decode_step_latency", &m.decode_step_latency),
            ("request_latency", &m.request_latency),
            ("ttft", &m.ttft),
            ("ttft_interactive", &m.ttft_interactive),
            ("ttft_batch", &m.ttft_batch),
        ] {
            histograms.insert(name.to_string(), HistogramSnapshot::of(h));
        }
        WorkerSnapshot { worker, scalars, histograms }
    }

    pub fn scalar(&self, name: &str) -> u64 {
        self.scalars.get(name).copied().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let scalars = Json::Obj(
            self.scalars.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let histograms = Json::Obj(
            self.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect(),
        );
        Json::obj(vec![
            ("worker", Json::Num(self.worker as f64)),
            ("scalars", scalars),
            ("histograms", histograms),
        ])
    }

    pub fn from_json(j: &Json) -> Result<WorkerSnapshot> {
        let scalars = match j.req("scalars")? {
            Json::Obj(m) => m
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        v.as_f64().ok_or_else(|| anyhow!("scalar '{k}' not a number"))? as u64,
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => return Err(anyhow!("scalars must be an object")),
        };
        let histograms = match j.req("histograms")? {
            Json::Obj(m) => m
                .iter()
                .map(|(k, v)| Ok((k.clone(), HistogramSnapshot::from_json(v)?)))
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => return Err(anyhow!("histograms must be an object")),
        };
        Ok(WorkerSnapshot {
            worker: j.num_or("worker", 0.0) as usize,
            scalars,
            histograms,
        })
    }
}

/// Point-in-time freeze of a whole pool's metrics.  `ts_ms` is wall-clock
/// (Unix epoch) so two snapshots — possibly from different processes —
/// span a rate window.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub ts_ms: u64,
    pub n_workers: usize,
    /// Workers still in rotation (total minus supervisor-retired).
    pub live_workers: usize,
    pub pool: BTreeMap<String, u64>,
    pub workers: Vec<WorkerSnapshot>,
}

impl MetricsSnapshot {
    /// Freeze `metrics` now.  `live_workers` comes from the pool's router
    /// state (the metrics bundle itself only counts deaths).
    pub fn collect(metrics: &PoolMetrics, live_workers: usize) -> MetricsSnapshot {
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut pool = BTreeMap::new();
        let mut put = |k: &str, v: u64| {
            pool.insert(k.to_string(), v);
        };
        put("router_rejected", metrics.router_rejected.get());
        put("workers_dead", metrics.workers_dead.get());
        put("requests_redispatched", metrics.requests_redispatched.get());
        put("requests_done", metrics.requests_done());
        put("requests_rejected", metrics.requests_rejected());
        put("requests_cancelled", metrics.requests_cancelled());
        put("sessions_evicted", metrics.sessions_evicted());
        put("tokens_out", metrics.tokens_out());
        put("prefill_chunks", metrics.prefill_chunks());
        put("prefill_preemptions", metrics.prefill_preemptions());
        put("cache_bytes_in_use", metrics.cache_bytes_in_use());
        put("cache_peak_bytes", metrics.cache_peak_bytes());
        put("cache_cached_bytes", metrics.cache_cached_bytes());
        put("blocks_evicted", metrics.blocks_evicted());
        put("prefix_lookup_tokens", metrics.prefix_lookup_tokens());
        put("prefix_hit_tokens", metrics.prefix_hit_tokens());
        put("prefill_tokens_skipped", metrics.prefill_tokens_skipped());
        put("fp16_bytes_per_token", metrics.fp16_bytes_per_token());
        put("window_tokens", metrics.window_tokens());
        put("window_retired_tokens", metrics.window_retired_tokens());
        put("conns_open", metrics.conns_open.get());
        put("conns_read_paused", metrics.conns_read_paused.get());
        put("fanout_subscribers", metrics.fanout_subscribers.get());
        put("frames_dropped", metrics.frames_dropped.get());
        put("conns_dropped_slow", metrics.conns_dropped_slow.get());
        put("accept_transient_errors", metrics.accept_transient_errors.get());
        for (name, bytes) in metrics.policy_bytes() {
            put(&format!("policy_bytes_{name}"), bytes);
        }
        let workers = metrics
            .workers()
            .iter()
            .enumerate()
            .map(|(i, m)| WorkerSnapshot::of(i, m))
            .collect();
        MetricsSnapshot {
            ts_ms,
            n_workers: metrics.n_workers(),
            live_workers,
            pool,
            workers,
        }
    }

    pub fn pool_scalar(&self, name: &str) -> u64 {
        self.pool.get(name).copied().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let pool = Json::Obj(
            self.pool.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        Json::obj(vec![
            ("ts_ms", Json::Num(self.ts_ms as f64)),
            ("n_workers", Json::Num(self.n_workers as f64)),
            ("live_workers", Json::Num(self.live_workers as f64)),
            ("pool", pool),
            (
                "workers",
                Json::Arr(self.workers.iter().map(WorkerSnapshot::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        let pool = match j.req("pool")? {
            Json::Obj(m) => m
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        v.as_f64().ok_or_else(|| anyhow!("pool '{k}' not a number"))? as u64,
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => return Err(anyhow!("pool must be an object")),
        };
        let workers = j
            .req("workers")?
            .as_arr()
            .ok_or_else(|| anyhow!("workers must be an array"))?
            .iter()
            .map(WorkerSnapshot::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(MetricsSnapshot {
            ts_ms: j.num_or("ts_ms", 0.0) as u64,
            n_workers: j.num_or("n_workers", 0.0) as usize,
            live_workers: j.num_or("live_workers", 0.0) as usize,
            pool,
            workers,
        })
    }
}

/// Throughput rates derived from two snapshots of the same pool.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rates {
    pub window_s: f64,
    pub tok_per_s: f64,
    pub chunks_per_s: f64,
    pub requests_per_s: f64,
}

impl Rates {
    /// Rates over `prev → cur`; `None` when the window is empty or
    /// non-increasing (same scrape twice, clock skew).
    pub fn between(prev: &MetricsSnapshot, cur: &MetricsSnapshot) -> Option<Rates> {
        let window_s = cur.ts_ms.saturating_sub(prev.ts_ms) as f64 / 1e3;
        if window_s <= 0.0 {
            return None;
        }
        let delta = |k: &str| {
            cur.pool_scalar(k).saturating_sub(prev.pool_scalar(k)) as f64 / window_s
        };
        Some(Rates {
            window_s,
            tok_per_s: delta("tokens_out"),
            chunks_per_s: delta("prefill_chunks"),
            requests_per_s: delta("requests_done"),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::Num(self.window_s)),
            ("tok_per_s", Json::Num(self.tok_per_s)),
            ("chunks_per_s", Json::Num(self.chunks_per_s)),
            ("requests_per_s", Json::Num(self.requests_per_s)),
        ])
    }
}

/// Prometheus-exposition-style text rendering of a snapshot: pool scalars
/// as `cq_pool_<name>`, worker scalars as `cq_worker_<name>{worker="i"}`,
/// histograms as `<name>_ms` summaries with cumulative `_bucket` lines
/// (`le` in milliseconds, capped by `+Inf`).
pub fn prometheus_text(s: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "cq_pool_n_workers {}", s.n_workers);
    let _ = writeln!(out, "cq_pool_live_workers {}", s.live_workers);
    for (k, v) in &s.pool {
        let _ = writeln!(out, "cq_pool_{k} {v}");
    }
    for w in &s.workers {
        for (k, v) in &w.scalars {
            let _ = writeln!(out, "cq_worker_{k}{{worker=\"{}\"}} {v}", w.worker);
        }
        for (name, h) in &w.histograms {
            let _ = writeln!(
                out,
                "cq_{name}_ms_count{{worker=\"{}\"}} {}",
                w.worker, h.count
            );
            let _ = writeln!(
                out,
                "cq_{name}_ms_sum{{worker=\"{}\"}} {}",
                w.worker,
                h.sum_ns as f64 / 1e6
            );
            let mut acc = 0u64;
            for &(i, n) in &h.buckets {
                acc += n;
                let le = Histogram::bucket_upper_us(i) / 1e3;
                let _ = writeln!(
                    out,
                    "cq_{name}_ms_bucket{{worker=\"{}\",le=\"{le}\"}} {acc}",
                    w.worker
                );
            }
            let _ = writeln!(
                out,
                "cq_{name}_ms_bucket{{worker=\"{}\",le=\"+Inf\"}} {}",
                w.worker, h.count
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn loaded_pool() -> (PoolMetrics, Arc<ServeMetrics>, Arc<ServeMetrics>) {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.tokens_out.add(120);
        w1.tokens_out.add(30);
        w0.requests_done.add(7);
        w1.requests_done.add(3);
        w0.prefill_chunks.add(12);
        w0.prefill_preemptions.add(2);
        w0.prefill_backlog_tokens.set(96);
        w1.requests_rejected.add(1);
        w0.requests_cancelled.add(2);
        w0.sessions_evicted.add(1);
        w0.session_tokens.publish(9, 64);
        w0.cache_reserved_bytes.add(4096);
        w0.cache_released_bytes.add(1024);
        w0.cache_peak_bytes.observe_max(4096);
        w0.cache_frag_bytes.observe_max(100);
        w0.prefix_lookup_tokens.add(200);
        w0.prefix_hit_tokens.add(50);
        w0.prefill_tokens_skipped.add(50);
        w1.prefill_tokens_skipped.add(6);
        w0.encode_pool_busy.set(5);
        w0.encode_pool_threads.set(4);
        w0.blocks_promoted.add(8);
        w0.blocks_evicted.add(3);
        w0.block_bytes.observe_max(64);
        w0.bytes_per_token.observe_max(4);
        w0.fp16_bytes_per_token.observe_max(64);
        w0.window_tokens.set(24);
        w0.window_retired_tokens.add(17);
        w0.policy_bytes.add("cq-8c8b-w4", 512);
        w0.policy_bytes.add("fp16", 2048);
        w1.policy_bytes.add("fp16", 1024);
        w0.max_prompt_tokens.observe_max(48);
        w0.phases.iterations.add(10);
        w0.phases.record_idle(Duration::from_micros(500));
        w0.phases.record_encode(Duration::from_micros(150));
        w0.phases.record_decode(Duration::from_micros(300));
        for ms in [1u64, 2, 8] {
            w0.ttft.record(Duration::from_millis(ms));
            w0.decode_step_latency.record(Duration::from_millis(ms));
        }
        w1.queue_wait.record(Duration::from_micros(700));
        w1.request_latency.record(Duration::from_millis(25));
        let t = w0.trace.begin(1, "interactive", 4).unwrap();
        w0.trace.settle(&t, crate::metrics::trace::TraceOutcome::Done, "");
        let pool = PoolMetrics::new(vec![w0.clone(), w1.clone()]);
        pool.router_rejected.add(2);
        pool.workers_dead.add(1);
        pool.requests_redispatched.add(3);
        pool.conns_open.set(11);
        pool.conns_read_paused.set(2);
        pool.fanout_subscribers.set(5);
        pool.frames_dropped.add(9);
        pool.conns_dropped_slow.add(1);
        pool.accept_transient_errors.add(4);
        (pool, w0, w1)
    }

    #[test]
    fn snapshot_roundtrips_every_counter_and_bucket() {
        let (pool, w0, _w1) = loaded_pool();
        let snap = MetricsSnapshot::collect(&pool, 1);
        // Counters match the live bundles they froze.
        assert_eq!(snap.n_workers, 2);
        assert_eq!(snap.live_workers, 1);
        assert_eq!(snap.pool_scalar("tokens_out"), 150);
        assert_eq!(snap.pool_scalar("requests_done"), 10);
        assert_eq!(snap.pool_scalar("requests_rejected"), 3, "worker + router");
        assert_eq!(snap.pool_scalar("workers_dead"), 1);
        assert_eq!(snap.workers[0].scalar("tokens_out"), 120);
        assert_eq!(snap.workers[0].scalar("prefill_backlog_tokens"), 96);
        assert_eq!(snap.workers[0].scalar("live_sessions"), 1);
        assert_eq!(snap.workers[0].scalar("cache_in_use_bytes"), 3072);
        assert_eq!(snap.workers[0].scalar("trace_finished"), 1);
        assert_eq!(snap.workers[0].scalar("phase_idle_ns"), 500_000);
        // Encode-pool + radix-skip observables survive the freeze: the
        // per-worker scalars and the pool-level aggregate.
        assert_eq!(snap.workers[0].scalar("prefill_tokens_skipped"), 50);
        assert_eq!(snap.workers[0].scalar("encode_pool_busy"), 5);
        assert_eq!(snap.workers[0].scalar("encode_pool_threads"), 4);
        assert_eq!(snap.workers[0].scalar("phase_encode_ns"), 150_000);
        assert_eq!(snap.workers[0].scalar("phase_last_encode_ns"), 150_000);
        assert_eq!(snap.pool_scalar("prefill_tokens_skipped"), 56, "w0 + w1");
        // Policy observables: window occupancy/retire counters and dynamic
        // per-policy byte scalars (merged name-wise at pool level).
        assert_eq!(snap.workers[0].scalar("fp16_bytes_per_token"), 64);
        assert_eq!(snap.workers[0].scalar("window_tokens"), 24);
        assert_eq!(snap.workers[0].scalar("window_retired_tokens"), 17);
        assert_eq!(snap.workers[0].scalar("policy_bytes_cq-8c8b-w4"), 512);
        assert_eq!(snap.workers[0].scalar("policy_bytes_fp16"), 2048);
        assert_eq!(snap.pool_scalar("window_tokens"), 24);
        assert_eq!(snap.pool_scalar("window_retired_tokens"), 17);
        assert_eq!(snap.pool_scalar("fp16_bytes_per_token"), 64);
        assert_eq!(snap.pool_scalar("policy_bytes_cq-8c8b-w4"), 512);
        assert_eq!(snap.pool_scalar("policy_bytes_fp16"), 3072, "w0 + w1");
        // Frontend (reactor) gauges and counters ride the same snapshot.
        assert_eq!(snap.pool_scalar("conns_open"), 11);
        assert_eq!(snap.pool_scalar("conns_read_paused"), 2);
        assert_eq!(snap.pool_scalar("fanout_subscribers"), 5);
        assert_eq!(snap.pool_scalar("frames_dropped"), 9);
        assert_eq!(snap.pool_scalar("conns_dropped_slow"), 1);
        assert_eq!(snap.pool_scalar("accept_transient_errors"), 4);
        let ttft = &snap.workers[0].histograms["ttft"];
        assert_eq!(ttft.count, 3);
        assert_eq!(ttft.sum_ns, 11_000_000);
        assert_eq!(
            ttft.buckets.iter().map(|&(_, n)| n).sum::<u64>(),
            3,
            "every sample lands in a serialized bucket"
        );
        // Percentiles computed from the frozen buckets match the live ones.
        assert_eq!(ttft.percentile_ms(0.5), w0.ttft.percentile_ms(0.5));
        assert_eq!(ttft.percentile_ms(1.0), w0.ttft.percentile_ms(1.0));
        assert!((ttft.mean_ms() - w0.ttft.mean_ms()).abs() < 1e-12);
        // JSON → text → parse → struct preserves everything.
        let line = snap.to_json().dump();
        let back = MetricsSnapshot::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn rates_match_hand_built_counter_deltas() {
        let (pool, w0, _w1) = loaded_pool();
        let mut prev = MetricsSnapshot::collect(&pool, 2);
        prev.ts_ms = 10_000;
        // 4 s later: +200 tokens, +8 chunks, +4 requests.
        w0.tokens_out.add(200);
        w0.prefill_chunks.add(8);
        w0.requests_done.add(4);
        let mut cur = MetricsSnapshot::collect(&pool, 2);
        cur.ts_ms = 14_000;
        let rates = Rates::between(&prev, &cur).unwrap();
        assert!((rates.window_s - 4.0).abs() < 1e-12);
        assert!((rates.tok_per_s - 50.0).abs() < 1e-12);
        assert!((rates.chunks_per_s - 2.0).abs() < 1e-12);
        assert!((rates.requests_per_s - 1.0).abs() < 1e-12);
        let j = rates.to_json();
        assert_eq!(j.get("tok_per_s").unwrap().as_f64().unwrap(), 50.0);
        // Degenerate windows refuse to divide.
        assert!(Rates::between(&cur, &prev).is_none(), "negative window");
        assert!(Rates::between(&cur, &cur).is_none(), "zero window");
    }

    #[test]
    fn prometheus_text_renders_scalars_and_cumulative_buckets() {
        let (pool, _w0, _w1) = loaded_pool();
        let snap = MetricsSnapshot::collect(&pool, 2);
        let text = prometheus_text(&snap);
        assert!(text.contains("cq_pool_tokens_out 150"), "{text}");
        assert!(text.contains("cq_pool_live_workers 2"), "{text}");
        assert!(text.contains("cq_worker_tokens_out{worker=\"0\"} 120"), "{text}");
        assert!(text.contains("cq_pool_prefill_tokens_skipped 56"), "{text}");
        assert!(text.contains("cq_worker_encode_pool_busy{worker=\"0\"} 5"), "{text}");
        assert!(text.contains("cq_worker_phase_encode_ns{worker=\"0\"} 150000"), "{text}");
        // Dynamic per-policy scalars render like any other name.
        assert!(text.contains("cq_pool_policy_bytes_fp16 3072"), "{text}");
        assert!(text.contains("cq_worker_policy_bytes_cq-8c8b-w4{worker=\"0\"} 512"), "{text}");
        assert!(text.contains("cq_pool_window_retired_tokens 17"), "{text}");
        assert!(text.contains("cq_ttft_ms_count{worker=\"0\"} 3"), "{text}");
        assert!(text.contains("cq_ttft_ms_bucket{worker=\"0\",le=\"+Inf\"} 3"), "{text}");
        // Bucket lines are cumulative: the last finite `le` carries the
        // full count.
        let last_finite = text
            .lines()
            .rev()
            .find(|l| {
                l.starts_with("cq_ttft_ms_bucket{worker=\"0\",le=\"") && !l.contains("+Inf")
            })
            .unwrap();
        assert!(last_finite.ends_with(" 3"), "{last_finite}");
    }

    #[test]
    fn missing_scalar_names_read_as_zero() {
        let snap = MetricsSnapshot {
            ts_ms: 0,
            n_workers: 0,
            live_workers: 0,
            pool: BTreeMap::new(),
            workers: Vec::new(),
        };
        assert_eq!(snap.pool_scalar("tokens_out"), 0);
    }
}

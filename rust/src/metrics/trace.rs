//! Per-request flight recorder (observability layer 3).
//!
//! A [`RequestTrace`] records monotonic-clock span events through a
//! request's serve-loop lifecycle: enqueued → admitted → each prefill
//! chunk (index + tokens) → first token → decode steps (sampled, see
//! [`sample_decode_step`]) → one terminal event (done / failed /
//! cancelled / redispatched, with reason).  The worker's
//! [`TraceRecorder`] keeps the in-flight set plus a bounded ring of
//! terminal traces (`--trace-ring`, default [`DEFAULT_TRACE_RING`];
//! 0 disables tracing entirely).
//!
//! Two consumers read a recorder from outside its worker thread:
//!
//! * the `{"op":"trace"}` admin op serializes the whole recorder
//!   (live + finished + crashed) for a wire scrape;
//! * the pool supervisor calls [`TraceRecorder::dump_crashed`] when it
//!   retires a crashed worker, converting every live trace into a
//!   terminal post-mortem (`failed` if the request had already produced
//!   its first token, `redispatched` otherwise — mirroring the
//!   `EventSink` drop semantics) kept in a separate crash-dump store.
//!
//! All locks recover from poisoning (`unwrap_or_else(e.into_inner())`):
//! the whole point of the crash dump is reading a recorder whose owning
//! worker just panicked.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::util::json::Json;

use super::Counter;

/// Default `--trace-ring` capacity: terminal traces retained per worker.
pub const DEFAULT_TRACE_RING: usize = 256;

/// Decode-step sampling policy: every early step (the interesting ramp)
/// plus every 16th thereafter, so long generations cost O(gen/16) trace
/// events instead of O(gen).
pub fn sample_decode_step(index: usize) -> bool {
    index < 4 || index % 16 == 0
}

/// Terminal disposition of a traced request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOutcome {
    Done,
    Failed,
    Cancelled,
    /// The request died *unprocessed* with its worker and was re-routed to
    /// a live worker (its trace there starts over).
    Redispatched,
}

impl TraceOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceOutcome::Done => "done",
            TraceOutcome::Failed => "failed",
            TraceOutcome::Cancelled => "cancelled",
            TraceOutcome::Redispatched => "redispatched",
        }
    }
}

/// One span event in a request's lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEventKind {
    Enqueued,
    Admitted,
    PrefillChunk { index: usize, tokens: usize },
    FirstToken,
    DecodeStep { index: usize },
    Terminal { outcome: TraceOutcome, reason: String },
}

impl TraceEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::Enqueued => "enqueued",
            TraceEventKind::Admitted => "admitted",
            TraceEventKind::PrefillChunk { .. } => "prefill_chunk",
            TraceEventKind::FirstToken => "first_token",
            TraceEventKind::DecodeStep { .. } => "decode_step",
            TraceEventKind::Terminal { .. } => "terminal",
        }
    }
}

/// A timestamped span event: `at_ms` is milliseconds since the trace
/// began (monotonic clock, so spans are crash-safe and NTP-immune).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub at_ms: f64,
    pub kind: TraceEventKind,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("t_ms", Json::Num((self.at_ms * 1000.0).round() / 1000.0)),
            ("kind", Json::Str(self.kind.name().to_string())),
        ];
        match &self.kind {
            TraceEventKind::PrefillChunk { index, tokens } => {
                pairs.push(("chunk", Json::Num(*index as f64)));
                pairs.push(("tokens", Json::Num(*tokens as f64)));
            }
            TraceEventKind::DecodeStep { index } => {
                pairs.push(("step", Json::Num(*index as f64)));
            }
            TraceEventKind::Terminal { outcome, reason } => {
                pairs.push(("outcome", Json::Str(outcome.as_str().to_string())));
                if !reason.is_empty() {
                    pairs.push(("reason", Json::Str(reason.clone())));
                }
            }
            _ => {}
        }
        Json::obj(pairs)
    }
}

/// One request's flight record.  Shared (`Arc`) between the run state in
/// the serve loop, the recorder's live map, and — after settlement — the
/// terminal ring, so marking events never copies history.
pub struct RequestTrace {
    pub id: u64,
    /// Scheduling class, as the wire string (`"interactive"`/`"batch"`).
    pub priority: &'static str,
    pub prompt_tokens: usize,
    t0: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

impl RequestTrace {
    fn new(id: u64, priority: &'static str, prompt_tokens: usize) -> RequestTrace {
        let t = RequestTrace {
            id,
            priority,
            prompt_tokens,
            t0: Instant::now(),
            events: Mutex::new(Vec::new()),
        };
        t.mark(TraceEventKind::Enqueued);
        t
    }

    fn locked(&self) -> MutexGuard<'_, Vec<TraceEvent>> {
        self.events.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Append a span event stamped with the elapsed monotonic time.
    pub fn mark(&self, kind: TraceEventKind) {
        let at_ms = self.t0.elapsed().as_secs_f64() * 1e3;
        self.locked().push(TraceEvent { at_ms, kind });
    }

    /// Copy of the recorded events, in append order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.locked().clone()
    }

    /// The terminal disposition, once one was marked.
    pub fn outcome(&self) -> Option<(TraceOutcome, String)> {
        self.locked().iter().rev().find_map(|e| match &e.kind {
            TraceEventKind::Terminal { outcome, reason } => {
                Some((*outcome, reason.clone()))
            }
            _ => None,
        })
    }

    /// True once the request produced its first token (prefill complete) —
    /// the boundary between "redispatchable" and "mid-flight" on a crash.
    pub fn reached_first_token(&self) -> bool {
        self.locked().iter().any(|e| matches!(e.kind, TraceEventKind::FirstToken))
    }

    pub fn to_json(&self) -> Json {
        let events = self.events();
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("priority", Json::Str(self.priority.to_string())),
            ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
            ("events", Json::Arr(events.iter().map(TraceEvent::to_json).collect())),
        ];
        if let Some((outcome, reason)) = self.outcome() {
            pairs.push(("outcome", Json::Str(outcome.as_str().to_string())));
            if !reason.is_empty() {
                pairs.push(("reason", Json::Str(reason)));
            }
        }
        Json::obj(pairs)
    }
}

/// Per-worker flight recorder: the live in-flight set, a bounded ring of
/// terminal traces, and the crash-dump store the supervisor fills when it
/// retires this worker.  Lives inside `ServeMetrics` so the worker, the
/// supervisor, and the TCP admin ops all reach it through the existing
/// metrics `Arc` — no extra plumbing.
pub struct TraceRecorder {
    /// Ring capacity; 0 disables tracing ([`Self::begin`] returns `None`).
    cap: AtomicUsize,
    live: Mutex<HashMap<u64, Arc<RequestTrace>>>,
    ring: Mutex<VecDeque<Arc<RequestTrace>>>,
    crashed: Mutex<Vec<Arc<RequestTrace>>>,
    /// Terminal traces evicted from the ring (scrape staleness signal).
    pub dropped: Counter,
}

impl Default for TraceRecorder {
    fn default() -> TraceRecorder {
        TraceRecorder {
            cap: AtomicUsize::new(DEFAULT_TRACE_RING),
            live: Mutex::new(HashMap::new()),
            ring: Mutex::new(VecDeque::new()),
            crashed: Mutex::new(Vec::new()),
            dropped: Counter::default(),
        }
    }
}

impl TraceRecorder {
    /// Set the terminal-trace ring capacity (`--trace-ring`); 0 disables
    /// tracing.  The serve loop applies its config value at startup.
    pub fn set_capacity(&self, cap: usize) {
        self.cap.store(cap, Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        while ring.len() > cap {
            ring.pop_front();
            self.dropped.add(1);
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    pub fn enabled(&self) -> bool {
        self.capacity() > 0
    }

    /// Start tracing a request at enqueue time.  `None` when disabled —
    /// callers thread the `Option` through and marking becomes free.
    pub fn begin(
        &self,
        id: u64,
        priority: &'static str,
        prompt_tokens: usize,
    ) -> Option<Arc<RequestTrace>> {
        if !self.enabled() {
            return None;
        }
        let trace = Arc::new(RequestTrace::new(id, priority, prompt_tokens));
        self.live
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id, trace.clone());
        Some(trace)
    }

    /// Terminal settlement: mark the outcome, move the trace from the live
    /// set into the bounded ring (evicting the oldest beyond capacity).
    pub fn settle(&self, trace: &Arc<RequestTrace>, outcome: TraceOutcome, reason: &str) {
        trace.mark(TraceEventKind::Terminal { outcome, reason: reason.to_string() });
        self.live.lock().unwrap_or_else(|e| e.into_inner()).remove(&trace.id);
        let cap = self.capacity();
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        ring.push_back(trace.clone());
        while ring.len() > cap {
            ring.pop_front();
            self.dropped.add(1);
        }
    }

    /// Crash post-mortem (supervisor, on retiring this recorder's worker):
    /// every live trace gets a terminal event — `redispatched` if the
    /// request never reached its first token (the `EventSink` re-routes it
    /// to a live worker), `failed` if it died mid-flight — and moves into
    /// the crash-dump store, which survives past retirement for
    /// `{"op":"trace"}` scrapes.  Returns the number of traces dumped.
    pub fn dump_crashed(&self, reason: &str) -> usize {
        let drained: Vec<Arc<RequestTrace>> = {
            let mut live = self.live.lock().unwrap_or_else(|e| e.into_inner());
            let mut v: Vec<_> = live.drain().map(|(_, t)| t).collect();
            // Deterministic dump order for tests and log readers.
            v.sort_by_key(|t| t.id);
            v
        };
        let n = drained.len();
        let mut crashed = self.crashed.lock().unwrap_or_else(|e| e.into_inner());
        for trace in drained {
            let outcome = if trace.reached_first_token() {
                TraceOutcome::Failed
            } else {
                TraceOutcome::Redispatched
            };
            trace.mark(TraceEventKind::Terminal { outcome, reason: reason.to_string() });
            crashed.push(trace);
        }
        n
    }

    pub fn live_count(&self) -> usize {
        self.live.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn finished_count(&self) -> usize {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn crashed_count(&self) -> usize {
        self.crashed.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Terminal traces currently retained, oldest first.
    pub fn finished(&self) -> Vec<Arc<RequestTrace>> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).iter().cloned().collect()
    }

    /// Crash-dump traces (empty unless the supervisor retired this worker).
    pub fn crash_dump(&self) -> Vec<Arc<RequestTrace>> {
        self.crashed.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Whole-recorder serialization for the `{"op":"trace"}` admin op.
    pub fn to_json(&self) -> Json {
        let live: Vec<Arc<RequestTrace>> = {
            let map = self.live.lock().unwrap_or_else(|e| e.into_inner());
            let mut v: Vec<_> = map.values().cloned().collect();
            v.sort_by_key(|t| t.id);
            v
        };
        Json::obj(vec![
            ("capacity", Json::Num(self.capacity() as f64)),
            ("dropped", Json::Num(self.dropped.get() as f64)),
            ("live", Json::Arr(live.iter().map(|t| t.to_json()).collect())),
            (
                "finished",
                Json::Arr(self.finished().iter().map(|t| t.to_json()).collect()),
            ),
            (
                "crashed",
                Json::Arr(self.crash_dump().iter().map(|t| t.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_records_ordered_spans_and_outcome() {
        let rec = TraceRecorder::default();
        let t = rec.begin(7, "interactive", 12).expect("enabled by default");
        t.mark(TraceEventKind::PrefillChunk { index: 0, tokens: 8 });
        t.mark(TraceEventKind::PrefillChunk { index: 1, tokens: 4 });
        t.mark(TraceEventKind::FirstToken);
        t.mark(TraceEventKind::DecodeStep { index: 1 });
        assert_eq!(rec.live_count(), 1);
        assert!(t.outcome().is_none(), "no terminal yet");
        rec.settle(&t, TraceOutcome::Done, "");
        assert_eq!(rec.live_count(), 0);
        assert_eq!(rec.finished_count(), 1);
        let events = t.events();
        assert_eq!(events.first().unwrap().kind, TraceEventKind::Enqueued);
        assert!(
            events.windows(2).all(|w| w[0].at_ms <= w[1].at_ms),
            "span timestamps are monotone"
        );
        assert_eq!(t.outcome().unwrap().0, TraceOutcome::Done);
        assert!(t.reached_first_token());
        // Serialized shape: id + events with kinds in order.
        let j = t.to_json();
        assert_eq!(j.get("id").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "done");
        let kinds: Vec<String> = j
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.str_or("kind", "?"))
            .collect();
        assert_eq!(
            kinds,
            ["enqueued", "prefill_chunk", "prefill_chunk", "first_token", "decode_step", "terminal"]
        );
    }

    #[test]
    fn ring_evicts_oldest_terminal_traces() {
        let rec = TraceRecorder::default();
        rec.set_capacity(2);
        for id in 0..3u64 {
            let t = rec.begin(id, "batch", 1).unwrap();
            rec.settle(&t, TraceOutcome::Done, "");
        }
        assert_eq!(rec.finished_count(), 2);
        assert_eq!(rec.dropped.get(), 1);
        let kept: Vec<u64> = rec.finished().iter().map(|t| t.id).collect();
        assert_eq!(kept, [1, 2], "oldest trace evicted first");
        // Shrinking the capacity trims the ring too.
        rec.set_capacity(1);
        assert_eq!(rec.finished_count(), 1);
        assert_eq!(rec.dropped.get(), 2);
    }

    #[test]
    fn zero_capacity_disables_tracing() {
        let rec = TraceRecorder::default();
        rec.set_capacity(0);
        assert!(!rec.enabled());
        assert!(rec.begin(1, "interactive", 4).is_none());
        assert_eq!(rec.live_count(), 0);
        assert_eq!(rec.to_json().get("live").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn crash_dump_classifies_by_first_token() {
        let rec = TraceRecorder::default();
        // Request 1 was mid-decode (first token already out); request 2
        // was still prefilling when the worker died.
        let t1 = rec.begin(1, "interactive", 8).unwrap();
        t1.mark(TraceEventKind::FirstToken);
        let t2 = rec.begin(2, "batch", 8).unwrap();
        t2.mark(TraceEventKind::PrefillChunk { index: 0, tokens: 4 });
        assert_eq!(rec.dump_crashed("worker 0 crashed: boom"), 2);
        assert_eq!(rec.live_count(), 0, "live set drained into the dump");
        assert_eq!(rec.crashed_count(), 2);
        let dump = rec.crash_dump();
        assert_eq!(dump[0].id, 1);
        assert_eq!(dump[0].outcome().unwrap().0, TraceOutcome::Failed);
        let (outcome, reason) = dump[1].outcome().unwrap();
        assert_eq!(outcome, TraceOutcome::Redispatched);
        assert!(reason.contains("boom"));
        // The dump serializes under "crashed" and survives a JSON roundtrip.
        let j = Json::parse(&rec.to_json().dump()).unwrap();
        assert_eq!(j.get("crashed").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("crashed").unwrap().as_arr().unwrap()[1]
                .get("outcome")
                .unwrap()
                .as_str()
                .unwrap(),
            "redispatched"
        );
    }

    #[test]
    fn decode_step_sampling_keeps_early_and_periodic_steps() {
        assert!(sample_decode_step(0) && sample_decode_step(3));
        assert!(!sample_decode_step(5) && !sample_decode_step(15));
        assert!(sample_decode_step(16) && sample_decode_step(32));
    }
}

//! Telemetry: latency histograms, throughput counters, and the von-Neumann
//! memory-traffic model the paper's §2.2 argument rests on.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-bucketed latency histogram (thread-safe, lock-free).
pub struct Histogram {
    /// Buckets: [0, 1µs), [1µs, 2µs), [2µs, 4µs) ... doubling.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns < 1000 {
            0
        } else {
            (64 - (ns / 1000).leading_zeros() as usize).min(47)
        }
    }

    pub fn record(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate percentile from bucket upper bounds (µs resolution).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                let upper_us = if i == 0 { 1u64 } else { 1u64 << i };
                return upper_us as f64 / 1e3;
            }
        }
        f64::INFINITY
    }
}

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Memory-traffic model for one decode step (paper §2.2): every generated
/// token must read the entire cache of its sequence once.  Comparing fp16
/// and packed-code traffic gives the bandwidth-bound speedup ceiling.
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub bits_per_fpn: f64,
}

impl TrafficModel {
    /// Bytes read from cache to decode one token at context length `t`.
    pub fn bytes_per_decode(&self, t: usize) -> f64 {
        let fpns = (2 * self.n_layers * self.n_heads * self.head_dim * t) as f64;
        fpns * self.bits_per_fpn / 8.0
    }

    /// Speedup ceiling vs an fp16 cache (ratio of traffic).
    pub fn speedup_vs_fp16(&self) -> f64 {
        16.0 / self.bits_per_fpn
    }
}

/// Serving metrics bundle.
#[derive(Default)]
pub struct ServeMetrics {
    pub queue_wait: Histogram,
    pub prefill_latency: Histogram,
    pub decode_step_latency: Histogram,
    pub request_latency: Histogram,
    pub tokens_out: Counter,
    pub requests_done: Counter,
    pub requests_rejected: Counter,
}

impl ServeMetrics {
    pub fn summary(&self, wall_secs: f64) -> String {
        format!(
            "requests={} rejected={} tokens={} tput={:.1} tok/s  decode p50={:.2}ms p95={:.2}ms  e2e p50={:.1}ms p95={:.1}ms",
            self.requests_done.get(),
            self.requests_rejected.get(),
            self.tokens_out.get(),
            self.tokens_out.get() as f64 / wall_secs.max(1e-9),
            self.decode_step_latency.percentile_ms(0.5),
            self.decode_step_latency.percentile_ms(0.95),
            self.request_latency.percentile_ms(0.5),
            self.request_latency.percentile_ms(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_records_and_reports() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ms() > 20.0 && h.mean_ms() < 30.0);
        let p50 = h.percentile_ms(0.5);
        assert!(p50 >= 2.0 && p50 <= 8.2, "p50={p50}");
        assert!(h.percentile_ms(1.0) >= 100.0);
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn traffic_model_matches_paper_ratios() {
        let fp = TrafficModel { n_layers: 4, n_heads: 4, head_dim: 64, bits_per_fpn: 16.0 };
        let cq1 = TrafficModel { bits_per_fpn: 1.0, ..fp };
        // 16x traffic reduction at 1 bit/FPN.
        assert!((fp.bytes_per_decode(512) / cq1.bytes_per_decode(512) - 16.0).abs() < 1e-9);
        assert!((cq1.speedup_vs_fp16() - 16.0).abs() < 1e-9);
        // Absolute check: fp16, T=512: 2*4*4*64*512 fpns * 2 bytes = 2 MiB.
        assert_eq!(fp.bytes_per_decode(512) as usize, 2 * 4 * 4 * 64 * 512 * 2);
    }
}

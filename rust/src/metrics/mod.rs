//! Telemetry: the pool's three-layer observability stack, plus the
//! von-Neumann memory-traffic model the paper's §2.2 argument rests on.
//!
//! Layer 1 — **primitives** (this module): lock-free [`Histogram`] /
//! [`Counter`] / [`Gauge`] / [`Level`], the per-worker [`ServeMetrics`]
//! bundle, serve-loop [`PhaseMetrics`] (where each scheduler iteration's
//! wall-clock went: idle / prefill / decode / quantize+store), and the
//! pool-level [`PoolMetrics`] aggregation (counters sum, histograms merge
//! bucket-wise).
//!
//! Layer 2 — **export** ([`export`]): a point-in-time
//! [`export::MetricsSnapshot`] of every counter / gauge / level / raw
//! histogram bucket, serialized via `util::json`, with
//! delta-vs-previous-snapshot [`export::Rates`] (tok/s, chunks/s over the
//! window) and a Prometheus-style text rendering.  The TCP frontend serves
//! these as the `{"op":"metrics"}` / `{"op":"health"}` admin ops (see the
//! `server` wire doc).
//!
//! Layer 3 — **flight recorder** ([`trace`]): per-request
//! [`trace::RequestTrace`] span events (enqueued → admitted → each prefill
//! chunk → first token → sampled decode steps → terminal) kept in a
//! bounded per-worker ring, queryable via `{"op":"trace"}` and dumped by
//! the pool supervisor when it retires a crashed worker, so a chaos kill
//! leaves a post-mortem instead of silence.

pub mod export;
pub mod trace;

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Power-of-two µs octaves the log-linear histogram covers (1µs up to
/// ~9 minutes); samples beyond the last octave clamp into its top bucket.
const OCTAVES: usize = 40;
/// Linear sub-buckets per octave.
const SUBDIV: usize = 4;
/// Total bucket count: the [0, 1µs) bucket plus `OCTAVES * SUBDIV`
/// log-linear buckets.  Fixed layout — snapshots serialize indices against
/// it and [`Histogram::merge_from`] adds index-wise.
pub const NUM_BUCKETS: usize = 1 + OCTAVES * SUBDIV;

/// Log-linear latency histogram (thread-safe, lock-free).
///
/// Bucket 0 is [0, 1µs).  Above that, each power-of-two octave of
/// microseconds splits into 4 linear sub-buckets, and percentiles report
/// the matching bucket's *midpoint* — the estimate is within ±12.5% of the
/// true sample.  (The earlier pure-doubling layout returned the bucket
/// upper bound, overstating a lone 1 ms sample as 2.048 ms.)
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        let us = ns / 1000;
        if us == 0 {
            return 0;
        }
        let o = (63 - us.leading_zeros() as usize).min(OCTAVES - 1);
        let sub = (((us - (1u64 << o)) * SUBDIV as u64) >> o).min(SUBDIV as u64 - 1);
        1 + o * SUBDIV + sub as usize
    }

    /// Inclusive lower bound of bucket `i`, in µs (export bucket labels).
    pub fn bucket_lower_us(i: usize) -> f64 {
        if i == 0 {
            return 0.0;
        }
        let o = (i - 1) / SUBDIV;
        let s = (i - 1) % SUBDIV;
        (1u64 << o) as f64 * (SUBDIV + s) as f64 / SUBDIV as f64
    }

    /// Exclusive upper bound of bucket `i`, in µs (Prometheus `le` labels).
    pub fn bucket_upper_us(i: usize) -> f64 {
        if i == 0 {
            return 1.0;
        }
        let o = (i - 1) / SUBDIV;
        let s = (i - 1) % SUBDIV;
        (1u64 << o) as f64 * (SUBDIV + s + 1) as f64 / SUBDIV as f64
    }

    /// Midpoint of bucket `i`, in µs — the percentile estimate for samples
    /// landing there.
    pub fn bucket_midpoint_us(i: usize) -> f64 {
        if i == 0 {
            return 0.5;
        }
        let o = (i - 1) / SUBDIV;
        let s = (i - 1) % SUBDIV;
        (1u64 << o) as f64 * (2 * (SUBDIV + s) + 1) as f64 / (2 * SUBDIV) as f64
    }

    pub fn record(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total recorded time in ns (export; `mean_ms` is derived from it).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Non-empty buckets as `(index, count)` pairs (sparse export form).
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect()
    }

    /// Fold another histogram's samples into this one (pool aggregation).
    /// Bucket layouts are identical by construction ([`NUM_BUCKETS`]), so
    /// merging is exact index-wise addition.
    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Approximate percentile: the midpoint of the bucket containing the
    /// `p`-quantile sample (±12.5% of the true value).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_midpoint_us(i) / 1e3;
            }
        }
        f64::INFINITY
    }

    /// p99 in ms — the tail figure the snapshot summaries lead with.
    pub fn p99(&self) -> f64 {
        self.percentile_ms(0.99)
    }
}

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// High-watermark gauge (records the maximum value ever observed).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn observe_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level gauge (last value set wins — unlike [`Gauge`], which
/// only ever rises).  The serve worker publishes its current prefill chunk
/// backlog here each loop iteration; the router reads it for TTFT-SLO
/// admission.
#[derive(Default)]
pub struct Level(AtomicU64);

impl Level {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Per-worker session-length directory: session id → total conversation
/// token count, published by the worker's session table.  The pool router
/// reads it to estimate a follow-up turn's true reservation (history + new
/// text) instead of only the new turn's text — the PR 4 follow-up where the
/// pool-wide byte estimate under-counted session requests.
#[derive(Default)]
pub struct SessionTokens(Mutex<HashMap<u64, u64>>);

impl SessionTokens {
    /// Lock the directory, recovering from poisoning.  A worker panicking
    /// while holding this lock (exactly what the chaos harness induces)
    /// must not cascade panics into the supervisor's metrics reads — the
    /// map holds plain `u64`s, so the data is valid even after an unwind
    /// mid-update.
    fn locked(&self) -> std::sync::MutexGuard<'_, HashMap<u64, u64>> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn publish(&self, sid: u64, tokens: u64) {
        self.locked().insert(sid, tokens);
    }

    pub fn forget(&self, sid: u64) {
        self.locked().remove(&sid);
    }

    pub fn get(&self, sid: u64) -> Option<u64> {
        self.locked().get(&sid).copied()
    }

    /// Sessions currently published (bounded by the worker's table cap).
    pub fn live_sessions(&self) -> usize {
        self.locked().len()
    }
}

/// Per-policy resident-byte ledger: policy name → cache bytes currently
/// reserved for requests served under that policy on this worker.  Settled
/// at admission (+) and every teardown path (−, including the crash guard),
/// so per-tenant accounting stays truthful through worker death.  Same
/// poison-recovery stance as [`SessionTokens`]: plain `u64` values are
/// valid even after an unwind mid-update.
#[derive(Default)]
pub struct PolicyBytes(Mutex<BTreeMap<String, u64>>);

impl PolicyBytes {
    fn locked(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, u64>> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn add(&self, policy: &str, bytes: u64) {
        *self.locked().entry(policy.to_string()).or_insert(0) += bytes;
    }

    pub fn sub(&self, policy: &str, bytes: u64) {
        let mut m = self.locked();
        if let Some(v) = m.get_mut(policy) {
            *v = v.saturating_sub(bytes);
        }
    }

    pub fn get(&self, policy: &str) -> u64 {
        self.locked().get(policy).copied().unwrap_or(0)
    }

    /// All policies with their resident bytes (sorted by name; policies
    /// that fell back to 0 stay listed so dashboards keep the series).
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.locked().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Sum across policies (compared against the shard's own reserved
    /// bytes in the mixed-tenant accounting test).
    pub fn total(&self) -> u64 {
        self.locked().values().sum()
    }
}

/// Memory-traffic model for one decode step (paper §2.2): every generated
/// token must read the entire cache of its sequence once.  Comparing fp16
/// and packed-code traffic gives the bandwidth-bound speedup ceiling.
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub bits_per_fpn: f64,
}

impl TrafficModel {
    /// Bytes read from cache to decode one token at context length `t`.
    pub fn bytes_per_decode(&self, t: usize) -> f64 {
        let fpns = (2 * self.n_layers * self.n_heads * self.head_dim * t) as f64;
        fpns * self.bits_per_fpn / 8.0
    }

    /// Speedup ceiling vs an fp16 cache (ratio of traffic).
    pub fn speedup_vs_fp16(&self) -> f64 {
        16.0 / self.bits_per_fpn
    }
}

/// Serve-loop phase accounting: where one worker's wall-clock goes, split
/// across the four phases of a scheduler iteration — idle (blocking on the
/// inbound channel), prefill (chunk compute), decode (the batched step),
/// and store (per-lane quantize+append+stream after the step).  Cumulative
/// counters give the lifetime split; the `last_*` levels give the most
/// recent iteration's split (instantaneous, for live scrapes).
///
/// `encode` is a **sub-phase of prefill**: the slice of each prefill chunk
/// spent in the centroid-assignment kernel (pooled `encode_span_pooled` in
/// CQ mode, the synthetic code derivation in sim mode).  It is reported as
/// a fraction of the same total as the four top-level phases, so
/// `encode <= prefill` always — the gap is artifact forwards, packing and
/// store bookkeeping.  This is the number the SIMD kernel + persistent
/// encode pool are meant to shrink, visible live via `{"op":"metrics"}`.
#[derive(Default)]
pub struct PhaseMetrics {
    /// Scheduler iterations completed (including idle ones).
    pub iterations: Counter,
    pub idle_ns: Counter,
    pub prefill_ns: Counter,
    /// Encode-kernel slice of `prefill_ns` (sub-phase, not additive with
    /// the top-level four).
    pub encode_ns: Counter,
    pub decode_ns: Counter,
    pub store_ns: Counter,
    pub last_idle_ns: Level,
    pub last_prefill_ns: Level,
    pub last_encode_ns: Level,
    pub last_decode_ns: Level,
    pub last_store_ns: Level,
}

impl PhaseMetrics {
    pub fn record_idle(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.idle_ns.add(ns);
        self.last_idle_ns.set(ns);
    }

    pub fn record_prefill(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.prefill_ns.add(ns);
        self.last_prefill_ns.set(ns);
    }

    pub fn record_encode(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.encode_ns.add(ns);
        self.last_encode_ns.set(ns);
    }

    pub fn record_decode(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.decode_ns.add(ns);
        self.last_decode_ns.set(ns);
    }

    pub fn record_store(&self, dur: std::time::Duration) {
        let ns = dur.as_nanos() as u64;
        self.store_ns.add(ns);
        self.last_store_ns.set(ns);
    }

    /// Cumulative `(idle, prefill, encode, decode, store)` fractions; all
    /// zeros before the first iteration.  The denominator is the four
    /// top-level phases — `encode` is prefill's kernel sub-slice, so the
    /// first, second, fourth and fifth components sum to 1 and
    /// `encode <= prefill`.
    pub fn split(&self) -> (f64, f64, f64, f64, f64) {
        let (i, p, e, d, s) = (
            self.idle_ns.get() as f64,
            self.prefill_ns.get() as f64,
            self.encode_ns.get() as f64,
            self.decode_ns.get() as f64,
            self.store_ns.get() as f64,
        );
        let total = i + p + d + s;
        if total <= 0.0 {
            return (0.0, 0.0, 0.0, 0.0, 0.0);
        }
        (i / total, p / total, e / total, d / total, s / total)
    }
}

/// Serving metrics bundle (one per serve-pool worker).
#[derive(Default)]
pub struct ServeMetrics {
    pub queue_wait: Histogram,
    pub prefill_latency: Histogram,
    pub decode_step_latency: Histogram,
    pub request_latency: Histogram,
    /// Time-to-first-token: request arrival at the worker to the first
    /// `Token` event (end of prefill) — the streaming API's headline
    /// latency.
    pub ttft: Histogram,
    /// TTFT split by scheduling class: the chunked-prefill scheduler's
    /// whole point is that interactive TTFT stays low while batch prefill
    /// is mid-flight.
    pub ttft_interactive: Histogram,
    pub ttft_batch: Histogram,
    /// Prefill chunks completed (a long prompt at `--prefill-chunk 512`
    /// contributes ceil(prompt/512); every boundary was a yield point).
    pub prefill_chunks: Counter,
    /// Chunks where an interactive request's prefill ran while batch
    /// prefill work was pending (the batch chunk was deferred).
    pub prefill_preemptions: Counter,
    /// Current prefill backlog: prompt tokens still un-prefilled across
    /// this worker's queue (instantaneous; router TTFT-SLO input).
    pub prefill_backlog_tokens: Level,
    pub tokens_out: Counter,
    pub requests_done: Counter,
    pub requests_rejected: Counter,
    /// Requests cancelled mid-flight (explicit `Inbound::Cancel` or a
    /// disconnected event stream): their lane and cache reservation were
    /// reclaimed before `max_new` was exhausted.
    pub requests_cancelled: Counter,
    /// Sessions evicted from this worker's bounded session table (LRU
    /// capacity or idle TTL); each surfaced a `session_evicted` failure to
    /// its next turn.
    pub sessions_evicted: Counter,
    /// Live-session token counts published for the router's reservation
    /// estimate (see [`SessionTokens`]).
    pub session_tokens: SessionTokens,
    /// Cache-budget accounting: bytes reserved / released by this shard's
    /// `CacheManager` (in_use = reserved - released, cached radix blocks
    /// included) and the shard's peak.
    pub cache_reserved_bytes: Counter,
    pub cache_released_bytes: Counter,
    pub cache_peak_bytes: Gauge,
    /// Prefix sharing: prompt tokens looked up vs served from cached
    /// blocks (quantize+store skipped for exactly the hit span).
    pub prefix_lookup_tokens: Counter,
    pub prefix_hit_tokens: Counter,
    /// Prompt tokens whose prefill **compute** was skipped entirely
    /// (radix-hit prefix: chunked prefill starts past them, so zero
    /// centroid assignments run).  A fully-hit prompt contributes its
    /// whole length here — the radix compute-skip acceptance probe.
    pub prefill_tokens_skipped: Counter,
    /// Encode tasks dispatched by the most recent pooled prefill encode
    /// (instantaneous fan-out width; 0 until the first CQ chunk).
    pub encode_pool_busy: Level,
    /// Worker threads owned by this worker's persistent encode pool; set
    /// at pool construction, zeroed by the pool's exit hook once every
    /// thread is joined — chaos tests read 0 here as proof that pool
    /// threads never outlive a retired worker.
    pub encode_pool_threads: Level,
    /// Block-pool lifecycle: blocks promoted into the radix index at
    /// completion and blocks reclaimed by LRU eviction.
    pub blocks_promoted: Counter,
    pub blocks_evicted: Counter,
    /// Peak internal fragmentation (allocated page bytes not covered by
    /// written token records).
    pub cache_frag_bytes: Gauge,
    /// Shard geometry, published once the worker's context is built (the
    /// router's pool-wide admission estimate reads these).
    pub bytes_per_token: Gauge,
    pub block_bytes: Gauge,
    /// Largest prompt the worker's prefill buckets accept (prompts are
    /// trimmed to this before reservation).
    pub max_prompt_tokens: Gauge,
    /// fp16 bytes per token for this worker's geometry, published with the
    /// context; the router prices fp16-policy reservations from it.
    pub fp16_bytes_per_token: Gauge,
    /// Tokens currently fp-resident in retention pens (sinks + windows)
    /// across this worker's active sequences — window occupancy,
    /// republished every scheduler iteration.
    pub window_tokens: Level,
    /// Tokens quantized-on-retire into pool blocks as they aged past their
    /// policy's window (cumulative).
    pub window_retired_tokens: Counter,
    /// Per-policy resident cache bytes (see [`PolicyBytes`]).
    pub policy_bytes: PolicyBytes,
    /// Serve-loop wall-clock split across idle/prefill/decode/store (the
    /// "where did the iteration go" breakdown; see [`PhaseMetrics`]).
    pub phases: PhaseMetrics,
    /// Per-request flight recorder: bounded ring of terminal
    /// [`trace::RequestTrace`]s plus the live in-flight set (see
    /// [`trace::TraceRecorder`]).
    pub trace: trace::TraceRecorder,
}

impl ServeMetrics {
    /// Cache bytes currently reserved on this shard (active reservations +
    /// radix-cached blocks).
    pub fn cache_bytes_in_use(&self) -> u64 {
        self.cache_reserved_bytes
            .get()
            .saturating_sub(self.cache_released_bytes.get())
    }

    /// Bytes held by radix-cached prefix blocks on this shard.
    pub fn cache_cached_bytes(&self) -> u64 {
        self.blocks_promoted
            .get()
            .saturating_sub(self.blocks_evicted.get())
            * self.block_bytes.get()
    }

    /// Fraction of looked-up prompt tokens served from cached blocks.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookup_tokens.get();
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hit_tokens.get() as f64 / lookups as f64
    }

    pub fn summary(&self, wall_secs: f64) -> String {
        let (idle, prefill, encode, decode, store) = self.phases.split();
        format!(
            "requests={} rejected={} cancelled={} sessions_evicted={} tokens={} tput={:.1} tok/s  ttft p50={:.1}ms (int p50={:.1}ms batch p50={:.1}ms)  prefill_chunks={} preempts={}  decode p50={:.2}ms p95={:.2}ms  e2e p50={:.1}ms p95={:.1}ms p99={:.1}ms  cache peak={}B  prefix hit={:.0}% skipped={} evicted={} frag={}B  loop[idle={:.0}% prefill={:.0}% (encode={:.0}%) decode={:.0}% store={:.0}%]",
            self.requests_done.get(),
            self.requests_rejected.get(),
            self.requests_cancelled.get(),
            self.sessions_evicted.get(),
            self.tokens_out.get(),
            self.tokens_out.get() as f64 / wall_secs.max(1e-9),
            self.ttft.percentile_ms(0.5),
            self.ttft_interactive.percentile_ms(0.5),
            self.ttft_batch.percentile_ms(0.5),
            self.prefill_chunks.get(),
            self.prefill_preemptions.get(),
            self.decode_step_latency.percentile_ms(0.5),
            self.decode_step_latency.percentile_ms(0.95),
            self.request_latency.percentile_ms(0.5),
            self.request_latency.percentile_ms(0.95),
            self.request_latency.p99(),
            self.cache_peak_bytes.get(),
            self.prefix_hit_rate() * 100.0,
            self.prefill_tokens_skipped.get(),
            self.blocks_evicted.get(),
            self.cache_frag_bytes.get(),
            idle * 100.0,
            prefill * 100.0,
            encode * 100.0,
            decode * 100.0,
            store * 100.0,
        )
    }
}

/// Pool-level telemetry: per-worker [`ServeMetrics`] plus aggregation.
///
/// Counters aggregate by summation; latency histograms merge bucket-wise so
/// pool percentiles weight every worker's samples equally.  The pool "peak"
/// is the sum of per-shard peaks — an upper bound on the true simultaneous
/// peak (shards peak independently).
pub struct PoolMetrics {
    workers: Vec<Arc<ServeMetrics>>,
    /// Requests refused by the router's pool-wide admission control before
    /// reaching any worker.
    pub router_rejected: Counter,
    /// Workers that died uncleanly (panic or startup/loop error) and were
    /// taken out of rotation by the supervisor.
    pub workers_dead: Counter,
    /// Queued (not-yet-admitted) requests the supervisor speculatively
    /// re-dispatched to a live worker after their worker died.
    pub requests_redispatched: Counter,
    /// Open frontend connections (reactor gauge).
    pub conns_open: Level,
    /// Connections whose read interest is currently withdrawn by the
    /// reactor's backpressure (outbound queue above half its cap, or too
    /// many in-flight subscriptions).
    pub conns_read_paused: Level,
    /// Live broadcast subscriptions across all in-flight generations
    /// (primary streams + watchers).
    pub fanout_subscribers: Level,
    /// Outbound frames discarded by the `drop-oldest` client buffer
    /// policy (slow readers).
    pub frames_dropped: Counter,
    /// Connections closed by the `disconnect` client buffer policy (slow
    /// readers).
    pub conns_dropped_slow: Counter,
    /// Transient `accept()` errors (EINTR/ECONNABORTED/fd pressure) the
    /// reactor survived instead of tearing down the frontend.
    pub accept_transient_errors: Counter,
}

impl PoolMetrics {
    pub fn new(workers: Vec<Arc<ServeMetrics>>) -> PoolMetrics {
        assert!(!workers.is_empty(), "pool needs at least one worker");
        PoolMetrics {
            workers,
            router_rejected: Counter::default(),
            workers_dead: Counter::default(),
            requests_redispatched: Counter::default(),
            conns_open: Level::default(),
            conns_read_paused: Level::default(),
            fanout_subscribers: Level::default(),
            frames_dropped: Counter::default(),
            conns_dropped_slow: Counter::default(),
            accept_transient_errors: Counter::default(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn worker(&self, i: usize) -> &ServeMetrics {
        &self.workers[i]
    }

    pub fn workers(&self) -> &[Arc<ServeMetrics>] {
        &self.workers
    }

    fn sum(&self, f: impl Fn(&ServeMetrics) -> u64) -> u64 {
        self.workers.iter().map(|m| f(m)).sum()
    }

    pub fn tokens_out(&self) -> u64 {
        self.sum(|m| m.tokens_out.get())
    }

    pub fn requests_done(&self) -> u64 {
        self.sum(|m| m.requests_done.get())
    }

    /// Worker-side (shard budget) rejections plus router-side (pool-wide
    /// admission control) rejections.
    pub fn requests_rejected(&self) -> u64 {
        self.sum(|m| m.requests_rejected.get()) + self.router_rejected.get()
    }

    /// Requests cancelled mid-flight across all workers.
    pub fn requests_cancelled(&self) -> u64 {
        self.sum(|m| m.requests_cancelled.get())
    }

    /// Sessions evicted (LRU/TTL) across all workers.
    pub fn sessions_evicted(&self) -> u64 {
        self.sum(|m| m.sessions_evicted.get())
    }

    /// Prompt tokens whose prefill compute was skipped via radix hits,
    /// across all workers.
    pub fn prefill_tokens_skipped(&self) -> u64 {
        self.sum(|m| m.prefill_tokens_skipped.get())
    }

    pub fn cache_bytes_reserved(&self) -> u64 {
        self.sum(|m| m.cache_reserved_bytes.get())
    }

    pub fn cache_bytes_in_use(&self) -> u64 {
        self.sum(|m| m.cache_bytes_in_use())
    }

    pub fn cache_peak_bytes(&self) -> u64 {
        self.sum(|m| m.cache_peak_bytes.get())
    }

    /// Bytes held by radix-cached prefixes across all shards.
    pub fn cache_cached_bytes(&self) -> u64 {
        self.sum(|m| m.cache_cached_bytes())
    }

    pub fn prefix_hit_tokens(&self) -> u64 {
        self.sum(|m| m.prefix_hit_tokens.get())
    }

    pub fn prefix_lookup_tokens(&self) -> u64 {
        self.sum(|m| m.prefix_lookup_tokens.get())
    }

    /// Pool-wide prefix hit rate (token-weighted across shards).
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookup_tokens();
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hit_tokens() as f64 / lookups as f64
    }

    pub fn blocks_evicted(&self) -> u64 {
        self.sum(|m| m.blocks_evicted.get())
    }

    /// Largest per-shard fragmentation peak (shards don't share pages, so
    /// summing would overstate waste on any single allocator).
    pub fn cache_frag_bytes(&self) -> u64 {
        self.workers
            .iter()
            .map(|m| m.cache_frag_bytes.get())
            .max()
            .unwrap_or(0)
    }

    /// Packed bytes per token as published by the first worker that built
    /// its context (0 until then).  All shards share one geometry.
    pub fn bytes_per_token(&self) -> u64 {
        self.workers
            .iter()
            .map(|m| m.bytes_per_token.get())
            .max()
            .unwrap_or(0)
    }

    /// Prefill prompt ceiling as published by the workers (0 until built).
    pub fn max_prompt_tokens(&self) -> u64 {
        self.workers
            .iter()
            .map(|m| m.max_prompt_tokens.get())
            .max()
            .unwrap_or(0)
    }

    /// fp16 bytes per token as published by the workers (0 until built).
    /// All shards share one geometry, like [`Self::bytes_per_token`].
    pub fn fp16_bytes_per_token(&self) -> u64 {
        self.workers
            .iter()
            .map(|m| m.fp16_bytes_per_token.get())
            .max()
            .unwrap_or(0)
    }

    /// Pool-wide window occupancy: fp-resident retention-pen tokens summed
    /// across workers (each shard's level is independent).
    pub fn window_tokens(&self) -> u64 {
        self.sum(|m| m.window_tokens.get())
    }

    /// Tokens quantized-on-retire across all workers.
    pub fn window_retired_tokens(&self) -> u64 {
        self.sum(|m| m.window_retired_tokens.get())
    }

    /// Per-policy resident bytes merged across workers (name-wise sums).
    pub fn policy_bytes(&self) -> Vec<(String, u64)> {
        let mut merged: BTreeMap<String, u64> = BTreeMap::new();
        for m in &self.workers {
            for (name, bytes) in m.policy_bytes.snapshot() {
                *merged.entry(name).or_insert(0) += bytes;
            }
        }
        merged.into_iter().collect()
    }

    /// All workers' decode-step latencies merged into one histogram.
    pub fn merged_decode_latency(&self) -> Histogram {
        let h = Histogram::new();
        for m in &self.workers {
            h.merge_from(&m.decode_step_latency);
        }
        h
    }

    /// All workers' end-to-end request latencies merged into one histogram.
    pub fn merged_request_latency(&self) -> Histogram {
        let h = Histogram::new();
        for m in &self.workers {
            h.merge_from(&m.request_latency);
        }
        h
    }

    /// All workers' time-to-first-token samples merged into one histogram.
    pub fn merged_ttft(&self) -> Histogram {
        let h = Histogram::new();
        for m in &self.workers {
            h.merge_from(&m.ttft);
        }
        h
    }

    /// Interactive-class TTFT merged across workers.
    pub fn merged_ttft_interactive(&self) -> Histogram {
        let h = Histogram::new();
        for m in &self.workers {
            h.merge_from(&m.ttft_interactive);
        }
        h
    }

    /// Batch-class TTFT merged across workers.
    pub fn merged_ttft_batch(&self) -> Histogram {
        let h = Histogram::new();
        for m in &self.workers {
            h.merge_from(&m.ttft_batch);
        }
        h
    }

    /// Prefill chunks completed across all workers.
    pub fn prefill_chunks(&self) -> u64 {
        self.sum(|m| m.prefill_chunks.get())
    }

    /// Interactive-over-batch prefill preemptions across all workers.
    pub fn prefill_preemptions(&self) -> u64 {
        self.sum(|m| m.prefill_preemptions.get())
    }

    /// Pool summary line followed by one indented line per worker.
    pub fn summary(&self, wall_secs: f64) -> String {
        let decode = self.merged_decode_latency();
        let e2e = self.merged_request_latency();
        let mut s = format!(
            "pool[{}w]: requests={} rejected={} cancelled={} dead_workers={} redispatched={} sessions_evicted={} tokens={} tput={:.1} tok/s  ttft p50={:.1}ms (int p95={:.1}ms)  prefill_chunks={} preempts={}  decode p50={:.2}ms  e2e p95={:.1}ms p99={:.1}ms  cache in_use={}B peak<={}B  prefix hit={:.0}% cached={}B evicted={}",
            self.n_workers(),
            self.requests_done(),
            self.requests_rejected(),
            self.requests_cancelled(),
            self.workers_dead.get(),
            self.requests_redispatched.get(),
            self.sessions_evicted(),
            self.tokens_out(),
            self.tokens_out() as f64 / wall_secs.max(1e-9),
            self.merged_ttft().percentile_ms(0.5),
            self.merged_ttft_interactive().percentile_ms(0.95),
            self.prefill_chunks(),
            self.prefill_preemptions(),
            decode.percentile_ms(0.5),
            e2e.percentile_ms(0.95),
            e2e.p99(),
            self.cache_bytes_in_use(),
            self.cache_peak_bytes(),
            self.prefix_hit_rate() * 100.0,
            self.cache_cached_bytes(),
            self.blocks_evicted(),
        );
        for (i, m) in self.workers.iter().enumerate() {
            s.push_str(&format!("\n  worker {i}: {}", m.summary(wall_secs)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_records_and_reports() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ms() > 20.0 && h.mean_ms() < 30.0);
        let p50 = h.percentile_ms(0.5);
        assert!(p50 >= 2.0 && p50 <= 8.2, "p50={p50}");
        assert!(h.percentile_ms(1.0) >= 100.0);
    }

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn histogram_merge_adds_counts_and_preserves_percentiles() {
        let a = Histogram::new();
        let b = Histogram::new();
        for ms in [1u64, 2, 4] {
            a.record(Duration::from_millis(ms));
        }
        for ms in [8u64, 100] {
            b.record(Duration::from_millis(ms));
        }
        let merged = Histogram::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        assert_eq!(merged.count(), 5);
        assert!(merged.mean_ms() > 20.0 && merged.mean_ms() < 30.0);
        assert!(merged.percentile_ms(1.0) >= 100.0);
    }

    /// What the old pure-doubling layout reported for a sample of `us`
    /// microseconds: the bucket upper bound `1 << (64 - us.leading_zeros())`.
    fn old_upper_bound_ms(us: u64) -> f64 {
        assert!(us >= 1);
        (1u64 << (64 - us.leading_zeros())) as f64 / 1e3
    }

    #[test]
    fn histogram_midpoints_tighter_than_old_upper_bounds() {
        // The headline fix: a lone 1 ms sample must report ~1 ms, not the
        // old 2.048 ms upper bound.
        let lone = Histogram::new();
        lone.record(Duration::from_millis(1));
        let p50 = lone.percentile_ms(0.5);
        assert!((p50 - 1.0).abs() <= 0.125, "lone 1ms reports {p50}ms");
        // Midpoint reporting stays within ±12.5% of the true value and
        // never exceeds the old estimate, across several octaves.
        for us in [1u64, 3, 17, 500, 1000, 12_345, 100_000, 7_000_000] {
            let h = Histogram::new();
            h.record(Duration::from_micros(us));
            let est = h.percentile_ms(0.5);
            let truth = us as f64 / 1e3;
            assert!(
                (est - truth).abs() <= truth * 0.125 + 1e-9,
                "us={us}: est={est} truth={truth}"
            );
            assert!(
                est <= old_upper_bound_ms(us) + 1e-9,
                "us={us}: new {est} > old {}",
                old_upper_bound_ms(us)
            );
        }
    }

    #[test]
    fn histogram_percentiles_monotone_in_p() {
        let h = Histogram::new();
        for us in [1u64, 5, 9, 40, 900, 1000, 2000, 15_000, 80_000, 1_000_000] {
            h.record(Duration::from_micros(us));
        }
        let mut prev = 0.0;
        for i in 1..=20 {
            let p = i as f64 / 20.0;
            let v = h.percentile_ms(p);
            assert!(v >= prev, "p={p}: {v} < {prev}");
            prev = v;
        }
        assert_eq!(h.p99(), h.percentile_ms(0.99));
    }

    #[test]
    fn histogram_bucket_bounds_tile_the_axis() {
        // Buckets must tile [0, ∞) without gaps or overlap: every bucket's
        // upper bound is the next bucket's lower bound, the midpoint sits
        // strictly inside, and bucket_of lands samples inside their bounds.
        for i in 0..NUM_BUCKETS - 1 {
            let (lo, hi) = (Histogram::bucket_lower_us(i), Histogram::bucket_upper_us(i));
            assert_eq!(hi, Histogram::bucket_lower_us(i + 1), "bucket {i}");
            let mid = Histogram::bucket_midpoint_us(i);
            assert!(lo < mid && mid < hi, "bucket {i}: {lo} {mid} {hi}");
        }
        for us in [0u64, 1, 2, 3, 7, 1023, 1024, 65_535, 1 << 30] {
            let i = Histogram::bucket_of(us * 1000);
            assert!(
                (us as f64) >= Histogram::bucket_lower_us(i)
                    && (us as f64) < Histogram::bucket_upper_us(i),
                "us={us} bucket {i}"
            );
        }
    }

    #[test]
    fn session_tokens_survive_mutex_poisoning() {
        // A worker panicking while holding the directory lock (what the
        // chaos harness induces) must not cascade panics into later
        // supervisor reads.
        let st = Arc::new(SessionTokens::default());
        st.publish(1, 10);
        let st2 = st.clone();
        let joined = std::thread::spawn(move || {
            let _guard = st2.0.lock().unwrap();
            panic!("poison the session directory");
        })
        .join();
        assert!(joined.is_err(), "the poisoning thread must panic");
        assert_eq!(st.get(1), Some(10), "reads recover past the poison");
        st.publish(2, 20);
        assert_eq!(st.live_sessions(), 2);
        st.forget(1);
        assert_eq!(st.get(1), None);
    }

    #[test]
    fn phase_metrics_split_and_levels() {
        let ph = PhaseMetrics::default();
        assert_eq!(ph.split(), (0.0, 0.0, 0.0, 0.0, 0.0), "empty split is zeros");
        ph.record_idle(Duration::from_micros(400));
        ph.record_prefill(Duration::from_micros(300));
        ph.record_encode(Duration::from_micros(150));
        ph.record_decode(Duration::from_micros(200));
        ph.record_store(Duration::from_micros(100));
        ph.iterations.add(1);
        let (i, p, e, d, s) = ph.split();
        assert!((i - 0.4).abs() < 1e-9 && (p - 0.3).abs() < 1e-9);
        assert!((d - 0.2).abs() < 1e-9 && (s - 0.1).abs() < 1e-9);
        // Encode is prefill's sub-slice over the same denominator: it does
        // not inflate the top-level total and never exceeds prefill.
        assert!((e - 0.15).abs() < 1e-9);
        assert!((i + p + d + s - 1.0).abs() < 1e-9, "encode excluded from the total");
        assert!(e <= p);
        // Levels hold the last iteration's value, counters accumulate.
        ph.record_decode(Duration::from_micros(600));
        assert_eq!(ph.last_decode_ns.get(), 600_000);
        assert_eq!(ph.decode_ns.get(), 800_000);
        ph.record_encode(Duration::from_micros(50));
        assert_eq!(ph.last_encode_ns.get(), 50_000);
        assert_eq!(ph.encode_ns.get(), 200_000);
        let m = ServeMetrics::default();
        m.phases.record_idle(Duration::from_micros(10));
        assert!(m.summary(1.0).contains("loop[idle=100%"));
        assert!(m.summary(1.0).contains("(encode=0%)"));
    }

    #[test]
    fn gauge_tracks_high_watermark() {
        let g = Gauge::default();
        g.observe_max(10);
        g.observe_max(3);
        assert_eq!(g.get(), 10);
        g.observe_max(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn pool_metrics_aggregate_worker_shards() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.tokens_out.add(10);
        w1.tokens_out.add(5);
        w0.requests_done.add(2);
        w1.requests_rejected.add(1);
        w0.cache_reserved_bytes.add(100);
        w0.cache_released_bytes.add(40);
        w0.cache_peak_bytes.observe_max(100);
        w1.cache_reserved_bytes.add(30);
        w1.cache_peak_bytes.observe_max(30);
        w0.decode_step_latency.record(Duration::from_millis(2));
        w1.decode_step_latency.record(Duration::from_millis(4));

        let pool = PoolMetrics::new(vec![w0.clone(), w1.clone()]);
        assert_eq!(pool.n_workers(), 2);
        assert_eq!(pool.tokens_out(), 15);
        assert_eq!(pool.requests_done(), 2);
        assert_eq!(pool.requests_rejected(), 1);
        // Per-shard accounting sums to pool totals.
        assert_eq!(
            pool.cache_bytes_in_use(),
            w0.cache_bytes_in_use() + w1.cache_bytes_in_use()
        );
        assert_eq!(pool.cache_bytes_in_use(), 90);
        assert_eq!(pool.cache_peak_bytes(), 130);
        assert_eq!(pool.merged_decode_latency().count(), 2);
        let s = pool.summary(1.0);
        assert!(s.contains("pool[2w]"), "{s}");
        assert!(s.contains("worker 1"), "{s}");
    }

    #[test]
    fn prefix_and_eviction_counters_aggregate() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        for w in [&w0, &w1] {
            w.block_bytes.observe_max(64);
            w.bytes_per_token.observe_max(4);
        }
        w0.prefix_lookup_tokens.add(100);
        w0.prefix_hit_tokens.add(75);
        w1.prefix_lookup_tokens.add(100);
        w1.prefix_hit_tokens.add(25);
        w0.blocks_promoted.add(10);
        w0.blocks_evicted.add(4);
        assert_eq!(w0.cache_cached_bytes(), 6 * 64);
        assert!((w0.prefix_hit_rate() - 0.75).abs() < 1e-12);

        let pool = PoolMetrics::new(vec![w0.clone(), w1.clone()]);
        assert_eq!(pool.prefix_hit_tokens(), 100);
        assert!((pool.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(pool.blocks_evicted(), 4);
        assert_eq!(pool.cache_cached_bytes(), 6 * 64);
        assert_eq!(pool.bytes_per_token(), 4);
        // Router rejections count toward the pool total.
        w0.requests_rejected.add(1);
        pool.router_rejected.add(2);
        assert_eq!(pool.requests_rejected(), 3);
        let s = pool.summary(1.0);
        assert!(s.contains("prefix hit"), "{s}");
    }

    #[test]
    fn cancelled_and_ttft_aggregate_across_workers() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.requests_cancelled.add(2);
        w1.requests_cancelled.add(1);
        w0.ttft.record(Duration::from_millis(4));
        w1.ttft.record(Duration::from_millis(20));
        let pool = PoolMetrics::new(vec![w0.clone(), w1]);
        assert_eq!(pool.requests_cancelled(), 3);
        assert_eq!(pool.merged_ttft().count(), 2);
        assert!(pool.merged_ttft().percentile_ms(1.0) >= 16.0);
        let s = pool.summary(1.0);
        assert!(s.contains("cancelled=3"), "{s}");
        assert!(s.contains("ttft"), "{s}");
        assert!(w0.summary(1.0).contains("cancelled=2"));
    }

    #[test]
    fn fault_and_session_counters_aggregate() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.sessions_evicted.add(2);
        w1.sessions_evicted.add(1);
        w0.session_tokens.publish(7, 120);
        assert_eq!(w0.session_tokens.get(7), Some(120));
        assert_eq!(w0.session_tokens.live_sessions(), 1);
        w0.session_tokens.forget(7);
        assert_eq!(w0.session_tokens.get(7), None);

        let pool = PoolMetrics::new(vec![w0.clone(), w1]);
        assert_eq!(pool.sessions_evicted(), 3);
        pool.workers_dead.add(1);
        pool.requests_redispatched.add(4);
        let s = pool.summary(1.0);
        assert!(s.contains("dead_workers=1"), "{s}");
        assert!(s.contains("redispatched=4"), "{s}");
        assert!(s.contains("sessions_evicted=3"), "{s}");
        assert!(w0.summary(1.0).contains("sessions_evicted=2"));
    }

    #[test]
    fn policy_bytes_ledger_settles_and_aggregates() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.policy_bytes.add("cq-8c10b", 100);
        w0.policy_bytes.add("fp16", 400);
        w0.policy_bytes.add("cq-8c10b", 50);
        w1.policy_bytes.add("fp16", 600);
        assert_eq!(w0.policy_bytes.get("cq-8c10b"), 150);
        assert_eq!(w0.policy_bytes.total(), 550);
        // Teardown settles; underflow clamps; unknown names are no-ops.
        w0.policy_bytes.sub("cq-8c10b", 150);
        w0.policy_bytes.sub("cq-8c10b", 7);
        w0.policy_bytes.sub("never-admitted", 3);
        assert_eq!(w0.policy_bytes.get("cq-8c10b"), 0);
        assert_eq!(
            w0.policy_bytes.snapshot(),
            vec![("cq-8c10b".to_string(), 0), ("fp16".to_string(), 400)],
            "settled policies stay listed at 0"
        );
        let pool = PoolMetrics::new(vec![w0, w1]);
        assert_eq!(
            pool.policy_bytes(),
            vec![("cq-8c10b".to_string(), 0), ("fp16".to_string(), 1000)],
            "pool merge sums name-wise across workers"
        );
    }

    #[test]
    fn window_observables_aggregate_across_workers() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.window_tokens.set(6);
        w1.window_tokens.set(10);
        w0.window_retired_tokens.add(40);
        w1.window_retired_tokens.add(2);
        w0.fp16_bytes_per_token.observe_max(4096);
        let pool = PoolMetrics::new(vec![w0, w1]);
        assert_eq!(pool.window_tokens(), 16);
        assert_eq!(pool.window_retired_tokens(), 42);
        assert_eq!(pool.fp16_bytes_per_token(), 4096);
    }

    #[test]
    fn level_gauge_is_instantaneous() {
        let l = Level::default();
        assert_eq!(l.get(), 0);
        l.set(512);
        assert_eq!(l.get(), 512);
        l.set(64);
        assert_eq!(l.get(), 64, "levels fall as the backlog drains");
    }

    #[test]
    fn prefill_chunk_and_priority_ttft_aggregate() {
        let w0 = Arc::new(ServeMetrics::default());
        let w1 = Arc::new(ServeMetrics::default());
        w0.prefill_chunks.add(5);
        w1.prefill_chunks.add(3);
        w0.prefill_preemptions.add(2);
        w0.ttft_interactive.record(Duration::from_millis(2));
        w1.ttft_interactive.record(Duration::from_millis(8));
        w0.ttft_batch.record(Duration::from_millis(80));
        w0.prefill_backlog_tokens.set(1024);

        let pool = PoolMetrics::new(vec![w0.clone(), w1]);
        assert_eq!(pool.prefill_chunks(), 8);
        assert_eq!(pool.prefill_preemptions(), 2);
        assert_eq!(pool.merged_ttft_interactive().count(), 2);
        assert_eq!(pool.merged_ttft_batch().count(), 1);
        assert!(pool.merged_ttft_batch().percentile_ms(1.0) >= 64.0);
        let s = pool.summary(1.0);
        assert!(s.contains("prefill_chunks=8"), "{s}");
        assert!(s.contains("preempts=2"), "{s}");
        assert!(w0.summary(1.0).contains("prefill_chunks=5"));
    }

    #[test]
    fn traffic_model_matches_paper_ratios() {
        let fp = TrafficModel { n_layers: 4, n_heads: 4, head_dim: 64, bits_per_fpn: 16.0 };
        let cq1 = TrafficModel { bits_per_fpn: 1.0, ..fp };
        // 16x traffic reduction at 1 bit/FPN.
        assert!((fp.bytes_per_decode(512) / cq1.bytes_per_decode(512) - 16.0).abs() < 1e-9);
        assert!((cq1.speedup_vs_fp16() - 16.0).abs() < 1e-9);
        // Absolute check: fp16, T=512: 2*4*4*64*512 fpns * 2 bytes = 2 MiB.
        assert_eq!(fp.bytes_per_decode(512) as usize, 2 * 4 * 4 * 64 * 512 * 2);
    }
}

//! Uniform integer (INT-b) quantization baseline.
//!
//! Matches the INT rows of the paper's Tables 1–2: asymmetric uniform
//! quantization with keys quantized per-channel and values per-token
//! (§2.3), either ungrouped (one scale/zero per channel/token) or with
//! group size 128 along the reduction axis (`-gs128`, +0.25 bits/FPN from
//! the fp16 scale+zero pair per 128 values).

use super::{gather_channel, scatter_channel, Codec, KvDims, KvKind};
use crate::tensor::TensorF;

pub struct IntQ {
    pub bits: u32,
    /// Group size along the reduction axis; `None` = whole axis.
    pub group: Option<usize>,
}

impl IntQ {
    pub fn new(bits: u32, group: Option<usize>) -> IntQ {
        IntQ { bits, group }
    }
}

/// Asymmetric uniform quantize-dequantize of one slice in place.
pub fn uniform_qdq(xs: &mut [f32], bits: u32) {
    let levels = (1u32 << bits) as f32 - 1.0;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs.iter() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || hi <= lo {
        return; // constant or empty slice: exact at any width
    }
    let scale = (hi - lo) / levels;
    for x in xs.iter_mut() {
        let q = ((*x - lo) / scale).round().clamp(0.0, levels);
        *x = lo + q * scale;
    }
}

/// Apply a per-slice transform over groups of `group` elements.
pub fn grouped<F: FnMut(&mut [f32])>(xs: &mut [f32], group: Option<usize>, mut f: F) {
    match group {
        None => f(xs),
        Some(g) => {
            for chunk in xs.chunks_mut(g) {
                f(chunk);
            }
        }
    }
}

impl Codec for IntQ {
    fn name(&self) -> String {
        match self.group {
            None => format!("INT{}", self.bits),
            Some(g) => format!("INT{}-gs{}", self.bits, g),
        }
    }

    fn bits_per_fpn(&self) -> f64 {
        // scale + zero-point as two fp16 per group / per vector.  Ungrouped
        // variants amortize over the whole reduction axis (the paper's
        // "4.00-4.01" rows); gs128 adds exactly 32/128 = 0.25.
        match self.group {
            Some(g) => self.bits as f64 + 32.0 / g as f64,
            None => self.bits as f64,
        }
    }

    fn apply(&self, kind: KvKind, a: &mut TensorF) {
        let d = KvDims::of(a);
        match kind {
            // Keys: per-channel — quantize each channel's token series.
            KvKind::Key => {
                for l in 0..d.l {
                    for h in 0..d.h {
                        for ch in 0..d.hd {
                            let mut vals = gather_channel(a, l, h, ch);
                            grouped(&mut vals, self.group, |s| uniform_qdq(s, self.bits));
                            scatter_channel(a, l, h, ch, &vals);
                        }
                    }
                }
            }
            // Values: per-token — quantize each token's channel vector.
            KvKind::Value => {
                for l in 0..d.l {
                    for h in 0..d.h {
                        super::for_each_vec(a, l, h, |_, vec| {
                            grouped(vec, self.group, |s| uniform_qdq(s, self.bits));
                        });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Pcg64;

    fn randn_tensor(shape: &[usize], seed: u64) -> TensorF {
        let mut rng = Pcg64::seed(seed);
        let n = crate::tensor::numel(shape);
        TensorF::from_vec(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
    }

    #[test]
    fn uniform_qdq_endpoints_exact() {
        let mut xs = vec![-1.0f32, 0.0, 0.5, 1.0];
        uniform_qdq(&mut xs, 2);
        assert_eq!(xs[0], -1.0);
        assert_eq!(xs[3], 1.0);
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut a = randn_tensor(&[1, 1, 1, 64, 8], 1);
        let orig = a.clone();
        IntQ::new(8, None).apply(KvKind::Key, &mut a);
        let mse = a.sqdiff(&orig) / a.numel() as f64;
        assert!(mse < 1e-3, "mse={mse}");
    }

    #[test]
    fn int2_is_very_lossy() {
        let mut a = randn_tensor(&[1, 1, 2, 64, 8], 2);
        let orig = a.clone();
        IntQ::new(2, None).apply(KvKind::Key, &mut a);
        let mse = a.sqdiff(&orig) / a.numel() as f64;
        assert!(mse > 0.01, "INT2 should be lossy, mse={mse}");
    }

    #[test]
    fn grouping_reduces_error() {
        // Channel with a scale shift halfway: grouping isolates the ranges.
        let mut vals: Vec<f32> = (0..256).map(|i| if i < 128 { i as f32 * 0.01 } else { 100.0 + i as f32 }).collect();
        let orig = vals.clone();
        let mut g128 = vals.clone();
        grouped(&mut vals, None, |s| uniform_qdq(s, 4));
        grouped(&mut g128, Some(128), |s| uniform_qdq(s, 4));
        let err = |a: &[f32]| -> f64 {
            a.iter().zip(&orig).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
        };
        assert!(err(&g128) < err(&vals) * 0.5);
    }

    #[test]
    fn value_axis_is_per_token() {
        // A tensor where one token is an extreme outlier: per-token
        // quantization must keep other tokens accurate.
        let mut a = randn_tensor(&[1, 1, 1, 8, 16], 3);
        for c in 0..16 {
            a.data[3 * 16 + c] = 1000.0;
        }
        let orig = a.clone();
        IntQ::new(4, None).apply(KvKind::Value, &mut a);
        // Token 0 error unaffected by token 3's scale.
        let tok0: f64 = (0..16)
            .map(|c| ((a.data[c] - orig.data[c]) as f64).powi(2))
            .sum();
        assert!(tok0 < 0.1, "tok0 err={tok0}");
    }

    #[test]
    fn prop_qdq_idempotent_and_bounded() {
        run_prop(25, 13, |rng| {
            let bits = 2 + rng.below(6) as u32;
            let n = 4 + rng.below(60);
            let mut xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 5.0).collect();
            let (lo, hi) = xs.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &x| {
                (a.min(x), b.max(x))
            });
            uniform_qdq(&mut xs, bits);
            let once = xs.clone();
            uniform_qdq(&mut xs, bits);
            if xs != once {
                return Err("not idempotent".into());
            }
            let step = (hi - lo) / ((1u32 << bits) as f32 - 1.0);
            for &x in &xs {
                if x < lo - step || x > hi + step {
                    return Err(format!("value {x} escaped range [{lo},{hi}]"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn names_and_bits() {
        assert_eq!(IntQ::new(4, None).name(), "INT4");
        assert_eq!(IntQ::new(4, Some(128)).name(), "INT4-gs128");
        assert!((IntQ::new(4, Some(128)).bits_per_fpn() - 4.25).abs() < 1e-9);
        assert_eq!(IntQ::new(2, None).bits_per_fpn(), 2.0);
    }
}

//! Bit-packing of quantization codes.
//!
//! The KV cache stores codes at their true width (1–10 bits each, LSB-first
//! within a little-endian bit stream), which is what makes the paper's
//! "1 bit per channel" footprint real on the Rust side: a CQ-8c8b cache of
//! `T` tokens × `G` groups occupies exactly `ceil(T*G*8 / 8)` bytes.
//!
//! Two kernel tiers share one wire format:
//!
//! * [`pack_into`] / [`unpack_into`] — the hot path: a `u64` accumulator
//!   moves whole words through the stream (one shift+mask per code, one
//!   store per byte) and bits ∈ {8, 16, 32} degrade to straight byte copies.
//!   Both write caller-owned buffers, so the paged cache's per-token
//!   append/readout allocates nothing in steady state.
//! * [`pack_codes_ref`] / [`unpack_codes_ref`] — the original bit-at-a-time
//!   loops, kept as the equivalence oracle for property tests and as the
//!   pre-PR baseline the `quant_hot_path` bench measures against.
//!
//! [`pack_codes`] / [`unpack_codes`] are allocating wrappers over the fast
//! kernels for callers that want owned buffers.

/// Pack `codes` (each `< 2^bits`) into an LSB-first bit stream.
pub fn pack_codes(codes: &[u32], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out);
    out
}

/// Unpack `n` codes of `bits` width from an LSB-first bit stream.
pub fn unpack_codes(bytes: &[u8], bits: u32, n: usize) -> Vec<u32> {
    let mut out = vec![0u32; n];
    unpack_into(bytes, bits, &mut out);
    out
}

/// Bytes needed to store `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Word-level pack: write the LSB-first stream of `codes` into `out`
/// (`out.len() == packed_len(codes.len(), bits)`).  Every output byte is
/// assigned (no read-modify-write), so `out` need not be zeroed.  Byte-
/// aligned widths (8/16/32) take straight little-endian copy fast paths.
pub fn pack_into(codes: &[u32], bits: u32, out: &mut [u8]) {
    assert!((1..=32).contains(&bits));
    assert_eq!(out.len(), packed_len(codes.len(), bits), "output size mismatch");
    match bits {
        8 => {
            for (o, &c) in out.iter_mut().zip(codes) {
                debug_assert!(c < 1 << 8, "code {c} exceeds 8 bits");
                *o = c as u8;
            }
        }
        16 => {
            for (o, &c) in out.chunks_exact_mut(2).zip(codes) {
                debug_assert!(c < 1 << 16, "code {c} exceeds 16 bits");
                o.copy_from_slice(&(c as u16).to_le_bytes());
            }
        }
        32 => {
            for (o, &c) in out.chunks_exact_mut(4).zip(codes) {
                o.copy_from_slice(&c.to_le_bytes());
            }
        }
        _ => {
            // Accumulate codes into a u64 window, flushing whole bytes:
            // fill stays < 8 after flushing, so fill + bits <= 7 + 31 < 64.
            // Masking keeps an out-of-range code from corrupting its
            // neighbors (the bit-loop reference truncated the same way).
            let mask: u64 = (1u64 << bits) - 1;
            let mut acc: u64 = 0;
            let mut fill: u32 = 0;
            let mut o = 0usize;
            for &c in codes {
                debug_assert!(c < (1u32 << bits), "code {c} exceeds {bits} bits");
                acc |= (c as u64 & mask) << fill;
                fill += bits;
                while fill >= 8 {
                    out[o] = acc as u8;
                    o += 1;
                    acc >>= 8;
                    fill -= 8;
                }
            }
            if fill > 0 {
                out[o] = acc as u8;
                o += 1;
            }
            debug_assert_eq!(o, out.len());
        }
    }
}

/// Word-level unpack: read `out.len()` codes of `bits` width from the
/// LSB-first stream in `bytes` into the caller's buffer.  Mirror of
/// [`pack_into`], with the same byte-aligned fast paths.
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u32]) {
    assert!((1..=32).contains(&bits));
    assert!(
        bytes.len() >= packed_len(out.len(), bits),
        "stream too short: {} bytes for {} codes of {bits} bits",
        bytes.len(),
        out.len()
    );
    match bits {
        8 => {
            for (o, &b) in out.iter_mut().zip(bytes) {
                *o = b as u32;
            }
        }
        16 => {
            for (o, ch) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = u16::from_le_bytes([ch[0], ch[1]]) as u32;
            }
        }
        32 => {
            for (o, ch) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                *o = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
        }
        _ => {
            let mask: u64 = (1u64 << bits) - 1;
            let mut acc: u64 = 0;
            let mut fill: u32 = 0;
            let mut i = 0usize;
            for o in out.iter_mut() {
                // fill < bits <= 31 before each refill byte lands at
                // position fill <= 30, so acc never overflows 64 bits.
                while fill < bits {
                    acc |= (bytes[i] as u64) << fill;
                    i += 1;
                    fill += 8;
                }
                *o = (acc & mask) as u32;
                acc >>= bits;
                fill -= bits;
            }
        }
    }
}

/// Reference bit-at-a-time pack (the pre-word-level implementation).  Not on
/// any hot path — property tests and the `quant_hot_path` bench use it as
/// the equivalence/speed baseline.
pub fn pack_codes_ref(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=32).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 32 || c < (1u32 << bits), "code {c} exceeds {bits} bits");
        let mut v = c as u64;
        let mut remaining = bits;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = remaining.min(8 - off);
            out[byte] |= (((v & ((1u64 << take) - 1)) as u8) << off) as u8;
            v >>= take;
            bitpos += take as usize;
            remaining -= take;
        }
    }
    out
}

/// Reference bit-at-a-time unpack — counterpart of [`pack_codes_ref`].
pub fn unpack_codes_ref(bytes: &[u8], bits: u32, n: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut v: u64 = 0;
        let mut got = 0u32;
        while got < bits {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = (bits - got).min(8 - off);
            let chunk = ((bytes[byte] >> off) & ((1u16 << take) - 1) as u8) as u64;
            v |= chunk << got;
            got += take;
            bitpos += take as usize;
        }
        out.push(v as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    #[test]
    fn roundtrip_small_widths() {
        for bits in [1u32, 2, 3, 4, 5, 7, 8, 10, 12] {
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..37u32).map(|i| i.wrapping_mul(2654435761) & max).collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.len(), packed_len(codes.len(), bits));
            let back = unpack_codes(&packed, bits, codes.len());
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn one_bit_density() {
        let codes = vec![1u32; 16];
        let packed = pack_codes(&codes, 1);
        assert_eq!(packed, vec![0xff, 0xff]);
    }

    #[test]
    fn ten_bit_crosses_byte_boundaries() {
        let codes = vec![0x3ffu32, 0, 0x2aa, 0x155];
        let packed = pack_codes(&codes, 10);
        assert_eq!(packed.len(), 5);
        assert_eq!(unpack_codes(&packed, 10, 4), codes);
    }

    #[test]
    fn prop_roundtrip_random() {
        run_prop(40, 11, |rng| {
            let bits = 1 + rng.below(12) as u32;
            let n = 1 + rng.below(200);
            let max = (1u64 << bits) as u32;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max as usize) as u32).collect();
            let back = unpack_codes(&pack_codes(&codes, bits), bits, n);
            if back == codes {
                Ok(())
            } else {
                Err(format!("mismatch at bits={bits} n={n}"))
            }
        });
    }

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 10), 4); // 30 bits -> 4 bytes
        assert_eq!(packed_len(4, 8), 4);
    }

    #[test]
    fn prop_roundtrip_bits_1_to_16_ragged_lengths() {
        // Every width the cache can be configured with (1..=16), at lengths
        // that land on and off byte boundaries, with packed_len consistency.
        run_prop(80, 17, |rng| {
            let bits = 1 + rng.below(16) as u32; // 1..=16
            let n = 1 + rng.below(257); // ragged: 1..=257 codes
            let max = 1u64 << bits;
            let codes: Vec<u32> =
                (0..n).map(|_| rng.below(max as usize) as u32).collect();
            let packed = pack_codes(&codes, bits);
            if packed.len() != packed_len(n, bits) {
                return Err(format!(
                    "packed_len mismatch: {} vs {} (bits={bits} n={n})",
                    packed.len(),
                    packed_len(n, bits)
                ));
            }
            let back = unpack_codes(&packed, bits, n);
            if back != codes {
                return Err(format!("roundtrip mismatch at bits={bits} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fast_kernels_match_bit_loop_reference() {
        // Word-level pack/unpack (including the 8/16-bit memcpy fast paths)
        // must produce the exact stream of the bit-at-a-time reference, at
        // ragged lengths, for every configurable width 1..=16.
        run_prop(120, 53, |rng| {
            let bits = 1 + rng.below(16) as u32; // 1..=16 hits both fast paths
            let n = 1 + rng.below(300);
            let max = 1u64 << bits;
            let codes: Vec<u32> =
                (0..n).map(|_| rng.below(max as usize) as u32).collect();
            let fast = pack_codes(&codes, bits);
            let slow = pack_codes_ref(&codes, bits);
            if fast != slow {
                return Err(format!("pack stream diverges at bits={bits} n={n}"));
            }
            let back_fast = unpack_codes(&fast, bits, n);
            let back_slow = unpack_codes_ref(&slow, bits, n);
            if back_fast != codes || back_slow != codes {
                return Err(format!("unpack mismatch at bits={bits} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn into_variants_reuse_caller_buffers() {
        // pack_into overwrites every byte (stale garbage must not leak into
        // the stream) and unpack_into fills exactly out.len() codes.
        let codes = vec![5u32, 0, 7, 3, 1];
        let bits = 3;
        let mut buf = vec![0xffu8; packed_len(codes.len(), bits)];
        pack_into(&codes, bits, &mut buf);
        assert_eq!(buf, pack_codes_ref(&codes, bits));
        let mut out = vec![99u32; codes.len()];
        unpack_into(&buf, bits, &mut out);
        assert_eq!(out, codes);
        // Byte-aligned fast path: same contract.
        let codes8 = vec![200u32, 0, 17];
        let mut buf8 = vec![0xaau8; 3];
        pack_into(&codes8, 8, &mut buf8);
        assert_eq!(buf8, vec![200, 0, 17]);
    }

    #[test]
    fn prop_packed_len_matches_bit_arithmetic() {
        run_prop(120, 23, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let n = rng.below(1000);
            let want = (n * bits as usize + 7) / 8;
            if packed_len(n, bits) == want {
                Ok(())
            } else {
                Err(format!("packed_len({n}, {bits}) != {want}"))
            }
        });
    }

    #[test]
    fn prop_packing_is_dense_concatenable_records() {
        // The paged cache (kvcache::paged) stores fixed-width per-token
        // records in blocks and indexes them by multiplication; that is only
        // sound if packing a whole stream equals concatenating byte-aligned
        // record packings.
        run_prop(40, 29, |rng| {
            let bits = 1 + rng.below(16) as u32;
            // Record length chosen so each record is byte-aligned.
            let rec = match bits % 8 {
                0 => 1 + rng.below(8),
                4 => 2 * (1 + rng.below(4)),
                2 | 6 => 4 * (1 + rng.below(2)),
                _ => 8,
            };
            let n_recs = 1 + rng.below(6);
            let max = 1u64 << bits;
            let all: Vec<u32> = (0..rec * n_recs)
                .map(|_| rng.below(max as usize) as u32)
                .collect();
            let whole = pack_codes(&all, bits);
            let mut concat = Vec::new();
            for chunk in all.chunks(rec) {
                concat.extend_from_slice(&pack_codes(chunk, bits));
            }
            if whole == concat {
                Ok(())
            } else {
                Err(format!("dense concat failed at bits={bits} rec={rec}"))
            }
        });
    }
}

//! Bit-packing of quantization codes.
//!
//! The KV cache stores codes at their true width (1–10 bits each, LSB-first
//! within a little-endian bit stream), which is what makes the paper's
//! "1 bit per channel" footprint real on the Rust side: a CQ-8c8b cache of
//! `T` tokens × `G` groups occupies exactly `ceil(T*G*8 / 8)` bytes.

/// Pack `codes` (each `< 2^bits`) into an LSB-first bit stream.
pub fn pack_codes(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=32).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 32 || c < (1u32 << bits), "code {c} exceeds {bits} bits");
        let mut v = c as u64;
        let mut remaining = bits;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = remaining.min(8 - off);
            out[byte] |= (((v & ((1u64 << take) - 1)) as u8) << off) as u8;
            v >>= take;
            bitpos += take as usize;
            remaining -= take;
        }
    }
    out
}

/// Unpack `n` codes of `bits` width from an LSB-first bit stream.
pub fn unpack_codes(bytes: &[u8], bits: u32, n: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut v: u64 = 0;
        let mut got = 0u32;
        while got < bits {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = (bits - got).min(8 - off);
            let chunk = ((bytes[byte] >> off) & ((1u16 << take) - 1) as u8) as u64;
            v |= chunk << got;
            got += take;
            bitpos += take as usize;
        }
        out.push(v as u32);
    }
    out
}

/// Bytes needed to store `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    #[test]
    fn roundtrip_small_widths() {
        for bits in [1u32, 2, 3, 4, 5, 7, 8, 10, 12] {
            let max = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..37u32).map(|i| i.wrapping_mul(2654435761) & max).collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.len(), packed_len(codes.len(), bits));
            let back = unpack_codes(&packed, bits, codes.len());
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn one_bit_density() {
        let codes = vec![1u32; 16];
        let packed = pack_codes(&codes, 1);
        assert_eq!(packed, vec![0xff, 0xff]);
    }

    #[test]
    fn ten_bit_crosses_byte_boundaries() {
        let codes = vec![0x3ffu32, 0, 0x2aa, 0x155];
        let packed = pack_codes(&codes, 10);
        assert_eq!(packed.len(), 5);
        assert_eq!(unpack_codes(&packed, 10, 4), codes);
    }

    #[test]
    fn prop_roundtrip_random() {
        run_prop(40, 11, |rng| {
            let bits = 1 + rng.below(12) as u32;
            let n = 1 + rng.below(200);
            let max = (1u64 << bits) as u32;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max as usize) as u32).collect();
            let back = unpack_codes(&pack_codes(&codes, bits), bits, n);
            if back == codes {
                Ok(())
            } else {
                Err(format!("mismatch at bits={bits} n={n}"))
            }
        });
    }

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 10), 4); // 30 bits -> 4 bytes
        assert_eq!(packed_len(4, 8), 4);
    }
}

//! KV-cache quantization: the paper's contribution (CQ) and every baseline
//! it is compared against (Tables 1–3), plus the shared infrastructure
//! (k-means, bit packing, entropy/correlation estimators).
//!
//! All codecs implement [`Codec`]: an in-place quantize→dequantize transform
//! over a KV activation tensor laid out `[L, B, H, T, hd]` (layers, batch,
//! heads, tokens, head channels).  The evaluation harness extracts clean
//! K/V through the `eval_kv` artifact, runs a codec over them, and feeds the
//! result back — so every method is measured through the *same* model path.
//!
//! Axis conventions (faithful to the paper §2.3/§3.2):
//! * keys are quantized **pre-RoPE**;
//! * scalar baselines quantize keys per-channel and values per-token;
//! * CQ quantizes both keys and values channel-coupled (groups of `c`
//!   contiguous channels within a head share one `b`-bit code).
//!
//! Above the codec zoo sits the **policy layer** ([`policy`]): named
//! [`policy::PolicyDescriptor`]s choose which codec at which precision
//! applies to each (layer, position) cell — per-layer bit allocation from
//! measured sensitivity ([`policy::greedy_allocate`]), full-precision
//! sliding window + attention-sink retention realized by the paged cache's
//! quantize-on-retire protocol, and per-tenant policies on the serve wire
//! (one pool, 1-bit CQ and fp16 tenants side by side).
//!
//! # Hot path
//!
//! Serving cost concentrates in centroid assignment: every prefill token
//! crosses `2·L·H·G` codebooks.  The measured pipeline is
//!
//! * [`kmeans`]'s dot-product-expansion assignment, vectorized 8 centroids
//!   at a time (stable-Rust unroll by default, `core::simd` behind the
//!   cargo `simd` feature; both bit-identical to the scalar kernel — see
//!   the lane-layout contract in [`kmeans`]'s module doc);
//! * [`cq::CqCodebooks::encode_span_pooled`], which fans (layer,
//!   token-piece) encode tasks across a persistent
//!   [`crate::util::workpool::WorkPool`] so chunked prefill reuses one set
//!   of threads for the worker's whole lifetime;
//! * radix compute-skip upstream of both: prompt tokens matched by the
//!   paged store's prefix index are never encoded at all
//!   (`prefill_tokens_skipped` in the serve metrics).
//!
//! Floors are enforced by `benches/quant_hot_path.rs --check` against the
//! committed `BENCH_quant.json`.

pub mod corr;
pub mod cq;
pub mod entropy;
pub mod intq;
pub mod kmeans;
pub mod kvquant;
pub mod nf;
pub mod factory;
pub mod pack;
pub mod policy;

use crate::tensor::TensorF;

/// Which half of the KV cache a tensor holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvKind {
    Key,
    Value,
}

/// A KV-cache quantization method.
pub trait Codec: Send + Sync {
    /// Display name, e.g. `CQ-4c8b` or `KVQuant-2b-1%`.
    fn name(&self) -> String;

    /// Bits per floating-point number, including per-group scale/zero and
    /// sparse-outlier overheads, excluding constant codebook storage
    /// (paper §4 "Bits Per FPN" accounting).
    fn bits_per_fpn(&self) -> f64;

    /// Quantize-dequantize `a` (layout `[L, B, H, T, hd]`) in place.
    fn apply(&self, kind: KvKind, a: &mut TensorF);
}

/// Identity codec — the FP16 row of every table.
pub struct Fp16;

impl Codec for Fp16 {
    fn name(&self) -> String {
        "FP16".into()
    }
    fn bits_per_fpn(&self) -> f64 {
        16.0
    }
    fn apply(&self, _kind: KvKind, _a: &mut TensorF) {}
}

/// Dimensions of a KV activation tensor `[L, B, H, T, hd]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvDims {
    pub l: usize,
    pub b: usize,
    pub h: usize,
    pub t: usize,
    pub hd: usize,
}

impl KvDims {
    pub fn of(a: &TensorF) -> KvDims {
        assert_eq!(a.rank(), 5, "KV tensor must be [L,B,H,T,hd], got {:?}", a.shape);
        KvDims {
            l: a.shape[0],
            b: a.shape[1],
            h: a.shape[2],
            t: a.shape[3],
            hd: a.shape[4],
        }
    }

    /// Flat offset of the contiguous `[hd]` token vector at (l, b, h, t).
    #[inline]
    pub fn vec_off(&self, l: usize, b: usize, h: usize, t: usize) -> usize {
        (((l * self.b + b) * self.h + h) * self.t + t) * self.hd
    }

    /// Tokens per (layer, head) slice.
    pub fn n_tokens(&self) -> usize {
        self.b * self.t
    }
}

/// Visit every token vector (contiguous `&mut [f32]` of length `hd`) of one
/// (layer, head) pair.
pub fn for_each_vec<F: FnMut(usize, &mut [f32])>(
    a: &mut TensorF,
    l: usize,
    h: usize,
    mut f: F,
) {
    let d = KvDims::of(a);
    let mut i = 0;
    for b in 0..d.b {
        for t in 0..d.t {
            let off = d.vec_off(l, b, h, t);
            f(i, &mut a.data[off..off + d.hd]);
            i += 1;
        }
    }
}

/// Gather one channel (l, h, dch) across all (b, t) into a vector.
pub fn gather_channel(a: &TensorF, l: usize, h: usize, dch: usize) -> Vec<f32> {
    let d = KvDims::of(a);
    let mut out = Vec::with_capacity(d.n_tokens());
    for b in 0..d.b {
        for t in 0..d.t {
            out.push(a.data[d.vec_off(l, b, h, t) + dch]);
        }
    }
    out
}

/// Apply a slice transform along the paper's quantization axes: keys
/// per-channel (the token series of each channel), values per-token (the
/// channel vector of each token), optionally subdivided into groups of
/// `group` elements along the reduction axis.
pub fn grouped_axis_apply<F: FnMut(&mut [f32])>(
    a: &mut TensorF,
    kind: KvKind,
    group: Option<usize>,
    mut f: F,
) {
    let d = KvDims::of(a);
    let mut run = |s: &mut [f32]| match group {
        None => f(s),
        Some(g) => {
            for chunk in s.chunks_mut(g) {
                f(chunk);
            }
        }
    };
    match kind {
        KvKind::Key => {
            for l in 0..d.l {
                for h in 0..d.h {
                    for ch in 0..d.hd {
                        let mut vals = gather_channel(a, l, h, ch);
                        run(&mut vals);
                        scatter_channel(a, l, h, ch, &vals);
                    }
                }
            }
        }
        KvKind::Value => {
            for l in 0..d.l {
                for h in 0..d.h {
                    for_each_vec(a, l, h, |_, v| run(v));
                }
            }
        }
    }
}

/// Scatter a channel back (inverse of [`gather_channel`]).
pub fn scatter_channel(a: &mut TensorF, l: usize, h: usize, dch: usize, vals: &[f32]) {
    let d = KvDims::of(a);
    assert_eq!(vals.len(), d.n_tokens());
    let mut i = 0;
    for b in 0..d.b {
        for t in 0..d.t {
            let off = d.vec_off(l, b, h, t) + dch;
            a.data[off] = vals[i];
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(shape: &[usize]) -> TensorF {
        let n = crate::tensor::numel(shape);
        TensorF::from_vec(shape, (0..n).map(|x| x as f32).collect()).unwrap()
    }

    #[test]
    fn fp16_is_identity() {
        let mut a = seq_tensor(&[1, 1, 1, 2, 3]);
        let before = a.clone();
        Fp16.apply(KvKind::Key, &mut a);
        assert_eq!(a, before);
        assert_eq!(Fp16.bits_per_fpn(), 16.0);
    }

    #[test]
    fn vec_off_matches_tensor_indexing() {
        let a = seq_tensor(&[2, 3, 4, 5, 6]);
        let d = KvDims::of(&a);
        assert_eq!(
            a.data[d.vec_off(1, 2, 3, 4)],
            a.at(&[1, 2, 3, 4, 0])
        );
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut a = seq_tensor(&[2, 2, 2, 3, 4]);
        let orig = a.clone();
        let ch = gather_channel(&a, 1, 0, 2);
        assert_eq!(ch.len(), 6);
        let doubled: Vec<f32> = ch.iter().map(|x| x * 2.0).collect();
        scatter_channel(&mut a, 1, 0, 2, &doubled);
        let back = gather_channel(&a, 1, 0, 2);
        assert_eq!(back, doubled);
        // Other channels untouched.
        assert_eq!(gather_channel(&a, 1, 0, 1), gather_channel(&orig, 1, 0, 1));
    }

    #[test]
    fn for_each_vec_visits_all_tokens_contiguously() {
        let mut a = seq_tensor(&[1, 2, 2, 3, 4]);
        let mut count = 0;
        for_each_vec(&mut a, 0, 1, |i, v| {
            assert_eq!(v.len(), 4);
            assert_eq!(i, count);
            count += 1;
            // Vectors are contiguous: consecutive channel values.
            assert_eq!(v[1] - v[0], 1.0);
        });
        assert_eq!(count, 6);
    }
}

//! NormalFloat (NF-b) quantization baseline (Dettmers et al., QLoRA).
//!
//! NF-b places its 2^b levels at evenly spaced quantiles of N(0,1) —
//! information-theoretically optimal for normally distributed data — then
//! scales each channel (keys) or token (values) into [-1, 1] by its absmax.
//! The level nearest zero is snapped to exactly 0, as in the QLoRA grid.
//! `-gs128` applies the absmax per group of 128 along the reduction axis.

use super::{grouped_axis_apply, Codec, KvKind};
use crate::tensor::TensorF;

/// Inverse standard-normal CDF (Acklam's rational approximation, |ε|<1.15e-9).
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let pl = 0.02425;
    if p < pl {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - pl {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

/// Build the NF-b level grid in [-1, 1] with 0 exactly representable.
pub fn nf_levels(bits: u32) -> Vec<f32> {
    let m = 1usize << bits;
    let delta = 1.0 / (2.0 * m as f64 + 2.0);
    let mut lv: Vec<f64> = (0..m)
        .map(|i| probit(delta + (1.0 - 2.0 * delta) * i as f64 / (m - 1) as f64))
        .collect();
    let maxab = lv.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    for x in lv.iter_mut() {
        *x /= maxab;
    }
    // Snap the level nearest zero to exactly zero (QLoRA property).
    let zi = lv
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    lv[zi] = 0.0;
    lv.iter().map(|&x| x as f32).collect()
}

/// Quantize-dequantize one slice against the normalized grid: absmax scale,
/// nearest level, rescale.
pub fn nf_qdq(xs: &mut [f32], levels: &[f32]) {
    let absmax = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if absmax == 0.0 {
        return;
    }
    for x in xs.iter_mut() {
        let u = *x / absmax;
        let mut best = levels[0];
        let mut bd = (u - best).abs();
        for &lv in &levels[1..] {
            let d = (u - lv).abs();
            if d < bd {
                bd = d;
                best = lv;
            }
        }
        *x = best * absmax;
    }
}

pub struct NfQ {
    pub bits: u32,
    pub group: Option<usize>,
    levels: Vec<f32>,
}

impl NfQ {
    pub fn new(bits: u32, group: Option<usize>) -> NfQ {
        NfQ { bits, group, levels: nf_levels(bits) }
    }
}

impl Codec for NfQ {
    fn name(&self) -> String {
        match self.group {
            None => format!("NF{}", self.bits),
            Some(g) => format!("NF{}-gs{}", self.bits, g),
        }
    }

    fn bits_per_fpn(&self) -> f64 {
        match self.group {
            Some(g) => self.bits as f64 + 16.0 / g as f64, // one fp16 absmax per group
            None => self.bits as f64,
        }
    }

    fn apply(&self, kind: KvKind, a: &mut TensorF) {
        grouped_axis_apply(a, kind, self.group, |s| nf_qdq(s, &self.levels));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::KvDims;
    use crate::util::rng::Pcg64;

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        assert!((probit(0.0013498980316301) + 3.0).abs() < 1e-6);
        assert!((probit(0.84134474606854) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nf4_grid_properties() {
        let lv = nf_levels(4);
        assert_eq!(lv.len(), 16);
        assert_eq!(lv[0], -1.0);
        assert_eq!(*lv.last().unwrap(), 1.0);
        assert!(lv.contains(&0.0));
        assert!(lv.windows(2).all(|w| w[0] < w[1]), "monotone: {lv:?}");
        // Denser near zero than near the tails (normal-quantile property).
        let near = lv[8] - lv[7];
        let far = lv[15] - lv[14];
        assert!(near.abs() < far.abs());
    }

    #[test]
    fn nf2_grid() {
        let lv = nf_levels(2);
        assert_eq!(lv.len(), 4);
        assert!(lv.contains(&0.0));
        assert_eq!(lv[0], -1.0);
    }

    #[test]
    fn nf_beats_int_on_gaussian_data() {
        let mut rng = Pcg64::seed(1);
        let orig: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let mut nf = orig.clone();
        nf_qdq(&mut nf, &nf_levels(4));
        let mut int = orig.clone();
        super::super::intq::uniform_qdq(&mut int, 4);
        let err = |a: &[f32]| -> f64 {
            a.iter().zip(&orig).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
        };
        assert!(err(&nf) < err(&int), "nf={} int={}", err(&nf), err(&int));
    }

    #[test]
    fn zero_slice_is_noop() {
        let mut xs = vec![0.0f32; 8];
        nf_qdq(&mut xs, &nf_levels(4));
        assert!(xs.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn codec_applies_over_kv_axes() {
        let mut rng = Pcg64::seed(2);
        let shape = [1, 1, 2, 16, 8];
        let n = crate::tensor::numel(&shape);
        let mut a =
            TensorF::from_vec(&shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap();
        let orig = a.clone();
        NfQ::new(4, None).apply(KvKind::Key, &mut a);
        let d = KvDims::of(&a);
        assert_eq!(d.hd, 8);
        let mse = a.sqdiff(&orig) / n as f64;
        assert!(mse > 0.0 && mse < 0.05, "mse={mse}");
    }
}

//! Codec factory: builds every Table-1/2/3 row from its display name.
//!
//! Calibration-free codecs (INT/NF/FP16) build directly; calibration-based
//! codecs (CQ, KVQuant) learn from a [`CalibData`] — the same 16-sequence
//! WikiText-2-style calibration set the paper uses for both method families.

use anyhow::{anyhow, bail, Result};

use crate::calib::CalibData;

use super::cq::{CqCodebooks, CqCodec, CqSpec, LearnCfg};
use super::intq::IntQ;
use super::kvquant::KvQuant;
use super::nf::NfQ;
use super::{Codec, Fp16};

/// Options for calibration-based codec construction.
#[derive(Clone, Copy, Debug)]
pub struct FactoryCfg {
    pub fisher: bool,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for FactoryCfg {
    fn default() -> Self {
        FactoryCfg { fisher: true, max_iters: 40, seed: 0 }
    }
}

/// Canonical codec name list in the paper's Table 1/2 row order.
pub fn table_rows() -> Vec<&'static str> {
    vec![
        "fp16",
        "int4", "int4-gs128", "nf4", "nf4-gs128", "kvquant-4b", "kvquant-4b-1%", "cq-2c8b",
        "int2", "int2-gs128", "nf2", "nf2-gs128", "kvquant-2b", "kvquant-2b-1%", "cq-4c8b",
        "kvquant-1b", "kvquant-1b-1%", "cq-8c8b", "cq-8c10b",
    ]
}

/// Whether a codec name needs calibration data.
pub fn needs_calibration(name: &str) -> bool {
    let n = name.to_lowercase();
    n.starts_with("cq-") || n.starts_with("kvquant")
}

/// Build a codec by name.  `calib` is required for CQ/KVQuant rows.
pub fn build_codec(
    name: &str,
    calib: Option<&CalibData>,
    cfg: FactoryCfg,
) -> Result<Box<dyn Codec>> {
    let n = name.to_lowercase();
    if n == "fp16" {
        return Ok(Box::new(Fp16));
    }
    if let Some(rest) = n.strip_prefix("int") {
        let (bits, group) = parse_scalar(rest)?;
        return Ok(Box::new(IntQ::new(bits, group)));
    }
    if let Some(rest) = n.strip_prefix("nf") {
        let (bits, group) = parse_scalar(rest)?;
        return Ok(Box::new(NfQ::new(bits, group)));
    }
    let calib = calib.ok_or_else(|| anyhow!("codec '{name}' needs calibration data"))?;
    if let Some(rest) = n.strip_prefix("cq-") {
        let spec = parse_cq(rest)?;
        let (gk, gv) = if cfg.fisher {
            (Some(&calib.gk), Some(&calib.gv))
        } else {
            (None, None)
        };
        let books = CqCodebooks::learn(
            spec,
            &calib.k,
            &calib.v,
            gk,
            gv,
            LearnCfg { fisher: cfg.fisher, max_iters: cfg.max_iters, seed: cfg.seed },
        );
        let codec = if cfg.fisher {
            CqCodec::new(books)
        } else {
            CqCodec::with_label(books, &format!("CQ-{}-uniform", spec.tag()))
        };
        return Ok(Box::new(codec));
    }
    if let Some(rest) = n.strip_prefix("kvquant-") {
        // forms: "2b", "2b-1%"
        let (bits_s, frac) = match rest.split_once("b-") {
            Some((b, f)) => {
                let pct: f64 = f
                    .trim_end_matches('%')
                    .parse()
                    .map_err(|_| anyhow!("bad outlier % in '{name}'"))?;
                (b, pct / 100.0)
            }
            None => (rest.trim_end_matches('b'), 0.0),
        };
        let bits: u32 = bits_s.parse().map_err(|_| anyhow!("bad bits in '{name}'"))?;
        let (gk, gv) = if cfg.fisher {
            (Some(&calib.gk), Some(&calib.gv))
        } else {
            (None, None)
        };
        return Ok(Box::new(KvQuant::learn(
            bits,
            frac,
            &calib.k,
            &calib.v,
            gk,
            gv,
            cfg.max_iters,
            cfg.seed,
        )));
    }
    bail!("unknown codec '{name}' (rows: {:?})", table_rows())
}

/// Parse "<bits>" or "<bits>-gs<group>".
fn parse_scalar(s: &str) -> Result<(u32, Option<usize>)> {
    match s.split_once("-gs") {
        Some((b, g)) => Ok((
            b.parse().map_err(|_| anyhow!("bad bits '{b}'"))?,
            Some(g.parse().map_err(|_| anyhow!("bad group '{g}'"))?),
        )),
        None => Ok((s.parse().map_err(|_| anyhow!("bad bits '{s}'"))?, None)),
    }
}

/// Parse "<c>c<b>b".
pub fn parse_cq(s: &str) -> Result<CqSpec> {
    let (c, rest) = s
        .split_once('c')
        .ok_or_else(|| anyhow!("bad CQ spec '{s}' (want e.g. 4c8b)"))?;
    let b = rest.trim_end_matches('b');
    Ok(CqSpec::new(
        c.parse().map_err(|_| anyhow!("bad channels '{c}'"))?,
        b.parse().map_err(|_| anyhow!("bad bits '{b}'"))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF;
    use crate::util::rng::Pcg64;

    fn fake_calib() -> CalibData {
        let mut rng = Pcg64::seed(0);
        let shape = [2, 1, 2, 16, 8];
        let mut mk = || {
            let n = crate::tensor::numel(&shape);
            TensorF::from_vec(&shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        CalibData { k: mk(), v: mk(), gk: mk(), gv: mk() }
    }

    #[test]
    fn builds_every_table_row() {
        let calib = fake_calib();
        let cfg = FactoryCfg { fisher: true, max_iters: 5, seed: 0 };
        for name in table_rows() {
            let codec = build_codec(name, Some(&calib), cfg)
                .unwrap_or_else(|e| panic!("row {name}: {e:#}"));
            assert!(codec.bits_per_fpn() > 0.0, "{name}");
        }
    }

    #[test]
    fn bits_per_fpn_matches_paper_budget() {
        let calib = fake_calib();
        let cfg = FactoryCfg { fisher: false, max_iters: 3, seed: 0 };
        for (name, bits) in [
            ("cq-2c8b", 4.0),
            ("cq-4c8b", 2.0),
            ("cq-8c8b", 1.0),
            ("cq-8c10b", 1.25),
            ("int2", 2.0),
            ("kvquant-1b-1%", 1.32),
        ] {
            let c = build_codec(name, Some(&calib), cfg).unwrap();
            assert!(
                (c.bits_per_fpn() - bits).abs() < 1e-9,
                "{name}: {} != {bits}",
                c.bits_per_fpn()
            );
        }
    }

    #[test]
    fn calibration_requirement_enforced() {
        assert!(build_codec("cq-4c8b", None, FactoryCfg::default()).is_err());
        assert!(build_codec("int4", None, FactoryCfg::default()).is_ok());
        assert!(needs_calibration("kvquant-2b"));
        assert!(!needs_calibration("nf4-gs128"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(build_codec("zstd", None, FactoryCfg::default()).is_err());
        assert!(parse_cq("8x8").is_err());
        assert_eq!(parse_cq("8c10b").unwrap(), CqSpec::new(8, 10));
    }
}

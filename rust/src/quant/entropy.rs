//! Binned (joint) entropy estimation — the information-theoretic measurement
//! behind the paper's Figure 1 and §3.1 motivation.
//!
//! Each channel's support is partitioned into `bins` equally sized bins
//! (the paper uses 16); values are discretized to bin indices and entropy is
//! estimated from empirical bin frequencies (Eq. 4).  Joint entropy over a
//! group of channels uses the product binning, counted sparsely in a hash
//! map so group sizes up to 4 stay cheap.

use std::collections::HashMap;

/// Per-channel binning: equal-width bins over [min, max].
pub struct Binner {
    pub lo: f32,
    pub width: f32,
    pub bins: usize,
}

impl Binner {
    pub fn fit(values: &[f32], bins: usize) -> Binner {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in values {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !lo.is_finite() || hi <= lo {
            return Binner { lo: 0.0, width: 1.0, bins };
        }
        Binner { lo, width: (hi - lo) / bins as f32, bins }
    }

    #[inline]
    pub fn bin(&self, x: f32) -> usize {
        (((x - self.lo) / self.width) as usize).min(self.bins - 1)
    }
}

/// Entropy (bits) of empirical counts.
fn entropy_of_counts<I: Iterator<Item = u32>>(counts: I, n: usize) -> f64 {
    let n = n as f64;
    let mut h = 0.0;
    for c in counts {
        if c > 0 {
            let p = c as f64 / n;
            h -= p * p.log2();
        }
    }
    h
}

/// Marginal entropy of one channel, `bins` equal-width bins.
pub fn marginal_entropy(values: &[f32], bins: usize) -> f64 {
    let b = Binner::fit(values, bins);
    let mut counts = vec![0u32; bins];
    for &x in values {
        counts[b.bin(x)] += 1;
    }
    entropy_of_counts(counts.into_iter(), values.len())
}

/// Joint entropy of a channel group.  `channels[c][i]` is sample `i` of
/// channel `c`; all channels must have equal sample counts.
pub fn joint_entropy(channels: &[&[f32]], bins: usize) -> f64 {
    assert!(!channels.is_empty());
    let n = channels[0].len();
    assert!(channels.iter().all(|c| c.len() == n));
    assert!(
        (channels.len() as f64) * (bins as f64).log2() <= 60.0,
        "group too large for u64 bin keys"
    );
    let binners: Vec<Binner> = channels.iter().map(|c| Binner::fit(c, bins)).collect();
    let mut counts: HashMap<u64, u32> = HashMap::new();
    for i in 0..n {
        let mut key = 0u64;
        for (c, b) in channels.iter().zip(&binners) {
            key = key * bins as u64 + b.bin(c[i]) as u64;
        }
        *counts.entry(key).or_insert(0) += 1;
    }
    entropy_of_counts(counts.into_values(), n)
}

/// Sum of marginal entropies of a channel group (the upper bound in Eq. 3).
pub fn sum_marginal_entropy(channels: &[&[f32]], bins: usize) -> f64 {
    channels.iter().map(|c| marginal_entropy(c, bins)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn uniform_bins_hit_log2_bins() {
        // Perfectly uniform data over 16 bins -> H == 4 bits.
        let vals: Vec<f32> = (0..1600).map(|i| (i % 16) as f32 + 0.5).collect();
        let h = marginal_entropy(&vals, 16);
        assert!((h - 4.0).abs() < 1e-9, "h={h}");
    }

    #[test]
    fn constant_channel_has_zero_entropy() {
        let vals = vec![3.0f32; 100];
        assert_eq!(marginal_entropy(&vals, 16), 0.0);
    }

    #[test]
    fn joint_entropy_of_identical_channels_equals_marginal() {
        let mut rng = Pcg64::seed(1);
        let a: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let hj = joint_entropy(&[&a, &a], 16);
        let hm = marginal_entropy(&a, 16);
        assert!((hj - hm).abs() < 1e-9, "joint {hj} vs marginal {hm}");
    }

    #[test]
    fn independent_channels_joint_close_to_sum() {
        let mut rng = Pcg64::seed(2);
        let a: Vec<f32> = (0..30000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..30000).map(|_| rng.normal() as f32).collect();
        let hj = joint_entropy(&[&a, &b], 8);
        let hs = sum_marginal_entropy(&[&a, &b], 8);
        // Finite-sample bias pulls joint slightly below the sum.
        assert!(hj <= hs + 1e-9);
        assert!(hj > hs - 0.35, "joint {hj} vs sum {hs}");
    }

    #[test]
    fn dependent_channels_have_lower_joint_entropy() {
        // The paper's core observation (Fig. 1): correlated channels'
        // joint entropy grows sub-linearly.
        let mut rng = Pcg64::seed(3);
        let a: Vec<f32> = (0..30000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = a.iter().map(|&x| x + 0.1 * rng.normal() as f32).collect();
        let hj = joint_entropy(&[&a, &b], 16);
        let hs = sum_marginal_entropy(&[&a, &b], 16);
        assert!(hj < hs - 1.0, "dependency should show: joint {hj} sum {hs}");
    }

    #[test]
    fn subadditivity_property() {
        // H(X1..Xn) <= sum H(Xi) for arbitrary random data (Eq. 3).
        let mut rng = Pcg64::seed(4);
        for _ in 0..5 {
            let n = 2000;
            let chans: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..n).map(|_| (rng.normal() * 2.0) as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = chans.iter().map(|c| c.as_slice()).collect();
            assert!(joint_entropy(&refs, 8) <= sum_marginal_entropy(&refs, 8) + 1e-9);
        }
    }
}

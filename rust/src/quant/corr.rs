//! Pearson correlation matrices over activation channels — the measurement
//! behind the paper's Figure 2 (and Appendix Figures 5–8): channels of
//! key/value head embeddings are strongly linearly dependent.

/// Pearson correlation between two equal-length samples.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let (mut sab, mut saa, mut sbb) = (0.0, 0.0, 0.0);
    for i in 0..a.len() {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        sab += da * db;
        saa += da * da;
        sbb += db * db;
    }
    if saa == 0.0 || sbb == 0.0 {
        return 0.0;
    }
    sab / (saa * sbb).sqrt()
}

/// Full correlation matrix (row-major `[c, c]`) over `channels[c][i]`.
pub fn corr_matrix(channels: &[Vec<f32>]) -> Vec<f64> {
    let c = channels.len();
    let mut m = vec![0.0; c * c];
    for i in 0..c {
        m[i * c + i] = 1.0;
        for j in (i + 1)..c {
            let r = pearson(&channels[i], &channels[j]);
            m[i * c + j] = r;
            m[j * c + i] = r;
        }
    }
    m
}

/// Mean absolute off-diagonal correlation — the scalar summary printed by
/// the Figure-2 bench (heat maps are dumped as CSV).
pub fn mean_abs_offdiag(m: &[f64], c: usize) -> f64 {
    if c < 2 {
        return 0.0;
    }
    let mut s = 0.0;
    for i in 0..c {
        for j in 0..c {
            if i != j {
                s += m[i * c + j].abs();
            }
        }
    }
    s / (c * (c - 1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn perfect_correlation() {
        let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c: Vec<f32> = a.iter().map(|x| -x).collect();
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_near_zero() {
        let mut rng = Pcg64::seed(1);
        let a: Vec<f32> = (0..20000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..20000).map(|_| rng.normal() as f32).collect();
        assert!(pearson(&a, &b).abs() < 0.03);
    }

    #[test]
    fn constant_channel_yields_zero() {
        let a = vec![1.0f32; 10];
        let b: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(pearson(&a, &b), 0.0);
    }

    #[test]
    fn matrix_is_symmetric_with_unit_diagonal() {
        let mut rng = Pcg64::seed(2);
        let chans: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..500).map(|_| rng.normal() as f32).collect())
            .collect();
        let m = corr_matrix(&chans);
        for i in 0..4 {
            assert_eq!(m[i * 4 + i], 1.0);
            for j in 0..4 {
                assert!((m[i * 4 + j] - m[j * 4 + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mean_abs_offdiag_summary() {
        // Block of two perfectly correlated + one independent channel.
        let base: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let mut rng = Pcg64::seed(3);
        let noise: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let m = corr_matrix(&[base.clone(), base.clone(), noise]);
        let s = mean_abs_offdiag(&m, 3);
        assert!(s > 0.3 && s < 0.8, "s={s}");
    }
}

//! Coupled Quantization (CQ) — the paper's contribution (§3.2).
//!
//! Channels of each key/value head embedding are split into contiguous
//! groups of `c`; each group is quantized jointly to one of `2^b` learned
//! multi-channel centroids (notation `CQ-<c>c<b>b`, bits/FPN = b/c).
//! Codebooks are learned per (layer, K/V, head, group) on a calibration set
//! with k-means++ (Eq. 5), optionally weighted by the diagonal Fisher
//! information of the activations (Eq. 6) to preserve salient activations.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::kmeans::{kmeans, KMeans, KMeansCfg};
use super::{Codec, KvDims, KvKind};
use crate::tensor::TensorF;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::workpool::WorkPool;

/// A CQ-<c>c<b>b configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CqSpec {
    pub channels: usize,
    pub bits: usize,
}

impl CqSpec {
    pub fn new(channels: usize, bits: usize) -> CqSpec {
        CqSpec { channels, bits }
    }
    pub fn n_centroids(&self) -> usize {
        1 << self.bits
    }
    pub fn n_groups(&self, head_dim: usize) -> usize {
        assert_eq!(head_dim % self.channels, 0);
        head_dim / self.channels
    }
    pub fn bits_per_fpn(&self) -> f64 {
        self.bits as f64 / self.channels as f64
    }
    pub fn tag(&self) -> String {
        format!("{}c{}b", self.channels, self.bits)
    }
}

/// Centroid-learning options.
#[derive(Clone, Copy, Debug)]
pub struct LearnCfg {
    /// Use Fisher-guided weighting (paper Eq. 6) when gradients are given.
    pub fisher: bool,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for LearnCfg {
    fn default() -> Self {
        LearnCfg { fisher: true, max_iters: 100, seed: 0 }
    }
}

/// Learned CQ codebooks for one model: `books[l][kv][h][g]`.
pub struct CqCodebooks {
    pub spec: CqSpec,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    books: Vec<KMeans>,
    /// Wall-clock seconds spent in centroid learning (Table 5).
    pub learn_secs: f64,
}

impl CqCodebooks {
    fn book_index(&self, l: usize, kind: KvKind, h: usize, g: usize) -> usize {
        let kv = match kind {
            KvKind::Key => 0,
            KvKind::Value => 1,
        };
        ((l * 2 + kv) * self.n_heads + h) * self.spec.n_groups(self.head_dim) + g
    }

    pub fn book(&self, l: usize, kind: KvKind, h: usize, g: usize) -> &KMeans {
        &self.books[self.book_index(l, kind, h, g)]
    }

    /// Learn codebooks from calibration activations (`k`,`v`: `[L,B,H,T,hd]`)
    /// and, when `cfg.fisher`, their loss gradients of identical shape.
    pub fn learn(
        spec: CqSpec,
        k: &TensorF,
        v: &TensorF,
        gk: Option<&TensorF>,
        gv: Option<&TensorF>,
        cfg: LearnCfg,
    ) -> CqCodebooks {
        let d = KvDims::of(k);
        assert_eq!(k.shape, v.shape);
        let t0 = std::time::Instant::now();
        let groups = spec.n_groups(d.hd);
        let mut books =
            Vec::with_capacity(d.l * 2 * d.h * groups);
        for l in 0..d.l {
            for (kind_i, (acts, grads)) in [(k, gk), (v, gv)].into_iter().enumerate() {
                for h in 0..d.h {
                    for g in 0..groups {
                        let (pts, w) = collect_group_points(acts, grads, l, h, g, spec, cfg.fisher);
                        let km = kmeans(
                            &pts,
                            d.n_tokens(),
                            spec.channels,
                            w.as_deref(),
                            KMeansCfg {
                                k: spec.n_centroids(),
                                max_iters: cfg.max_iters,
                                seed: cfg
                                    .seed
                                    .wrapping_add((((l * 2 + kind_i) * d.h + h) * groups + g) as u64),
                            },
                        );
                        books.push(km);
                    }
                }
            }
        }
        CqCodebooks {
            spec,
            n_layers: d.l,
            n_heads: d.h,
            head_dim: d.hd,
            books,
            learn_secs: t0.elapsed().as_secs_f64(),
        }
    }

    /// Encode one head vector (`len == head_dim`) to per-group codes.
    pub fn encode_vec(&self, l: usize, kind: KvKind, h: usize, x: &[f32]) -> Vec<u32> {
        assert_eq!(x.len(), self.head_dim);
        let c = self.spec.channels;
        (0..self.spec.n_groups(self.head_dim))
            .map(|g| self.book(l, kind, h, g).assign(&x[g * c..(g + 1) * c]) as u32)
            .collect()
    }

    /// Batch-encode tokens `t0..t1` of batch row `b` for ONE layer into
    /// `out`, laid out `[t1-t0, n_heads, groups]`.
    ///
    /// This is the loop inversion the prefill hot path rides: books are the
    /// OUTER loops and tokens the inner one, so each centroid table (plus
    /// its `‖c‖²` norms, computed once here) stays cache-resident across the
    /// whole span instead of being re-walked per token, and assignment runs
    /// the dot-product expansion kernel.  Produces exactly the codes
    /// [`Self::encode_vec`] would, token by token.
    pub fn encode_layer_span_into(
        &self,
        l: usize,
        kind: KvKind,
        acts: &TensorF,
        b: usize,
        t0: usize,
        t1: usize,
        out: &mut [u32],
    ) {
        let d = KvDims::of(acts);
        assert_eq!(d.hd, self.head_dim);
        let c = self.spec.channels;
        let groups = self.spec.n_groups(self.head_dim);
        let span = t1 - t0;
        assert_eq!(out.len(), span * d.h * groups);
        let mut cnorms = Vec::with_capacity(self.spec.n_centroids());
        for h in 0..d.h {
            for g in 0..groups {
                let book = self.book(l, kind, h, g);
                book.centroid_sq_norms_into(&mut cnorms);
                for t in 0..span {
                    let off = d.vec_off(l, b, h, t0 + t) + g * c;
                    out[(t * d.h + h) * groups + g] =
                        book.assign_with_norms(&acts.data[off..off + c], &cnorms) as u32;
                }
            }
        }
    }

    /// Batched prefill encode through a caller-owned persistent
    /// [`WorkPool`]: K and V codes for tokens `t0..t1` of batch row 0,
    /// returned as token-major per-side buffers (`[t1-t0, L*H*G]` each,
    /// layout `[t][l][h][g]`) — the record shape
    /// `PagedSeqCache::append_span` consumes.
    ///
    /// Fan-out granularity: each layer's span is cut into
    /// `ceil(width / L)` token pieces, so the task count reaches the pool
    /// width even when `layers < threads` (a 1-layer config still
    /// parallelizes) while a wide model degenerates to one task per layer.
    /// Every decomposition writes disjoint slices of the same per-layer
    /// buffers, so the output is byte-identical regardless of pool size —
    /// including the inline fallback (`width == 1`) and the small-span
    /// path, which skip task dispatch entirely.
    pub fn encode_span_pooled(
        &self,
        k: &TensorF,
        v: &TensorF,
        t0: usize,
        t1: usize,
        pool: &WorkPool,
    ) -> (Vec<u32>, Vec<u32>) {
        let d = KvDims::of(k);
        assert_eq!(k.shape, v.shape);
        let groups = self.spec.n_groups(self.head_dim);
        let hg = d.h * groups;
        let per_side = d.l * hg;
        let span = t1 - t0;
        if span == 0 {
            return (Vec::new(), Vec::new());
        }
        // A mostly-radix-hit prompt encodes only a few private tokens,
        // where the batched kernel alone already wins — run those inline
        // even when a real pool is available.
        const PARALLEL_MIN_SPAN: usize = 4;
        let width = pool.width();
        let mut layer_codes: Vec<(Vec<u32>, Vec<u32>)> = (0..d.l)
            .map(|_| (vec![0u32; span * hg], vec![0u32; span * hg]))
            .collect();
        if width == 1 || span < PARALLEL_MIN_SPAN {
            for (l, (kc, vc)) in layer_codes.iter_mut().enumerate() {
                self.encode_layer_span_into(l, KvKind::Key, k, 0, t0, t1, kc);
                self.encode_layer_span_into(l, KvKind::Value, v, 0, t0, t1, vc);
            }
        } else {
            let pieces = width.div_ceil(d.l).min(span);
            let piece_tokens = span.div_ceil(pieces);
            pool.scope(|s| {
                for (l, (kc, vc)) in layer_codes.iter_mut().enumerate() {
                    let piece_iter = kc
                        .chunks_mut(piece_tokens * hg)
                        .zip(vc.chunks_mut(piece_tokens * hg))
                        .enumerate();
                    for (p, (kcp, vcp)) in piece_iter {
                        let a = t0 + p * piece_tokens;
                        let b = a + kcp.len() / hg;
                        s.spawn(move || {
                            self.encode_layer_span_into(l, KvKind::Key, k, 0, a, b, kcp);
                            self.encode_layer_span_into(l, KvKind::Value, v, 0, a, b, vcp);
                        });
                    }
                }
            });
        }
        // Interleave per-layer [t][h][g] buffers into token-major records.
        let mut k_all = vec![0u32; span * per_side];
        let mut v_all = vec![0u32; span * per_side];
        for (l, (kc, vc)) in layer_codes.iter().enumerate() {
            for t in 0..span {
                let src = t * hg;
                let dst = t * per_side + l * hg;
                k_all[dst..dst + hg].copy_from_slice(&kc[src..src + hg]);
                v_all[dst..dst + hg].copy_from_slice(&vc[src..src + hg]);
            }
        }
        (k_all, v_all)
    }

    /// [`Self::encode_span_pooled`] behind a one-shot inline pool — for
    /// callers without a persistent pool (offline eval, one-off tests).
    /// Serving keeps a per-worker [`WorkPool`] alive across prefill chunks
    /// instead: spawning threads here cost tens of µs per chunk, which is
    /// exactly what the persistent pool exists to amortize.
    pub fn encode_span_parallel(
        &self,
        k: &TensorF,
        v: &TensorF,
        t0: usize,
        t1: usize,
    ) -> (Vec<u32>, Vec<u32>) {
        self.encode_span_pooled(k, v, t0, t1, &WorkPool::new(0))
    }

    /// Random unit-normal codebooks — no calibration pass needed.  Used by
    /// the `quant_hot_path` bench and kernel-equivalence tests, where only
    /// the geometry (not the learned quality) matters.
    pub fn synthetic(
        spec: CqSpec,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
        seed: u64,
    ) -> CqCodebooks {
        let groups = spec.n_groups(head_dim);
        let mut rng = Pcg64::seed(seed);
        let books = (0..n_layers * 2 * n_heads * groups)
            .map(|_| KMeans {
                k: spec.n_centroids(),
                dim: spec.channels,
                centroids: (0..spec.n_centroids() * spec.channels)
                    .map(|_| rng.normal() as f32)
                    .collect(),
                inertia: 0.0,
                iters_run: 0,
            })
            .collect();
        CqCodebooks { spec, n_layers, n_heads, head_dim, books, learn_secs: 0.0 }
    }

    /// Decode per-group codes back into a head vector.
    pub fn decode_vec(&self, l: usize, kind: KvKind, h: usize, codes: &[u32], out: &mut [f32]) {
        let c = self.spec.channels;
        for (g, &code) in codes.iter().enumerate() {
            out[g * c..(g + 1) * c]
                .copy_from_slice(self.book(l, kind, h, g).centroid(code as usize));
        }
    }

    /// Export centroids as the `[L, H, G, K, C]` tensor fed to the
    /// `decode_cq_*` artifacts.
    pub fn export_tensor(&self, kind: KvKind) -> TensorF {
        let g = self.spec.n_groups(self.head_dim);
        let kk = self.spec.n_centroids();
        let c = self.spec.channels;
        let mut t = TensorF::zeros(&[self.n_layers, self.n_heads, g, kk, c]);
        let mut off = 0;
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                for gi in 0..g {
                    let book = self.book(l, kind, h, gi);
                    for j in 0..kk {
                        let src = if j < book.k { book.centroid(j) } else { book.centroid(book.k - 1) };
                        t.data[off..off + c].copy_from_slice(src);
                        off += c;
                    }
                }
            }
        }
        t
    }

    /// Centroid parameter count (paper Table 5: `l × 2 × h × hd × 2^b`
    /// halves — independent of `c` because dims-per-centroid and group count
    /// trade off exactly).
    pub fn centroid_param_count(&self) -> usize {
        // per (l, kv, h): (hd/c) groups × 2^b centroids × c dims = hd · 2^b
        self.n_layers * 2 * self.n_heads * self.head_dim * self.spec.n_centroids()
    }

    /// Serialize to `<path>` (JSON header line + raw LE f32 centroids).
    pub fn save(&self, path: &Path) -> Result<()> {
        let hdr = Json::obj(vec![
            ("channels", Json::Num(self.spec.channels as f64)),
            ("bits", Json::Num(self.spec.bits as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("head_dim", Json::Num(self.head_dim as f64)),
            ("learn_secs", Json::Num(self.learn_secs)),
        ]);
        let mut bytes = hdr.dump().into_bytes();
        bytes.push(b'\n');
        for b in &self.books {
            for x in &b.centroids {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Load a serialized codebook file.
    pub fn load(path: &Path) -> Result<CqCodebooks> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let nl = bytes
            .iter()
            .position(|&b| b == b'\n')
            .context("missing header line")?;
        let hdr = Json::parse(std::str::from_utf8(&bytes[..nl])?)?;
        let spec = CqSpec::new(
            hdr.req("channels")?.as_usize().context("channels")?,
            hdr.req("bits")?.as_usize().context("bits")?,
        );
        let n_layers = hdr.req("n_layers")?.as_usize().context("n_layers")?;
        let n_heads = hdr.req("n_heads")?.as_usize().context("n_heads")?;
        let head_dim = hdr.req("head_dim")?.as_usize().context("head_dim")?;
        let learn_secs = hdr.num_or("learn_secs", 0.0);
        let groups = spec.n_groups(head_dim);
        let n_books = n_layers * 2 * n_heads * groups;
        let per_book = spec.n_centroids() * spec.channels;
        let need = n_books * per_book * 4;
        let payload = &bytes[nl + 1..];
        if payload.len() != need {
            bail!("codebook payload: want {need} bytes, got {}", payload.len());
        }
        let mut books = Vec::with_capacity(n_books);
        for bi in 0..n_books {
            let mut cents = Vec::with_capacity(per_book);
            for j in 0..per_book {
                let o = (bi * per_book + j) * 4;
                cents.push(f32::from_le_bytes([
                    payload[o],
                    payload[o + 1],
                    payload[o + 2],
                    payload[o + 3],
                ]));
            }
            books.push(KMeans {
                k: spec.n_centroids(),
                dim: spec.channels,
                centroids: cents,
                inertia: 0.0,
                iters_run: 0,
            });
        }
        Ok(CqCodebooks { spec, n_layers, n_heads, head_dim, books, learn_secs })
    }
}

/// Gather the `[n_tokens, c]` point matrix for one (layer, head, group) and,
/// if Fisher-guided, the per-token weights `sum_{ch in group} g(A)^2`
/// (Eq. 6's `g(A)^T g(A)` over the coupled sub-vector).
fn collect_group_points(
    acts: &TensorF,
    grads: Option<&TensorF>,
    l: usize,
    h: usize,
    g: usize,
    spec: CqSpec,
    fisher: bool,
) -> (Vec<f32>, Option<Vec<f32>>) {
    let d = KvDims::of(acts);
    let c = spec.channels;
    let mut pts = Vec::with_capacity(d.n_tokens() * c);
    let mut w = if fisher && grads.is_some() {
        Some(Vec::with_capacity(d.n_tokens()))
    } else {
        None
    };
    for b in 0..d.b {
        for t in 0..d.t {
            let off = d.vec_off(l, b, h, t) + g * c;
            pts.extend_from_slice(&acts.data[off..off + c]);
            if let (Some(w), Some(gr)) = (w.as_mut(), grads) {
                let mut s = 0.0f32;
                for ch in 0..c {
                    let gi = gr.data[off + ch];
                    s += gi * gi;
                }
                // Guard against all-zero gradients (dead tokens): keep a
                // small floor so k-means still sees every point.
                w.push(s.max(1e-12));
            }
        }
    }
    (pts, w)
}

/// The CQ codec over full KV tensors — used by the perplexity/accuracy
/// harness (Tables 1–4).  Holds separate codebooks conceptually keyed by
/// KvKind inside [`CqCodebooks`].
pub struct CqCodec {
    pub books: CqCodebooks,
    label: String,
}

impl CqCodec {
    pub fn new(books: CqCodebooks) -> CqCodec {
        let label = format!("CQ-{}", books.spec.tag());
        CqCodec { books, label }
    }

    pub fn with_label(books: CqCodebooks, label: &str) -> CqCodec {
        CqCodec { books, label: label.to_string() }
    }
}

impl Codec for CqCodec {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn bits_per_fpn(&self) -> f64 {
        self.books.spec.bits_per_fpn()
    }

    fn apply(&self, kind: KvKind, a: &mut TensorF) {
        let d = KvDims::of(a);
        assert_eq!(d.l, self.books.n_layers);
        assert_eq!(d.h, self.books.n_heads);
        assert_eq!(d.hd, self.books.head_dim);
        let c = self.books.spec.channels;
        let groups = self.books.spec.n_groups(d.hd);
        // Same batch kernel as the serve path: book-major loops with `‖c‖²`
        // precomputed once per codebook, tokens streamed innermost.
        let mut cnorms = Vec::with_capacity(self.books.spec.n_centroids());
        for l in 0..d.l {
            for h in 0..d.h {
                for g in 0..groups {
                    let book = self.books.book(l, kind, h, g);
                    book.centroid_sq_norms_into(&mut cnorms);
                    for b in 0..d.b {
                        for t in 0..d.t {
                            let off = d.vec_off(l, b, h, t) + g * c;
                            let x = &mut a.data[off..off + c];
                            let j = book.assign_with_norms(&*x, &cnorms);
                            x.copy_from_slice(book.centroid(j));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Correlated two-channel activations: ch1 = ch0 + small noise — the
    /// regime where coupling should crush independent quantization.
    fn correlated_kv(l: usize, h: usize, hd: usize, n: usize, seed: u64) -> TensorF {
        let mut rng = Pcg64::seed(seed);
        let mut t = TensorF::zeros(&[l, 1, h, n, hd]);
        for i in 0..t.data.len() / hd {
            let base = rng.normal() as f32;
            for c in 0..hd {
                let corr = base + 0.05 * rng.normal() as f32;
                t.data[i * hd + c] = if c % 2 == 0 { base } else { corr };
            }
        }
        t
    }

    fn learn_books(spec: CqSpec, fisher: bool) -> (CqCodebooks, TensorF, TensorF) {
        let k = correlated_kv(2, 2, 8, 64, 1);
        let v = correlated_kv(2, 2, 8, 64, 2);
        let gk = correlated_kv(2, 2, 8, 64, 3);
        let gv = correlated_kv(2, 2, 8, 64, 4);
        let cfg = LearnCfg { fisher, max_iters: 30, seed: 0 };
        let books = CqCodebooks::learn(spec, &k, &v, Some(&gk), Some(&gv), cfg);
        (books, k, v)
    }

    #[test]
    fn coupling_beats_scalar_at_equal_bits() {
        // 2 bits/FPN budget: CQ-1c2b (scalar) vs CQ-2c4b (coupled).
        let (scalar, k, _) = learn_books(CqSpec::new(1, 2), false);
        let (coupled, _, _) = learn_books(CqSpec::new(2, 4), false);
        let err = |books: CqCodebooks| {
            let codec = CqCodec::new(books);
            let mut kq = k.clone();
            codec.apply(KvKind::Key, &mut kq);
            k.sqdiff(&kq)
        };
        let es = err(scalar);
        let ec = err(coupled);
        assert!(
            ec < es * 0.8,
            "coupled {ec} should beat scalar {es} on correlated channels"
        );
    }

    #[test]
    fn encode_decode_roundtrip_is_fixed_point() {
        let (books, k, _) = learn_books(CqSpec::new(2, 3), false);
        let d = KvDims::of(&k);
        let off = d.vec_off(1, 0, 1, 5);
        let x = &k.data[off..off + d.hd];
        let codes = books.encode_vec(1, KvKind::Key, 1, x);
        assert_eq!(codes.len(), 4);
        let mut decoded = vec![0.0; d.hd];
        books.decode_vec(1, KvKind::Key, 1, &codes, &mut decoded);
        // Re-encoding the decoded vector must give identical codes.
        assert_eq!(books.encode_vec(1, KvKind::Key, 1, &decoded), codes);
    }

    #[test]
    fn export_tensor_matches_books() {
        let (books, _, _) = learn_books(CqSpec::new(4, 2), false);
        let t = books.export_tensor(KvKind::Value);
        assert_eq!(t.shape, vec![2, 2, 2, 4, 4]); // [L,H,G,K,C]
        let c0 = books.book(1, KvKind::Value, 0, 1).centroid(2);
        let off = t.offset(&[1, 0, 1, 2, 0]);
        assert_eq!(&t.data[off..off + 4], c0);
    }

    #[test]
    fn save_load_roundtrip() {
        let (books, k, _) = learn_books(CqSpec::new(2, 4), true);
        let dir = std::env::temp_dir().join("cq_books_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("books.cqb");
        books.save(&p).unwrap();
        let loaded = CqCodebooks::load(&p).unwrap();
        assert_eq!(loaded.spec, books.spec);
        let codec_a = CqCodec::new(books);
        let codec_b = CqCodec::new(loaded);
        let mut ka = k.clone();
        let mut kb = k.clone();
        codec_a.apply(KvKind::Key, &mut ka);
        codec_b.apply(KvKind::Key, &mut kb);
        assert_eq!(ka, kb);
    }

    #[test]
    fn bits_per_fpn_accounting() {
        assert_eq!(CqSpec::new(2, 8).bits_per_fpn(), 4.0);
        assert_eq!(CqSpec::new(4, 8).bits_per_fpn(), 2.0);
        assert_eq!(CqSpec::new(8, 8).bits_per_fpn(), 1.0);
        assert_eq!(CqSpec::new(8, 10).bits_per_fpn(), 1.25);
        assert_eq!(CqSpec::new(8, 10).tag(), "8c10b");
    }

    #[test]
    fn cqspec_table_bits_tag_centroids_groups() {
        // (channels, bits) -> (bits/FPN, tag, 2^b centroids, groups at hd=64)
        let table: [(usize, usize, f64, &str, usize, usize); 6] = [
            (1, 2, 2.0, "1c2b", 4, 64),
            (2, 4, 2.0, "2c4b", 16, 32),
            (2, 8, 4.0, "2c8b", 256, 32),
            (4, 8, 2.0, "4c8b", 256, 16),
            (8, 8, 1.0, "8c8b", 256, 8),
            (8, 10, 1.25, "8c10b", 1024, 8),
        ];
        for (c, b, bpf, tag, k, g) in table {
            let spec = CqSpec::new(c, b);
            assert_eq!(spec.bits_per_fpn(), bpf, "{tag}");
            assert_eq!(spec.tag(), tag);
            assert_eq!(spec.n_centroids(), k, "{tag}");
            assert_eq!(spec.n_groups(64), g, "{tag}");
        }
    }

    #[test]
    fn batch_span_encode_matches_per_token_encode_vec() {
        // The prefill batch kernel (book-major, threaded across layers) must
        // produce exactly the codes the scalar per-token path yields —
        // synthetic random codebooks over random activations.
        let spec = CqSpec::new(2, 4);
        let (l_n, h_n, hd, t_n) = (3usize, 2usize, 8usize, 17usize);
        let books = CqCodebooks::synthetic(spec, l_n, h_n, hd, 7);
        let mut rng = Pcg64::seed(8);
        let mk = |rng: &mut Pcg64| {
            let mut t = TensorF::zeros(&[l_n, 1, h_n, t_n, hd]);
            for x in t.data.iter_mut() {
                *x = rng.normal() as f32;
            }
            t
        };
        let k = mk(&mut rng);
        let v = mk(&mut rng);
        let groups = spec.n_groups(hd);
        let per_side = l_n * h_n * groups;
        // Spans cover the threaded path (>= PARALLEL_MIN_SPAN), the inline
        // small-span path, and the empty span.
        for (t0, t1) in [(0usize, t_n), (3, 11), (9, 11), (5, 5)] {
            let (k_all, v_all) = books.encode_span_parallel(&k, &v, t0, t1);
            assert_eq!(k_all.len(), (t1 - t0) * per_side);
            let d = KvDims::of(&k);
            for (i, t) in (t0..t1).enumerate() {
                let mut want_k = Vec::new();
                let mut want_v = Vec::new();
                for l in 0..l_n {
                    for h in 0..h_n {
                        let off = d.vec_off(l, 0, h, t);
                        want_k.extend(books.encode_vec(l, KvKind::Key, h, &k.data[off..off + hd]));
                        want_v.extend(books.encode_vec(
                            l,
                            KvKind::Value,
                            h,
                            &v.data[off..off + hd],
                        ));
                    }
                }
                assert_eq!(
                    &k_all[i * per_side..(i + 1) * per_side],
                    &want_k[..],
                    "k token {t} (span {t0}..{t1})"
                );
                assert_eq!(
                    &v_all[i * per_side..(i + 1) * per_side],
                    &want_v[..],
                    "v token {t} (span {t0}..{t1})"
                );
            }
        }
    }

    #[test]
    fn pooled_encode_is_byte_identical_for_every_pool_width() {
        use crate::util::workpool::WorkPool;
        // The (layer × token-piece) decomposition must not be observable:
        // any pool width — inline fallback included — yields byte-for-byte
        // the single-thread encode_layer_span_into output.  The 1-layer
        // geometry exercises the layers < threads token-split fan-out.
        let mut rng = Pcg64::seed(31);
        for &(l_n, h_n, hd, t_n) in &[(3usize, 2usize, 8usize, 17usize), (1, 2, 8, 13)] {
            let spec = CqSpec::new(2, 4);
            let books = CqCodebooks::synthetic(spec, l_n, h_n, hd, 7);
            let mk = |rng: &mut Pcg64| {
                let mut t = TensorF::zeros(&[l_n, 1, h_n, t_n, hd]);
                for x in t.data.iter_mut() {
                    *x = rng.normal() as f32;
                }
                t
            };
            let k = mk(&mut rng);
            let v = mk(&mut rng);
            let baseline = books.encode_span_parallel(&k, &v, 0, t_n);
            for threads in [0usize, 2, 3, 5] {
                let pool = WorkPool::new(threads);
                for (t0, t1) in [(0usize, t_n), (3, 11), (9, 11), (5, 5)] {
                    let got = books.encode_span_pooled(&k, &v, t0, t1, &pool);
                    let want = books.encode_span_parallel(&k, &v, t0, t1);
                    assert_eq!(got, want, "L={l_n} threads={threads} span {t0}..{t1}");
                }
                let full = books.encode_span_pooled(&k, &v, 0, t_n, &pool);
                assert_eq!(full, baseline);
                if pool.threads() > 1 {
                    // Fan-out granularity: even a 1-layer model must cut
                    // enough token pieces to cover the pool width.
                    assert!(
                        pool.last_scope_tasks() >= pool.threads() as u64,
                        "L={l_n} threads={threads}: only {} tasks",
                        pool.last_scope_tasks()
                    );
                }
            }
        }
    }

    #[test]
    fn layer_span_kernel_matches_encode_vec_per_layer() {
        let spec = CqSpec::new(4, 3);
        let books = CqCodebooks::synthetic(spec, 2, 3, 8, 21);
        let mut rng = Pcg64::seed(22);
        let mut acts = TensorF::zeros(&[2, 1, 3, 9, 8]);
        for x in acts.data.iter_mut() {
            *x = rng.normal() as f32;
        }
        let d = KvDims::of(&acts);
        let groups = spec.n_groups(8);
        let mut out = vec![0u32; 4 * d.h * groups];
        books.encode_layer_span_into(1, KvKind::Value, &acts, 0, 2, 6, &mut out);
        for (i, t) in (2..6).enumerate() {
            for h in 0..d.h {
                let off = d.vec_off(1, 0, h, t);
                let want = books.encode_vec(1, KvKind::Value, h, &acts.data[off..off + 8]);
                assert_eq!(
                    &out[(i * d.h + h) * groups..(i * d.h + h + 1) * groups],
                    &want[..],
                    "t={t} h={h}"
                );
            }
        }
    }

    #[test]
    fn quantization_error_shrinks_with_bits() {
        let (b2, k, _) = learn_books(CqSpec::new(2, 2), false);
        let (b5, _, _) = learn_books(CqSpec::new(2, 5), false);
        let err = |books: CqCodebooks| {
            let codec = CqCodec::new(books);
            let mut kq = k.clone();
            codec.apply(KvKind::Key, &mut kq);
            k.sqdiff(&kq)
        };
        assert!(err(b5) < err(b2) * 0.6);
    }
}

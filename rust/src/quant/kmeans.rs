//! k-means clustering engine: k-means++ initialization (Arthur &
//! Vassilvitskii 2007) + Lloyd iterations, with optional per-point weights —
//! the optimizer behind both uniform (paper Eq. 5) and Fisher-guided (Eq. 6)
//! centroid learning.
//!
//! Assignment uses the MXU-friendly expansion `||x-c||² = ||x||² - 2x·c +
//! ||c||²` with the `||x||²` term dropped for argmin: [`KMeans::assign`]
//! derives `||c||²` inline, the batched hot path
//! ([`KMeans::assign_batch_into`] / [`KMeans::assign_with_norms`])
//! precomputes it once per codebook via [`KMeans::centroid_sq_norms_into`].
//!
//! # SIMD lane layout and tie-break contract
//!
//! The shared expansion kernel ([`nearest_by_expansion`]) walks the
//! centroid table **8 centroids per iteration**: lane `l` of a block
//! starting at centroid `j0` owns centroid `j0 + l` and accumulates its
//! dot product over channels in ascending `i` order — the *same* float
//! operation sequence (`dot[l] += x[i] * c[i]`, then `‖c‖² - 2·dot`) as
//! the scalar `assign`, so every path agrees bit-for-bit.  The in-block
//! horizontal min keeps the **lowest lane** on equal scores and blocks
//! compare with strict `<` in ascending order, which together reproduce
//! the scalar rule exactly: ties always resolve to the lowest centroid
//! index.  (The tie rule assumes NaN-free scores; centroids are learned
//! from finite activations, and the property tests pin the contract.)
//! Centroid counts that are not a multiple of 8 fall through to a scalar
//! tail over the remainder.  The stable build uses a manually unrolled
//! 8-accumulator block; `--features simd` swaps in the `core::simd`
//! (nightly `portable_simd`) implementation of the same block — both are
//! bit-identical by construction.  The pre-expansion brute-force scan
//! survives as [`KMeans::assign_reference`] for property tests and the
//! `quant_hot_path` bench baseline.

use crate::util::rng::Pcg64;

/// Centroids processed per kernel iteration (one SIMD block).
const LANES: usize = 8;

/// Argmin over `‖c_j‖² - 2·x·c_j` for one point against a centroid table.
/// Shared by the batched entry points and the Lloyd loop; walks the table
/// in 8-centroid blocks ([`block8_scores`]) with a scalar tail, keeping
/// the scalar `assign`'s accumulation order and strict-`<` lowest-index
/// tie rule bit-for-bit (see the module doc for the lane contract).
#[inline]
fn nearest_by_expansion(centroids: &[f32], cnorms: &[f32], dim: usize, x: &[f32]) -> usize {
    debug_assert_eq!(x.len(), dim);
    let k = cnorms.len();
    let mut best = 0usize;
    let mut best_s = f32::INFINITY;
    let blocks = k / LANES;
    for blk in 0..blocks {
        let j0 = blk * LANES;
        let (s, lane) = block8_scores(centroids, cnorms, dim, x, j0);
        // Strict `<` across blocks: an earlier block wins equal scores,
        // and within a block `block8_scores` already kept the lowest lane
        // — so ties resolve to the lowest centroid index overall.
        if s < best_s {
            best_s = s;
            best = j0 + lane;
        }
    }
    for j in blocks * LANES..k {
        let c = &centroids[j * dim..(j + 1) * dim];
        let mut dot = 0.0f32;
        for i in 0..dim {
            dot += x[i] * c[i];
        }
        let s = cnorms[j] - 2.0 * dot;
        if s < best_s {
            best_s = s;
            best = j;
        }
    }
    best
}

/// Score one 8-centroid block against `x`: returns the block's minimum
/// score and the lowest lane achieving it.  Manual unroll (stable Rust):
/// eight independent accumulators break the single serial add chain of the
/// old per-centroid loop, so the compiler can keep 8 FMA pipes busy.  Each
/// lane still adds channel terms in ascending `i` order — bit-identical to
/// the scalar kernel.
#[cfg(not(feature = "simd"))]
#[inline]
fn block8_scores(
    centroids: &[f32],
    cnorms: &[f32],
    dim: usize,
    x: &[f32],
    j0: usize,
) -> (f32, usize) {
    let block = &centroids[j0 * dim..(j0 + LANES) * dim];
    let mut dot = [0.0f32; LANES];
    for (i, &xi) in x.iter().enumerate() {
        for (l, d) in dot.iter_mut().enumerate() {
            *d += xi * block[l * dim + i];
        }
    }
    let mut best_s = f32::INFINITY;
    let mut lane = 0usize;
    for (l, &d) in dot.iter().enumerate() {
        let s = cnorms[j0 + l] - 2.0 * d;
        if s < best_s {
            best_s = s;
            lane = l;
        }
    }
    (best_s, lane)
}

/// `core::simd` variant of the 8-centroid block (nightly `portable_simd`,
/// `--features simd`).  Lane `l` holds centroid `j0 + l`; each step does
/// an element-wise multiply-then-add in ascending channel order, so the
/// per-lane rounding matches the scalar kernel exactly.  The horizontal
/// reduction takes `reduce_min` and then the lowest set lane of the
/// equality mask — the lowest-index tie rule (NaN-free by contract).
#[cfg(feature = "simd")]
#[inline]
fn block8_scores(
    centroids: &[f32],
    cnorms: &[f32],
    dim: usize,
    x: &[f32],
    j0: usize,
) -> (f32, usize) {
    use core::simd::prelude::*;
    let base = j0 * dim;
    let mut dot = Simd::<f32, LANES>::splat(0.0);
    for (i, &xi) in x.iter().enumerate() {
        let lanes: [f32; LANES] = std::array::from_fn(|l| centroids[base + l * dim + i]);
        let c = Simd::from_array(lanes);
        dot = Simd::splat(xi) * c + dot;
    }
    let s = Simd::<f32, LANES>::from_slice(&cnorms[j0..j0 + LANES]) - Simd::splat(2.0) * dot;
    let m = s.reduce_min();
    let lane = s.simd_eq(Simd::splat(m)).to_bitmask().trailing_zeros() as usize;
    (m, lane)
}

/// `‖c_j‖²` for every centroid row of `centroids`, reusing `out`.
#[inline]
fn sq_norms_into(centroids: &[f32], dim: usize, out: &mut Vec<f32>) {
    out.clear();
    for c in centroids.chunks_exact(dim) {
        let mut s = 0.0f32;
        for i in 0..dim {
            s += c[i] * c[i];
        }
        out.push(s);
    }
}

/// Learned centroid table: `k` centroids of dimension `dim`, row-major.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub k: usize,
    pub dim: usize,
    pub centroids: Vec<f32>,
    /// Total weighted quantization error at the final assignment.
    pub inertia: f64,
    /// Lloyd iterations actually executed (early-stops on convergence).
    pub iters_run: usize,
}

impl KMeans {
    #[inline]
    pub fn centroid(&self, j: usize) -> &[f32] {
        &self.centroids[j * self.dim..(j + 1) * self.dim]
    }

    /// Index of the nearest centroid to `x` (L2), via the dot-product
    /// expansion with `‖c‖²` derived inline.  One-off calls only — hot loops
    /// precompute the norms once ([`Self::centroid_sq_norms_into`]) and use
    /// [`Self::assign_with_norms`] / [`Self::assign_batch_into`], which
    /// return bit-identical results.
    pub fn assign(&self, x: &[f32]) -> usize {
        debug_assert_eq!(x.len(), self.dim);
        let mut best = 0usize;
        let mut best_s = f32::INFINITY;
        for j in 0..self.k {
            let c = self.centroid(j);
            let mut dot = 0.0f32;
            let mut cn = 0.0f32;
            for i in 0..self.dim {
                dot += x[i] * c[i];
                cn += c[i] * c[i];
            }
            let s = cn - 2.0 * dot;
            if s < best_s {
                best_s = s;
                best = j;
            }
        }
        best
    }

    /// Fill `out` with `‖c_j‖²` for every centroid — the per-codebook
    /// precompute the batched assignment kernels consume.
    pub fn centroid_sq_norms_into(&self, out: &mut Vec<f32>) {
        sq_norms_into(&self.centroids, self.dim, out);
    }

    /// Nearest centroid to `x` with caller-precomputed squared norms.
    #[inline]
    pub fn assign_with_norms(&self, x: &[f32], cnorms: &[f32]) -> usize {
        debug_assert_eq!(cnorms.len(), self.k);
        nearest_by_expansion(&self.centroids, cnorms, self.dim, x)
    }

    /// Batched assignment: `points` is row-major `[n, dim]`, one code per
    /// point written to `out` (`out.len() == n`).  The centroid table is
    /// streamed once per point with `‖c‖²` amortized across the whole batch
    /// — this is the prefill-encode hot path.
    pub fn assign_batch_into(&self, points: &[f32], cnorms: &[f32], out: &mut [u32]) {
        assert_eq!(points.len(), out.len() * self.dim);
        debug_assert_eq!(cnorms.len(), self.k);
        for (x, o) in points.chunks_exact(self.dim).zip(out.iter_mut()) {
            *o = nearest_by_expansion(&self.centroids, cnorms, self.dim, x) as u32;
        }
    }

    /// Pre-expansion reference: brute-force `(x-c)²` scan.  Kept (not used
    /// on any hot path) as the equivalence oracle for property tests and the
    /// scalar baseline the `quant_hot_path` bench measures against.
    pub fn assign_reference(&self, x: &[f32]) -> usize {
        debug_assert_eq!(x.len(), self.dim);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for j in 0..self.k {
            let c = self.centroid(j);
            let mut d = 0.0f32;
            for i in 0..self.dim {
                let t = x[i] - c[i];
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best = j;
            }
        }
        best
    }

    /// Replace `x` with its nearest centroid; returns the code.
    pub fn quantize_vec(&self, x: &mut [f32]) -> usize {
        let j = self.assign(x);
        x.copy_from_slice(self.centroid(j));
        j
    }
}

/// Configuration for a k-means run.
#[derive(Clone, Copy, Debug)]
pub struct KMeansCfg {
    pub k: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for KMeansCfg {
    fn default() -> Self {
        // The paper runs 100 Lloyd iterations (§4.3); we keep that cap but
        // early-stop when assignments stabilize, which in practice happens
        // far earlier.
        KMeansCfg { k: 16, max_iters: 100, seed: 0 }
    }
}

/// Run (weighted) k-means over `n` points of dimension `dim` stored
/// row-major in `points`.  `weights` (len `n`) biases both the k-means++
/// seeding and the Lloyd updates — passing the diagonal Fisher information
/// yields the paper's Eq. 6 objective; `None` yields uniform Eq. 5.
pub fn kmeans(points: &[f32], n: usize, dim: usize, weights: Option<&[f32]>, cfg: KMeansCfg) -> KMeans {
    assert_eq!(points.len(), n * dim);
    if let Some(w) = weights {
        assert_eq!(w.len(), n);
    }
    assert!(n > 0, "kmeans needs at least one point");
    let k = cfg.k.min(n.max(1));
    let mut rng = Pcg64::seed(cfg.seed);

    let wgt = |i: usize| -> f64 {
        weights.map(|w| (w[i] as f64).max(0.0)).unwrap_or(1.0)
    };
    let pt = |i: usize| -> &[f32] { &points[i * dim..(i + 1) * dim] };

    // --- k-means++ seeding (weighted D² sampling) -----------------------
    let mut centroids = vec![0.0f32; k * dim];
    let first = rng.weighted(&(0..n).map(wgt).collect::<Vec<_>>());
    centroids[..dim].copy_from_slice(pt(first));
    let mut d2 = vec![0.0f64; n]; // weighted distance² to nearest chosen centroid
    for i in 0..n {
        d2[i] = sqdist(pt(i), &centroids[..dim]) * wgt(i);
    }
    for j in 1..k {
        let next = rng.weighted(&d2);
        centroids[j * dim..(j + 1) * dim].copy_from_slice(pt(next));
        for i in 0..n {
            let d = sqdist(pt(i), pt(next)) * wgt(i);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    // --- Lloyd iterations ------------------------------------------------
    let mut assign = vec![0usize; n];
    let mut cnorms = Vec::with_capacity(k);
    let mut iters_run = 0;
    for _ in 0..cfg.max_iters {
        iters_run += 1;
        // Assignment step: batched expansion kernel, norms amortized over
        // the whole point set (no per-iteration centroid clone).
        let mut changed = false;
        sq_norms_into(&centroids, dim, &mut cnorms);
        for i in 0..n {
            let a = nearest_by_expansion(&centroids, &cnorms, dim, pt(i));
            if a != assign[i] {
                assign[i] = a;
                changed = true;
            }
        }
        // Update step (weighted means).
        let mut sums = vec![0.0f64; k * dim];
        let mut wsum = vec![0.0f64; k];
        for i in 0..n {
            let w = wgt(i);
            let a = assign[i];
            wsum[a] += w;
            let p = pt(i);
            for c in 0..dim {
                sums[a * dim + c] += w * p[c] as f64;
            }
        }
        for j in 0..k {
            if wsum[j] > 0.0 {
                for c in 0..dim {
                    centroids[j * dim + c] = (sums[j * dim + c] / wsum[j]) as f32;
                }
            } else {
                // Empty cluster: reseed at the point with the largest
                // weighted error to its current centroid.
                let mut worst = 0usize;
                let mut worst_d = -1.0f64;
                for i in 0..n {
                    let d = sqdist(pt(i), &centroids[assign[i] * dim..assign[i] * dim + dim])
                        * wgt(i);
                    if d > worst_d {
                        worst_d = d;
                        worst = i;
                    }
                }
                centroids[j * dim..(j + 1) * dim].copy_from_slice(pt(worst));
            }
        }
        if !changed && iters_run > 1 {
            break;
        }
    }

    // Final inertia.
    let km = KMeans { k, dim, centroids, inertia: 0.0, iters_run };
    let inertia: f64 = (0..n)
        .map(|i| sqdist(pt(i), km.centroid(km.assign(pt(i)))) * wgt(i))
        .sum();
    KMeans { inertia, ..km }
}

/// Specialized 1-D k-means (scalar non-uniform quantization grids for the
/// KVQuant baseline).  Same semantics as [`kmeans`] with `dim == 1`.
pub fn kmeans_1d(values: &[f32], weights: Option<&[f32]>, cfg: KMeansCfg) -> KMeans {
    kmeans(values, values.len(), 1, weights, cfg)
}

#[inline]
fn sqdist(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Pcg64;

    fn blobs(rng: &mut Pcg64, centers: &[[f32; 2]], per: usize, spread: f64) -> Vec<f32> {
        let mut pts = Vec::new();
        for c in centers {
            for _ in 0..per {
                pts.push(c[0] + (rng.normal() * spread) as f32);
                pts.push(c[1] + (rng.normal() * spread) as f32);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Pcg64::seed(1);
        let centers = [[-10.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let pts = blobs(&mut rng, &centers, 50, 0.3);
        let km = kmeans(&pts, 150, 2, None, KMeansCfg { k: 3, max_iters: 50, seed: 2 });
        // Every true center must be within 0.5 of some learned centroid.
        for c in &centers {
            let best = (0..3)
                .map(|j| {
                    let cc = km.centroid(j);
                    ((cc[0] - c[0]).powi(2) + (cc[1] - c[1]).powi(2)).sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.5, "center {c:?} not recovered (best={best})");
        }
        assert!(km.inertia < 150.0 * 0.5);
    }

    #[test]
    fn k_greater_than_n_is_clamped() {
        let pts = [0.0f32, 0.0, 1.0, 1.0];
        let km = kmeans(&pts, 2, 2, None, KMeansCfg { k: 8, max_iters: 10, seed: 0 });
        assert_eq!(km.k, 2);
        assert!(km.inertia < 1e-9);
    }

    #[test]
    fn weights_pull_centroids() {
        // Two scalar clusters; give one point a huge weight — with k=1 the
        // single centroid must sit near the heavy point.
        let vals = [0.0f32, 0.1, 10.0];
        let w = [1.0f32, 1.0, 1000.0];
        let km = kmeans_1d(&vals, Some(&w), KMeansCfg { k: 1, max_iters: 20, seed: 0 });
        assert!(km.centroids[0] > 9.5, "centroid={}", km.centroids[0]);
    }

    #[test]
    fn fisher_weighting_reduces_weighted_error() {
        let mut rng = Pcg64::seed(3);
        let n = 400;
        let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        // Salient points: the right tail.
        let w: Vec<f32> = vals.iter().map(|&x| if x > 1.0 { 50.0 } else { 1.0 }).collect();
        let cfg = KMeansCfg { k: 4, max_iters: 60, seed: 4 };
        let uni = kmeans_1d(&vals, None, cfg);
        let fis = kmeans_1d(&vals, Some(&w), cfg);
        let werr = |km: &KMeans| -> f64 {
            vals.iter()
                .zip(&w)
                .map(|(&x, &wi)| {
                    let c = km.centroid(km.assign(&[x]))[0];
                    ((x - c) as f64).powi(2) * wi as f64
                })
                .sum()
        };
        assert!(
            werr(&fis) < werr(&uni),
            "fisher={} uniform={}",
            werr(&fis),
            werr(&uni)
        );
    }

    #[test]
    fn quantize_vec_replaces_with_centroid() {
        let pts = [0.0f32, 0.0, 4.0, 4.0];
        let km = kmeans(&pts, 2, 2, None, KMeansCfg { k: 2, max_iters: 10, seed: 0 });
        let mut x = [3.7f32, 4.2];
        let code = km.quantize_vec(&mut x);
        assert_eq!(&x, km.centroid(code));
        assert!((x[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn prop_inertia_never_exceeds_naive_single_centroid() {
        run_prop(15, 7, |rng| {
            let n = 20 + rng.below(60);
            let dim = 1 + rng.below(4);
            let pts: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 3.0).collect();
            let km = kmeans(&pts, n, dim, None, KMeansCfg { k: 4, max_iters: 30, seed: rng.next_u64() });
            // Single-centroid (mean) inertia is an upper bound for k >= 1.
            let mut mean = vec![0.0f32; dim];
            for i in 0..n {
                for c in 0..dim {
                    mean[c] += pts[i * dim + c] / n as f32;
                }
            }
            let naive: f64 = (0..n)
                .map(|i| {
                    (0..dim)
                        .map(|c| ((pts[i * dim + c] - mean[c]) as f64).powi(2))
                        .sum::<f64>()
                })
                .sum();
            if km.inertia <= naive + 1e-6 {
                Ok(())
            } else {
                Err(format!("inertia {} > naive {}", km.inertia, naive))
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::seed(9);
        let pts: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        let cfg = KMeansCfg { k: 8, max_iters: 40, seed: 5 };
        let a = kmeans(&pts, 100, 2, None, cfg);
        let b = kmeans(&pts, 100, 2, None, cfg);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn weighted_runs_are_deterministic_and_seed_sensitive() {
        // Fisher-weighted learning must be exactly reproducible from a seed
        // (EXPERIMENTS.md requires every table regenerate bit-identically)
        // while different seeds explore different k-means++ initializations.
        let mut rng = Pcg64::seed(11);
        let pts: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..150).map(|i| 1.0 + (i % 7) as f32).collect();
        let cfg = KMeansCfg { k: 8, max_iters: 3, seed: 21 };
        let a = kmeans(&pts, 150, 2, Some(&w), cfg);
        let b = kmeans(&pts, 150, 2, Some(&w), cfg);
        assert_eq!(a.centroids, b.centroids, "same seed => identical centroids");
        assert_eq!(a.inertia, b.inertia);
        // max_iters=3 stops before convergence, so different seeding must
        // still be visible in the centroids.
        let c = kmeans(&pts, 150, 2, Some(&w), KMeansCfg { seed: 22, ..cfg });
        assert_ne!(a.centroids, c.centroids, "different seed => different init");
    }

    #[test]
    fn prop_batch_assignment_matches_scalar_assign() {
        // The batched kernel (precomputed ‖c‖², assign_batch_into) must agree
        // bit-for-bit with the scalar `assign` on random codebooks — same
        // expansion, same accumulation order, same tie rule.
        run_prop(30, 41, |rng| {
            let dim = 1 + rng.below(8);
            let k = 1 + rng.below(32);
            let n = 1 + rng.below(120);
            let km = KMeans {
                k,
                dim,
                centroids: (0..k * dim).map(|_| rng.normal() as f32).collect(),
                inertia: 0.0,
                iters_run: 0,
            };
            let pts: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
            let mut cnorms = Vec::new();
            km.centroid_sq_norms_into(&mut cnorms);
            let mut batch = vec![0u32; n];
            km.assign_batch_into(&pts, &cnorms, &mut batch);
            for i in 0..n {
                let x = &pts[i * dim..(i + 1) * dim];
                let scalar = km.assign(x);
                let with_norms = km.assign_with_norms(x, &cnorms);
                if batch[i] as usize != scalar || with_norms != scalar {
                    return Err(format!(
                        "point {i}: batch={} with_norms={with_norms} scalar={scalar}",
                        batch[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ties_resolve_to_lowest_index_in_every_kernel() {
        // Duplicate + mirrored centroids with small-integer coordinates:
        // distances are exact in f32, so all four paths see true ties and
        // must pick the earliest centroid.
        let km = KMeans {
            k: 4,
            dim: 2,
            // c0 == c2 (exact duplicate); c1 and c3 equidistant from origin.
            centroids: vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, -1.0],
            inertia: 0.0,
            iters_run: 0,
        };
        let mut cnorms = Vec::new();
        km.centroid_sq_norms_into(&mut cnorms);
        // Origin ties all four centroids at distance 1.
        let origin = [0.0f32, 0.0];
        assert_eq!(km.assign(&origin), 0);
        assert_eq!(km.assign_with_norms(&origin, &cnorms), 0);
        assert_eq!(km.assign_reference(&origin), 0);
        // A point nearest the duplicated centroid must report the first copy.
        let near_dup = [2.0f32, 0.0];
        assert_eq!(km.assign(&near_dup), 0);
        assert_eq!(km.assign_reference(&near_dup), 0);
        let mut batch = vec![9u32; 2];
        let pts = [0.0f32, 0.0, 2.0, 0.0];
        km.assign_batch_into(&pts, &cnorms, &mut batch);
        assert_eq!(batch, vec![0, 0]);
    }

    #[test]
    fn expansion_matches_reference_on_exact_grids() {
        // Small-integer coordinates: both the naive (x-c)² scan and the
        // expansion compute exact f32 arithmetic, so argmins must coincide
        // everywhere (including tie points, via the shared lowest-index rule).
        let mut rng = Pcg64::seed(99);
        let dim = 3;
        let k = 9;
        let km = KMeans {
            k,
            dim,
            centroids: (0..k * dim).map(|_| (rng.below(7) as f32) - 3.0).collect(),
            inertia: 0.0,
            iters_run: 0,
        };
        for _ in 0..200 {
            let x: Vec<f32> = (0..dim).map(|_| (rng.below(9) as f32) - 4.0).collect();
            assert_eq!(km.assign(&x), km.assign_reference(&x), "x={x:?}");
        }
    }

    /// The pre-block scalar kernel, kept verbatim as the bit-identity
    /// oracle for the 8-lane rewrite: one serial accumulator per centroid,
    /// strict-`<` lowest-index ties.
    fn scalar_expansion(centroids: &[f32], cnorms: &[f32], dim: usize, x: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_s = f32::INFINITY;
        for (j, &cn) in cnorms.iter().enumerate() {
            let c = &centroids[j * dim..(j + 1) * dim];
            let mut dot = 0.0f32;
            for i in 0..dim {
                dot += x[i] * c[i];
            }
            let s = cn - 2.0 * dot;
            if s < best_s {
                best_s = s;
                best = j;
            }
        }
        best
    }

    #[test]
    fn prop_block_kernel_bit_identical_to_scalar_for_any_k_mod_8() {
        // The 8-lane kernel must agree bit-for-bit with the serial scalar
        // expansion on random data for every block/tail decomposition:
        // k = 1 (degenerate, pure tail), k < 8, k % 8 ∈ {0, ±1}, and
        // multi-block tables.  Dims exercise 1, odd, and wider-than-lane.
        for &k in &[1usize, 2, 7, 8, 9, 15, 16, 17, 24, 31, 33] {
            for &dim in &[1usize, 3, 8, 17] {
                run_prop(6, (k * 131 + dim) as u64, |rng| {
                    let km = KMeans {
                        k,
                        dim,
                        centroids: (0..k * dim).map(|_| rng.normal() as f32).collect(),
                        inertia: 0.0,
                        iters_run: 0,
                    };
                    let mut cnorms = Vec::new();
                    km.centroid_sq_norms_into(&mut cnorms);
                    for _ in 0..20 {
                        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                        let blocked = km.assign_with_norms(&x, &cnorms);
                        let scalar = scalar_expansion(&km.centroids, &cnorms, dim, &x);
                        if blocked != scalar {
                            return Err(format!(
                                "k={k} dim={dim}: blocked={blocked} scalar={scalar} x={x:?}"
                            ));
                        }
                    }
                    Ok(())
                });
            }
        }
    }

    #[test]
    fn block_kernel_ties_resolve_to_lowest_index_across_lane_and_block_edges() {
        // Exact duplicate centroids placed to tie (a) within one block,
        // (b) across the block/tail boundary, and (c) across two blocks:
        // the kernel must always report the first copy, like the scalar
        // rule.  k=19 gives two full blocks + a 3-wide tail.
        let (k, dim) = (19usize, 2usize);
        let mut centroids: Vec<f32> = (0..k * dim).map(|i| (i % 11) as f32 - 5.0).collect();
        let dup = |c: &mut Vec<f32>, from: usize, to: usize| {
            let src: Vec<f32> = c[from * dim..(from + 1) * dim].to_vec();
            c[to * dim..(to + 1) * dim].copy_from_slice(&src);
        };
        dup(&mut centroids, 2, 5); // within block 0
        dup(&mut centroids, 9, 14); // block 1 → block 1 (lanes 1 and 6)
        dup(&mut centroids, 3, 17); // block 0 → tail
        let km = KMeans { k, dim, centroids, inertia: 0.0, iters_run: 0 };
        let mut cnorms = Vec::new();
        km.centroid_sq_norms_into(&mut cnorms);
        for probe in [2usize, 9, 3] {
            let x: Vec<f32> = km.centroid(probe).to_vec();
            assert_eq!(
                km.assign_with_norms(&x, &cnorms),
                probe,
                "tie on duplicate of centroid {probe} must keep the first copy"
            );
            assert_eq!(km.assign_with_norms(&x, &cnorms), km.assign(&x));
        }
        // All-identical table: everything ties, index 0 wins.
        let km1 = KMeans {
            k: 17,
            dim: 3,
            centroids: vec![0.5; 17 * 3],
            inertia: 0.0,
            iters_run: 0,
        };
        let mut n1 = Vec::new();
        km1.centroid_sq_norms_into(&mut n1);
        assert_eq!(km1.assign_with_norms(&[9.0, -9.0, 1.0], &n1), 0);
    }

    #[test]
    fn block_kernel_k1_degenerate_case() {
        let km = KMeans {
            k: 1,
            dim: 4,
            centroids: vec![1.0, -2.0, 0.5, 3.0],
            inertia: 0.0,
            iters_run: 0,
        };
        let mut cnorms = Vec::new();
        km.centroid_sq_norms_into(&mut cnorms);
        assert_eq!(km.assign_with_norms(&[0.0, 0.0, 0.0, 0.0], &cnorms), 0);
        let mut out = vec![7u32; 3];
        km.assign_batch_into(&[0.25f32; 12], &cnorms, &mut out);
        assert_eq!(out, vec![0, 0, 0]);
    }

    #[test]
    fn fisher_weighted_and_unweighted_centroids_differ() {
        // Skewed weights must pull the solution away from the uniform
        // (Eq. 5) optimum toward the Fisher (Eq. 6) optimum.
        let mut rng = Pcg64::seed(13);
        let vals: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = vals
            .iter()
            .map(|&x| if x > 0.5 { 100.0 } else { 1.0 })
            .collect();
        let cfg = KMeansCfg { k: 4, max_iters: 60, seed: 3 };
        let uni = kmeans_1d(&vals, None, cfg);
        let fis = kmeans_1d(&vals, Some(&w), cfg);
        assert_ne!(uni.centroids, fis.centroids, "weights must matter");
        // And the weighted run allocates its centroid mass to the right
        // tail: its largest centroid sits above the unweighted one's mean.
        let maxc = |km: &KMeans| {
            km.centroids.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        };
        assert!(maxc(&fis) >= maxc(&uni) - 0.25, "fis={} uni={}", maxc(&fis), maxc(&uni));
    }
}

//! Eval-path realization of a [`PolicyDescriptor`]: a [`Codec`] that
//! applies per-layer sub-codecs and then restores the policy's
//! full-precision spans (sink prefix + trailing window), so `eval/ppl.rs`
//! measures exactly what a windowed policy serves — quantized history,
//! pristine recent tokens.
//!
//! Quantization runs over the *full* token series first (scalar key codecs
//! scale per channel across all tokens, matching how a serving cache's
//! quantizer sees the whole retired history) and the fp spans are restored
//! afterwards from a snapshot; this makes quantize-then-restore
//! byte-identical to plain quantization outside the window, the same
//! invariant the paged pool's retire path holds by construction.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::calib::CalibData;
use crate::quant::factory::{self, FactoryCfg};
use crate::quant::{Codec, KvDims, KvKind};
use crate::tensor::TensorF;

use super::{BitOption, PolicyDescriptor};

fn refresh(fcfg: &FactoryCfg) -> FactoryCfg {
    FactoryCfg { fisher: fcfg.fisher, max_iters: fcfg.max_iters, seed: fcfg.seed }
}

/// Build allocator menu rungs from factory rows, reading each rung's
/// bits/FPN off the built codec so accounting can never drift from the
/// codec's own overhead math.
pub fn menu_from_rows(
    rows: &[&str],
    calib: Option<&CalibData>,
    fcfg: &FactoryCfg,
) -> Result<Vec<BitOption>> {
    rows.iter()
        .map(|r| {
            let c = factory::build_codec(r, calib, refresh(fcfg))?;
            Ok(BitOption { codec: r.to_string(), bits: c.bits_per_fpn() })
        })
        .collect()
}

/// A policy rendered into runnable codecs: the base codec plus per-layer
/// overrides, with fp retention applied post-hoc.
pub struct PolicyCodec {
    desc: PolicyDescriptor,
    default_codec: Box<dyn Codec>,
    overrides: BTreeMap<usize, Box<dyn Codec>>,
    /// Context length the bits/FPN report amortizes the fp window over;
    /// 0 reports the asymptotic (long-context) rate.
    amortize_tokens: usize,
}

/// Build the codec for `desc`.  A plain table row (no retention, no layer
/// overrides) returns the factory codec directly — the policy layer adds
/// zero overhead when it has nothing to say.
pub fn build_policy_codec(
    desc: &PolicyDescriptor,
    calib: Option<&CalibData>,
    fcfg: FactoryCfg,
    amortize_tokens: usize,
) -> Result<Box<dyn Codec>> {
    if desc.base == "sim" {
        bail!(
            "policy '{}': base 'sim' is the serve-only pseudo-codec; eval needs a real \
             factory row",
            desc.name
        );
    }
    let default_codec = factory::build_codec(&desc.base, calib, refresh(&fcfg))?;
    let mut overrides = BTreeMap::new();
    for a in &desc.layers {
        overrides.insert(a.layer, factory::build_codec(&a.codec, calib, refresh(&fcfg))?);
    }
    if overrides.is_empty() && desc.retention().is_none() {
        return Ok(default_codec);
    }
    Ok(Box::new(PolicyCodec { desc: desc.clone(), default_codec, overrides, amortize_tokens }))
}

impl Codec for PolicyCodec {
    fn name(&self) -> String {
        self.desc.name.clone()
    }

    /// Mean quantized bits/FPN across layers, blended with 16-bit fp spans
    /// when `amortize_tokens` gives a context length to amortize over.
    /// With per-layer overrides the mean runs over the assignments (the
    /// allocator emits one per layer).
    fn bits_per_fpn(&self) -> f64 {
        let q = if self.overrides.is_empty() {
            self.default_codec.bits_per_fpn()
        } else {
            let sum: f64 = self.overrides.values().map(|c| c.bits_per_fpn()).sum();
            sum / self.overrides.len() as f64
        };
        if self.amortize_tokens == 0 {
            return q;
        }
        let t = self.amortize_tokens as f64;
        let f = self.desc.fp_resident_tokens(self.amortize_tokens) as f64;
        (q * (t - f) + 16.0 * f) / t
    }

    fn apply(&self, kind: KvKind, a: &mut TensorF) {
        let d = KvDims::of(a);
        let s = self.desc.sinks.min(d.t);
        let w = self.desc.window.min(d.t - s);
        let orig = (w + s > 0).then(|| a.clone());
        if self.overrides.is_empty() {
            self.default_codec.apply(kind, a);
        } else {
            for l in 0..d.l {
                let mut lay = slice_layer(a, l);
                self.overrides
                    .get(&l)
                    .unwrap_or(&self.default_codec)
                    .apply(kind, &mut lay);
                paste_layer(a, &lay, l);
            }
        }
        // Restore the fp spans: first `s` sink tokens + trailing `w`.
        if let Some(orig) = orig {
            for l in 0..d.l {
                for b in 0..d.b {
                    for h in 0..d.h {
                        for t in (0..s).chain(d.t - w..d.t) {
                            let off = d.vec_off(l, b, h, t);
                            a.data[off..off + d.hd]
                                .copy_from_slice(&orig.data[off..off + d.hd]);
                        }
                    }
                }
            }
        }
    }
}

/// Extract layer `l` of `[L,B,H,T,hd]` as a `[1,B,H,T,hd]` tensor.
fn slice_layer(src: &TensorF, l: usize) -> TensorF {
    let per = src.numel() / src.shape[0];
    let mut shape = src.shape.clone();
    shape[0] = 1;
    TensorF::from_vec(&shape, src.data[l * per..(l + 1) * per].to_vec()).unwrap()
}

/// Write a `[1,B,H,T,hd]` layer slice into layer `l` of `dst`.
fn paste_layer(dst: &mut TensorF, src: &TensorF, l: usize) {
    let per = dst.numel() / dst.shape[0];
    assert_eq!(src.numel(), per);
    dst.data[l * per..(l + 1) * per].copy_from_slice(&src.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::policy::LayerAssignment;

    fn wavy(shape: &[usize]) -> TensorF {
        let n = crate::tensor::numel(shape);
        TensorF::from_vec(
            shape,
            (0..n).map(|i| ((i * 37) % 101) as f32 / 7.0 - 5.0).collect(),
        )
        .unwrap()
    }

    fn plain(row: &str) -> Box<dyn Codec> {
        factory::build_codec(row, None, FactoryCfg::default()).unwrap()
    }

    fn token_span(a: &TensorF, t: usize) -> Vec<f32> {
        let d = KvDims::of(a);
        let mut out = Vec::new();
        for l in 0..d.l {
            for b in 0..d.b {
                for h in 0..d.h {
                    let off = d.vec_off(l, b, h, t);
                    out.extend_from_slice(&a.data[off..off + d.hd]);
                }
            }
        }
        out
    }

    #[test]
    fn window_and_sink_tokens_survive_apply_bit_exact() {
        let desc = PolicyDescriptor::parse("int2-w2-s1").unwrap();
        let codec = build_policy_codec(&desc, None, FactoryCfg::default(), 0).unwrap();
        let orig = wavy(&[2, 1, 2, 6, 4]);
        let mut a = orig.clone();
        codec.apply(KvKind::Key, &mut a);
        // fp spans: sink token 0 and trailing tokens 4, 5.
        for t in [0usize, 4, 5] {
            assert_eq!(token_span(&a, t), token_span(&orig, t), "token {t} must stay fp");
        }
        // The retired middle is byte-identical to plain quantization: the
        // policy quantizes the full series then restores, so scales match.
        let mut direct = orig.clone();
        plain("int2").apply(KvKind::Key, &mut direct);
        for t in 1..4 {
            assert_eq!(token_span(&a, t), token_span(&direct, t), "retired token {t}");
        }
        assert_ne!(a.data, orig.data, "something must actually quantize");
    }

    #[test]
    fn short_sequences_stay_entirely_fp() {
        let desc = PolicyDescriptor::parse("int2-w4-s2").unwrap();
        let codec = build_policy_codec(&desc, None, FactoryCfg::default(), 0).unwrap();
        let orig = wavy(&[1, 1, 1, 3, 4]); // 3 tokens < window + sinks
        let mut a = orig.clone();
        codec.apply(KvKind::Value, &mut a);
        assert_eq!(a.data, orig.data);
    }

    #[test]
    fn per_layer_overrides_route_each_layer_to_its_codec() {
        let mut desc = PolicyDescriptor::parse("int2").unwrap();
        desc.layers = vec![
            LayerAssignment { layer: 1, codec: "fp16".into(), bits: 16.0 },
        ];
        let codec = build_policy_codec(&desc, None, FactoryCfg::default(), 0).unwrap();
        let orig = wavy(&[2, 1, 2, 5, 4]);
        let mut a = orig.clone();
        codec.apply(KvKind::Value, &mut a);
        let per = orig.numel() / 2;
        assert_eq!(a.data[per..], orig.data[per..], "fp16 override leaves layer 1 alone");
        // Layer 0 falls through to the base codec.
        let mut direct = slice_layer(&orig, 0);
        plain("int2").apply(KvKind::Value, &mut direct);
        assert_eq!(a.data[..per], direct.data[..], "layer 0 quantized by the base");
    }

    #[test]
    fn bits_per_fpn_amortizes_the_fp_window() {
        let q = plain("int2").bits_per_fpn();
        let desc = PolicyDescriptor::parse("int2-w8").unwrap();
        let asym = build_policy_codec(&desc, None, FactoryCfg::default(), 0).unwrap();
        assert!((asym.bits_per_fpn() - q).abs() < 1e-12, "asymptotic = base rate");
        let amort = build_policy_codec(&desc, None, FactoryCfg::default(), 16).unwrap();
        let want = (q * 8.0 + 16.0 * 8.0) / 16.0;
        assert!((amort.bits_per_fpn() - want).abs() < 1e-12);
        assert_eq!(amort.name(), "int2-w8");
    }

    #[test]
    fn plain_rows_pass_through_unwrapped() {
        let desc = PolicyDescriptor::parse("int4").unwrap();
        let codec = build_policy_codec(&desc, None, FactoryCfg::default(), 0).unwrap();
        let reference = plain("int4");
        assert_eq!(codec.bits_per_fpn(), reference.bits_per_fpn());
        let mut a = wavy(&[1, 1, 2, 4, 4]);
        let mut b = a.clone();
        codec.apply(KvKind::Key, &mut a);
        reference.apply(KvKind::Key, &mut b);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn menu_from_rows_reads_bits_off_the_built_codecs() {
        let menu =
            menu_from_rows(crate::quant::policy::DEFAULT_MENU_ROWS, None, &FactoryCfg::default())
                .unwrap();
        assert_eq!(menu.len(), 4);
        assert_eq!(menu.last().unwrap().bits, 16.0, "fp16 rung is exact");
        assert!(menu[0].bits < menu.last().unwrap().bits, "ladder actually climbs");
        assert!(menu_from_rows(&["not-a-row"], None, &FactoryCfg::default()).is_err());
        // sim never builds an eval codec.
        let sim = PolicyDescriptor::parse("sim").unwrap();
        assert!(build_policy_codec(&sim, None, FactoryCfg::default(), 0).is_err());
    }
}

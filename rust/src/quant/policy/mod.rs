//! Adaptive quantization policies: *which codec at which precision* applies
//! to each (layer, position) cell of the KV cache.
//!
//! The codec zoo (`quant/{cq,intq,nf,kvquant}.rs`, rows named by
//! [`crate::quant::factory::table_rows`]) answers "how do I quantize a
//! tensor"; this module answers the serving-side questions layered on top:
//!
//! * **Per-layer bit allocation** — "Cache Me If You Must"-style: score each
//!   layer's sensitivity from `eval/ppl.rs` nll deltas
//!   ([`crate::eval::layer_sensitivity`]) and let [`greedy_allocate`] spend
//!   a bits-per-layer budget where it buys the most quality.
//! * **Full-precision retention** — SKVQ-style: the trailing `window`
//!   tokens plus the first `sinks` attention-sink tokens stay fp16 and are
//!   quantized-on-retire into the paged block pool as they age out
//!   (`kvcache/paged/` holds the retire protocol; DESIGN.md §5 documents
//!   it).
//! * **Per-tenant policies on the wire** — a [`PolicyDescriptor`] names a
//!   complete configuration; requests carry `"policy": "<name>"` (protocol
//!   v2.3) so one pool serves 1-bit CQ and fp16 tenants side by side, each
//!   admitted against *its own* bytes-per-token
//!   ([`PolicyDescriptor::reserve_bytes`]), not a pool-wide constant.
//!
//! Descriptor syntax: `<base>[-w<window>][-s<sinks>]` where `<base>` is any
//! factory table row (or the serve-only pseudo-codec `sim`), e.g.
//! `cq-8c8b-w64-s4` = 1-bit CQ with a 64-token fp window and 4 sink tokens.
//! `fp16` never takes a retention suffix (it is already full precision).
//! Descriptors serialize to JSON both ways ([`PolicyDescriptor::to_json`] /
//! [`PolicyDescriptor::from_json`]) so allocator output is a storable,
//! wire-shippable artifact.

pub mod codec;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::quant::factory;
use crate::util::json::Json;

/// Full-precision retention geometry of a policy: the trailing `window`
/// tokens and the first `sinks` tokens stay unquantized in the cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Retention {
    /// Trailing tokens held at full precision; quantized-on-retire as they
    /// age past the window.
    pub window: usize,
    /// Leading attention-sink tokens held at full precision forever.
    pub sinks: usize,
}

/// One layer's codec assignment from the calibration-time allocator.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAssignment {
    pub layer: usize,
    /// Factory table row applied to this layer.
    pub codec: String,
    /// That codec's bits/FPN (cached so accounting needs no rebuild).
    pub bits: f64,
}

/// A named, complete quantization policy: base codec, retention window,
/// and optional per-layer overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyDescriptor {
    /// Policy name as requested on the wire / CLI (the parsed spec string).
    pub name: String,
    /// Base codec: a factory table row, `fp16`, or the serve-only `sim`.
    pub base: String,
    pub window: usize,
    pub sinks: usize,
    /// Per-layer overrides (allocator output); empty = uniform `base`.
    pub layers: Vec<LayerAssignment>,
}

/// Base names valid in a policy spec beyond the factory table: `sim` is the
/// deterministic engine-free serve backend (codes are fabricated, so any
/// quantized-side policy is servable on it).
const EXTRA_BASES: &[&str] = &["sim"];

fn known_base(name: &str) -> bool {
    EXTRA_BASES.contains(&name) || factory::table_rows().iter().any(|r| *r == name)
}

impl PolicyDescriptor {
    /// Parse `<base>[-w<N>][-s<M>]` (suffixes in either order, each at most
    /// once); `<base>` must be a factory table row or `sim`.
    pub fn parse(spec: &str) -> Result<PolicyDescriptor> {
        let full = spec.trim().to_ascii_lowercase();
        if full.is_empty() {
            bail!("empty policy spec");
        }
        let mut base = full.as_str();
        let (mut window, mut sinks) = (None::<usize>, None::<usize>);
        // Peel retention suffixes off the right; table rows themselves never
        // end in `-w<digits>` / `-s<digits>` so this cannot eat a base name.
        loop {
            let Some((head, tail)) = base.rsplit_once('-') else { break };
            let parsed = match tail.strip_prefix('w') {
                Some(d) if !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit()) => {
                    if window.is_some() {
                        bail!("policy '{full}': duplicate -w suffix");
                    }
                    window = Some(d.parse()?);
                    true
                }
                _ => false,
            };
            let parsed = parsed
                || match tail.strip_prefix('s') {
                    Some(d) if !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit()) => {
                        if sinks.is_some() {
                            bail!("policy '{full}': duplicate -s suffix");
                        }
                        sinks = Some(d.parse()?);
                        true
                    }
                    _ => false,
                };
            if !parsed {
                break;
            }
            base = head;
        }
        if !known_base(base) {
            bail!(
                "policy '{full}': unknown base codec '{base}' (expected a \
                 factory table row or 'sim')"
            );
        }
        let (window, sinks) = (window.unwrap_or(0), sinks.unwrap_or(0));
        if base == "fp16" && (window > 0 || sinks > 0) {
            bail!("policy '{full}': fp16 is already full precision; drop the -w/-s suffix");
        }
        Ok(PolicyDescriptor {
            name: full.clone(),
            base: base.to_string(),
            window,
            sinks,
            layers: Vec::new(),
        })
    }

    /// A full-precision tenant (served unstored, fp16 bytes end to end).
    pub fn is_fp(&self) -> bool {
        self.base == "fp16"
    }

    pub fn retention(&self) -> Option<Retention> {
        (self.window > 0 || self.sinks > 0)
            .then_some(Retention { window: self.window, sinks: self.sinks })
    }

    /// Tokens of a `len`-token cache resident at full precision: the sink
    /// prefix plus the trailing window (the whole sequence while it is
    /// shorter than both combined).
    pub fn fp_resident_tokens(&self, len: usize) -> usize {
        if self.is_fp() {
            return len;
        }
        len.min(self.window + self.sinks)
    }

    /// Peak cache bytes a `tokens`-token sequence costs under this policy,
    /// given the pool's quantized and fp16 per-token byte rates.  This is
    /// the per-request replacement for the old pool-wide
    /// `bytes_per_token` admission constant: an fp16 tenant is charged fp16
    /// math, a windowed tenant is charged fp16 for its resident window +
    /// sinks and quantized bytes for the retired remainder.
    ///
    /// Per-layer overrides deliberately do **not** change this estimate:
    /// the serve pool packs at its one wire geometry; overrides shape the
    /// eval-side quality curve ([`codec::PolicyCodec`]), not the pool's
    /// block math.
    pub fn reserve_bytes(&self, tokens: usize, q_bpt: u64, fp_bpt: u64) -> u64 {
        let fp = self.fp_resident_tokens(tokens) as u64;
        let q = tokens as u64 - fp;
        q * q_bpt + fp * fp_bpt
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("layer", Json::Num(a.layer as f64)),
                    ("codec", Json::Str(a.codec.clone())),
                    ("bits", Json::Num(a.bits)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("base", Json::Str(self.base.clone())),
            ("window", Json::Num(self.window as f64)),
            ("sinks", Json::Num(self.sinks as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PolicyDescriptor> {
        let base = j.req("name")?; // presence check first for a clear error
        let _ = base;
        let layers = match j.get("layers") {
            None | Some(Json::Null) => Vec::new(),
            Some(arr) => arr
                .as_arr()
                .context("'layers' must be an array")?
                .iter()
                .map(|a| {
                    Ok(LayerAssignment {
                        layer: a
                            .get("layer")
                            .and_then(Json::as_usize)
                            .context("layer assignment needs a 'layer' index")?,
                        codec: a.str_or("codec", ""),
                        bits: a.num_or("bits", 0.0),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        let d = PolicyDescriptor {
            name: j.str_or("name", ""),
            base: j.str_or("base", ""),
            window: j.num_or("window", 0.0) as usize,
            sinks: j.num_or("sinks", 0.0) as usize,
            layers,
        };
        if !known_base(&d.base) {
            bail!("policy descriptor '{}': unknown base codec '{}'", d.name, d.base);
        }
        Ok(d)
    }
}

/// One rung of the allocator's codec menu.
#[derive(Clone, Debug, PartialEq)]
pub struct BitOption {
    pub codec: String,
    pub bits: f64,
}

impl BitOption {
    pub fn new(codec: &str, bits: f64) -> BitOption {
        BitOption { codec: codec.into(), bits }
    }
}

/// The default allocator menu rows: the calibration-free precision ladder
/// (CQ rows need learned codebooks per spec, so the scalar ladder is what a
/// menu can always climb).  Bits/FPN come from the built codecs at
/// allocation time ([`codec::menu_from_rows`]), never hand-typed.
pub const DEFAULT_MENU_ROWS: &[&str] = &["int2", "nf4", "int4", "fp16"];

/// Greedily assign per-layer codecs under a mean bits-per-layer budget.
///
/// Every layer starts at the cheapest menu rung; while budget remains, the
/// most sensitive layer that can still climb one rung does so (ties break
/// toward the layer currently holding fewer bits, then the lower index, so
/// uniform sensitivity spreads bits evenly instead of maxing layer 0).
/// Deterministic: same inputs, same assignment.
pub fn greedy_allocate(
    sensitivity: &[f64],
    menu: &[BitOption],
    budget_bits_per_layer: f64,
) -> Vec<LayerAssignment> {
    assert!(!menu.is_empty(), "allocator needs a non-empty codec menu");
    let mut menu = menu.to_vec();
    menu.sort_by(|a, b| a.bits.total_cmp(&b.bits));
    let l_n = sensitivity.len();
    let budget = budget_bits_per_layer * l_n as f64;
    let mut rung = vec![0usize; l_n];
    let mut spent = l_n as f64 * menu[0].bits;
    loop {
        let mut best: Option<usize> = None;
        for l in 0..l_n {
            if rung[l] + 1 >= menu.len() {
                continue;
            }
            let delta = menu[rung[l] + 1].bits - menu[rung[l]].bits;
            if spent + delta > budget + 1e-9 {
                continue;
            }
            best = match best {
                None => Some(l),
                Some(b) => {
                    let better = sensitivity[l] > sensitivity[b]
                        || (sensitivity[l] == sensitivity[b] && rung[l] < rung[b]);
                    Some(if better { l } else { b })
                }
            };
        }
        match best {
            Some(l) => {
                spent += menu[rung[l] + 1].bits - menu[rung[l]].bits;
                rung[l] += 1;
            }
            None => break,
        }
    }
    (0..l_n)
        .map(|l| LayerAssignment {
            layer: l,
            codec: menu[rung[l]].codec.clone(),
            bits: menu[rung[l]].bits,
        })
        .collect()
}

/// The set of policies one pool serves, keyed by spec name.  Built once
/// from `--policies a,b,c`; the router and every worker share it.
#[derive(Clone, Debug, Default)]
pub struct PolicyTable {
    map: BTreeMap<String, PolicyDescriptor>,
}

impl PolicyTable {
    pub fn build(specs: &[String]) -> Result<PolicyTable> {
        let mut map = BTreeMap::new();
        for spec in specs {
            let d = PolicyDescriptor::parse(spec)?;
            if map.insert(d.name.clone(), d).is_some() {
                bail!("duplicate policy '{}' in --policies", spec.trim().to_ascii_lowercase());
            }
        }
        Ok(PolicyTable { map })
    }

    pub fn get(&self, name: &str) -> Option<&PolicyDescriptor> {
        self.map.get(&name.trim().to_ascii_lowercase())
    }

    pub fn names(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_table_rows_and_retention_suffixes() {
        let p = PolicyDescriptor::parse("cq-8c8b").unwrap();
        assert_eq!((p.base.as_str(), p.window, p.sinks), ("cq-8c8b", 0, 0));
        assert!(p.retention().is_none());

        let p = PolicyDescriptor::parse("CQ-8c8b-w64-s4").unwrap();
        assert_eq!(p.name, "cq-8c8b-w64-s4", "name keeps the full lowercased spec");
        assert_eq!((p.base.as_str(), p.window, p.sinks), ("cq-8c8b", 64, 4));
        assert_eq!(p.retention(), Some(Retention { window: 64, sinks: 4 }));

        // Suffix order is free; grouped-scalar rows keep their -gs tail.
        let p = PolicyDescriptor::parse("int4-gs128-s2-w8").unwrap();
        assert_eq!((p.base.as_str(), p.window, p.sinks), ("int4-gs128", 8, 2));

        // kvquant rows with the -1% tail parse too.
        let p = PolicyDescriptor::parse("kvquant-2b-1%-w16").unwrap();
        assert_eq!((p.base.as_str(), p.window), ("kvquant-2b-1%", 16));

        let p = PolicyDescriptor::parse("sim-w4").unwrap();
        assert_eq!(p.base, "sim");

        assert!(PolicyDescriptor::parse("fp16").unwrap().is_fp());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in ["", "notacodec", "cq-9c9b", "fp16-w4", "fp16-s1", "int4-w2-w3"] {
            assert!(PolicyDescriptor::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn descriptor_json_roundtrip() {
        let mut d = PolicyDescriptor::parse("cq-8c8b-w32-s2").unwrap();
        d.layers = vec![
            LayerAssignment { layer: 0, codec: "int8".into(), bits: 8.5 },
            LayerAssignment { layer: 1, codec: "int2".into(), bits: 2.5 },
        ];
        let line = d.to_json().dump();
        let back = PolicyDescriptor::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, d, "JSON roundtrip must be lossless");
        // A layer-free descriptor roundtrips too (layers may be absent).
        let plain = PolicyDescriptor::parse("fp16").unwrap();
        let back =
            PolicyDescriptor::from_json(&Json::parse(&plain.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, plain);
        // Unknown bases are rejected on the way back in.
        let mut j = d.to_json();
        if let Json::Obj(ref mut m) = j {
            m.insert("base".into(), Json::Str("mystery".into()));
        }
        assert!(PolicyDescriptor::from_json(&j).is_err());
    }

    #[test]
    fn reserve_bytes_is_per_policy_math() {
        let (q, fp) = (4u64, 64u64);
        let cq = PolicyDescriptor::parse("cq-8c8b").unwrap();
        assert_eq!(cq.reserve_bytes(100, q, fp), 400, "plain policy: all quantized");
        let f = PolicyDescriptor::parse("fp16").unwrap();
        assert_eq!(f.reserve_bytes(100, q, fp), 6400, "fp tenant: fp16 math");
        let w = PolicyDescriptor::parse("cq-8c8b-w10-s2").unwrap();
        // 12 resident fp tokens + 88 retired quantized tokens.
        assert_eq!(w.reserve_bytes(100, q, fp), 88 * 4 + 12 * 64);
        // Shorter than window+sinks: everything is still fp-resident.
        assert_eq!(w.fp_resident_tokens(7), 7);
        assert_eq!(w.reserve_bytes(7, q, fp), 7 * 64);
    }

    #[test]
    fn greedy_allocator_spends_budget_on_sensitive_layers() {
        let menu = vec![
            BitOption::new("int2", 2.0),
            BitOption::new("int4", 4.0),
            BitOption::new("int8", 8.0),
        ];
        // Layer 2 is by far the most sensitive; budget of 4 bits/layer over
        // 3 layers = 12 bits total.
        let out = greedy_allocate(&[0.1, 0.2, 5.0], &menu, 4.0);
        assert_eq!(out.len(), 3);
        assert_eq!(out[2].codec, "int8", "most sensitive layer gets the most bits");
        let total: f64 = out.iter().map(|a| a.bits).sum();
        assert!(total <= 12.0 + 1e-9, "budget respected, got {total}");
        // Sensitivity order is respected in the assignment.
        assert!(out[2].bits >= out[1].bits && out[1].bits >= out[0].bits);

        // Budget at the floor: everyone gets the cheapest rung.
        let floor = greedy_allocate(&[1.0, 2.0], &menu, 2.0);
        assert!(floor.iter().all(|a| a.codec == "int2"));

        // Budget above the ceiling: everyone maxes out.
        let ceil = greedy_allocate(&[1.0, 2.0], &menu, 100.0);
        assert!(ceil.iter().all(|a| a.codec == "int8"));

        // Uniform sensitivity spreads evenly instead of maxing layer 0.
        let even = greedy_allocate(&[1.0, 1.0, 1.0, 1.0], &menu, 4.0);
        assert!(even.iter().all(|a| a.codec == "int4"), "{even:?}");

        // Determinism.
        assert_eq!(greedy_allocate(&[0.3, 0.7], &menu, 5.0), greedy_allocate(&[0.3, 0.7], &menu, 5.0));
    }

    #[test]
    fn policy_table_builds_and_rejects_duplicates() {
        let t = PolicyTable::build(&["cq-8c8b".into(), "fp16".into(), "cq-8c8b-w16".into()])
            .unwrap();
        assert_eq!(t.names(), vec!["cq-8c8b", "cq-8c8b-w16", "fp16"]);
        assert!(t.get("FP16").is_some(), "lookup is case-insensitive");
        assert!(t.get("nope").is_none());
        assert!(PolicyTable::build(&["fp16".into(), "FP16".into()]).is_err(), "dup");
        assert!(PolicyTable::build(&["wat".into()]).is_err(), "unknown base");
        assert!(PolicyTable::default().is_empty());
    }
}

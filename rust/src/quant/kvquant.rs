//! KVQuant-style baseline (Hooper et al. 2024): sensitivity-weighted
//! non-uniform quantization with optional dense-and-sparse outliers.
//!
//! * Keys (pre-RoPE): per-channel non-uniform grids — a 1-D Fisher-weighted
//!   k-means per (layer, head, channel) learned on calibration data.
//! * Values: per-token normalization (absmax) + a shared per-layer
//!   non-uniform grid over normalized magnitudes.
//! * `-1%` variants: the top-fraction magnitude outliers (threshold taken
//!   from calibration quantiles per layer/kind) are kept exact, modelling
//!   the paper's sparse fp16 side-band; accounting adds 32 bits (value +
//!   index) per outlier → +0.32 bits/FPN at 1 %.

use super::kmeans::{kmeans_1d, KMeans, KMeansCfg};
use super::{for_each_vec, gather_channel, scatter_channel, Codec, KvDims, KvKind};
use crate::tensor::TensorF;

pub struct KvQuant {
    pub bits: u32,
    /// Fraction of outliers stored exactly (0.0 = dense-only).
    pub outlier_frac: f64,
    dims: KvDims,
    /// `[l][h][ch]` scalar grids for keys.
    key_books: Vec<KMeans>,
    /// `[l]` shared normalized-value grids.
    val_books: Vec<KMeans>,
    /// `[l]` |x| outlier thresholds per kind, from calibration quantiles.
    key_thresh: Vec<f32>,
    val_thresh: Vec<f32>,
}

impl KvQuant {
    /// Learn grids on calibration activations (and gradients for Fisher
    /// weighting, as in KVQuant's sensitivity-weighted objective).
    pub fn learn(
        bits: u32,
        outlier_frac: f64,
        k: &TensorF,
        v: &TensorF,
        gk: Option<&TensorF>,
        gv: Option<&TensorF>,
        max_iters: usize,
        seed: u64,
    ) -> KvQuant {
        let d = KvDims::of(k);
        let kcfg = |s: u64| KMeansCfg { k: 1 << bits, max_iters, seed: s };

        let key_thresh = (0..d.l).map(|l| quantile_abs(k, l, 1.0 - outlier_frac)).collect();
        let val_thresh = (0..d.l).map(|l| quantile_abs(v, l, 1.0 - outlier_frac)).collect();

        let mut key_books = Vec::with_capacity(d.l * d.h * d.hd);
        for l in 0..d.l {
            for h in 0..d.h {
                for ch in 0..d.hd {
                    let vals = gather_channel(k, l, h, ch);
                    let w: Option<Vec<f32>> = gk.map(|g| {
                        gather_channel(g, l, h, ch)
                            .iter()
                            .map(|x| (x * x).max(1e-12))
                            .collect()
                    });
                    key_books.push(kmeans_1d(
                        &vals,
                        w.as_deref(),
                        kcfg(seed.wrapping_add(((l * d.h + h) * d.hd + ch) as u64)),
                    ));
                }
            }
        }

        // Values: collect per-token-normalized entries per layer.
        let mut val_books = Vec::with_capacity(d.l);
        for l in 0..d.l {
            let mut normed = Vec::new();
            let mut w = Vec::new();
            for b in 0..d.b {
                for h in 0..d.h {
                    for t in 0..d.t {
                        let off = d.vec_off(l, b, h, t);
                        let tok = &v.data[off..off + d.hd];
                        let s = tok.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                        if s == 0.0 {
                            continue;
                        }
                        for ch in 0..d.hd {
                            normed.push(tok[ch] / s);
                            // Error in original space scales by s: weight by
                            // (g·s)² when gradients are available.
                            let gw = gv
                                .map(|g| g.data[off + ch])
                                .unwrap_or(1.0);
                            w.push(((gw * s) * (gw * s)).max(1e-12));
                        }
                    }
                }
            }
            let wopt = if gv.is_some() { Some(w.as_slice()) } else { None };
            val_books.push(kmeans_1d(&normed, wopt, kcfg(seed.wrapping_add(7777 + l as u64))));
        }

        KvQuant {
            bits,
            outlier_frac,
            dims: d,
            key_books,
            val_books,
            key_thresh,
            val_thresh,
        }
    }

    fn key_book(&self, l: usize, h: usize, ch: usize) -> &KMeans {
        &self.key_books[(l * self.dims.h + h) * self.dims.hd + ch]
    }
}

/// |x| quantile of one layer slice (q in [0,1]; q>=1 disables outliers).
fn quantile_abs(a: &TensorF, l: usize, q: f64) -> f32 {
    if q >= 1.0 {
        return f32::INFINITY;
    }
    let d = KvDims::of(a);
    let per_layer = d.b * d.h * d.t * d.hd;
    let mut mags: Vec<f32> = a.data[l * per_layer..(l + 1) * per_layer]
        .iter()
        .map(|x| x.abs())
        .collect();
    mags.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let idx = ((mags.len() as f64 - 1.0) * q).round() as usize;
    mags[idx]
}

impl Codec for KvQuant {
    fn name(&self) -> String {
        if self.outlier_frac > 0.0 {
            format!("KVQuant-{}b-{}%", self.bits, (self.outlier_frac * 100.0) as u32)
        } else {
            format!("KVQuant-{}b", self.bits)
        }
    }

    fn bits_per_fpn(&self) -> f64 {
        // Dense code + (16-bit value + 16-bit index) per sparse outlier.
        self.bits as f64 + self.outlier_frac * 32.0
    }

    fn apply(&self, kind: KvKind, a: &mut TensorF) {
        let d = KvDims::of(a);
        assert_eq!((d.l, d.h, d.hd), (self.dims.l, self.dims.h, self.dims.hd));
        match kind {
            KvKind::Key => {
                for l in 0..d.l {
                    let thr = self.key_thresh[l];
                    for h in 0..d.h {
                        for ch in 0..d.hd {
                            let book = self.key_book(l, h, ch);
                            let mut vals = gather_channel(a, l, h, ch);
                            for x in vals.iter_mut() {
                                if x.abs() <= thr {
                                    *x = book.centroid(book.assign(&[*x]))[0];
                                }
                            }
                            scatter_channel(a, l, h, ch, &vals);
                        }
                    }
                }
            }
            KvKind::Value => {
                for l in 0..d.l {
                    let thr = self.val_thresh[l];
                    let book = &self.val_books[l];
                    for h in 0..d.h {
                        for_each_vec(a, l, h, |_, tok| {
                            let s = tok.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                            if s == 0.0 {
                                return;
                            }
                            for x in tok.iter_mut() {
                                if x.abs() <= thr {
                                    let u = *x / s;
                                    *x = book.centroid(book.assign(&[u]))[0] * s;
                                }
                            }
                        });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randn_kv(shape: &[usize], seed: u64, outlier_every: usize) -> TensorF {
        let mut rng = Pcg64::seed(seed);
        let n = crate::tensor::numel(shape);
        let mut data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        if outlier_every > 0 {
            for i in (0..n).step_by(outlier_every) {
                data[i] *= 50.0;
            }
        }
        TensorF::from_vec(shape, data).unwrap()
    }

    fn setup(bits: u32, frac: f64) -> (KvQuant, TensorF, TensorF) {
        let k = randn_kv(&[2, 1, 2, 128, 8], 1, 97);
        let v = randn_kv(&[2, 1, 2, 128, 8], 2, 101);
        let q = KvQuant::learn(bits, frac, &k, &v, None, None, 25, 0);
        (q, k, v)
    }

    #[test]
    fn dense_quantization_reduces_precision_gracefully() {
        let (q, k, _) = setup(4, 0.0);
        let mut kq = k.clone();
        q.apply(KvKind::Key, &mut kq);
        let mse = kq.sqdiff(&k) / k.numel() as f64;
        assert!(mse < 1.0, "4-bit NUQ mse={mse}");
    }

    #[test]
    fn outliers_preserved_exactly_with_sparse_band() {
        let (q, k, _) = setup(2, 0.01);
        let mut kq = k.clone();
        q.apply(KvKind::Key, &mut kq);
        // The largest-magnitude element must be untouched.
        let (mut imax, mut vmax) = (0usize, 0.0f32);
        for (i, &x) in k.data.iter().enumerate() {
            if x.abs() > vmax {
                vmax = x.abs();
                imax = i;
            }
        }
        assert_eq!(kq.data[imax], k.data[imax]);
    }

    #[test]
    fn sparse_band_improves_low_bit_error() {
        let (qd, k, _) = setup(1, 0.0);
        let (qs, _, _) = setup(1, 0.01);
        let mut a = k.clone();
        let mut b = k.clone();
        qd.apply(KvKind::Key, &mut a);
        qs.apply(KvKind::Key, &mut b);
        assert!(
            b.sqdiff(&k) < a.sqdiff(&k) * 0.8,
            "sparse {} dense {}",
            b.sqdiff(&k),
            a.sqdiff(&k)
        );
    }

    #[test]
    fn fisher_weighting_shifts_grids() {
        let k = randn_kv(&[1, 1, 1, 64, 4], 3, 0);
        let v = randn_kv(&[1, 1, 1, 64, 4], 4, 0);
        let gk = randn_kv(&[1, 1, 1, 64, 4], 5, 0);
        let gv = randn_kv(&[1, 1, 1, 64, 4], 6, 0);
        let uni = KvQuant::learn(3, 0.0, &k, &v, None, None, 25, 0);
        let fis = KvQuant::learn(3, 0.0, &k, &v, Some(&gk), Some(&gv), 25, 0);
        assert_ne!(uni.key_books[0].centroids, fis.key_books[0].centroids);
    }

    #[test]
    fn names_and_accounting() {
        let (q, _, _) = setup(2, 0.01);
        assert_eq!(q.name(), "KVQuant-2b-1%");
        assert!((q.bits_per_fpn() - 2.32).abs() < 1e-9);
        let (qd, _, _) = setup(4, 0.0);
        assert_eq!(qd.name(), "KVQuant-4b");
        assert_eq!(qd.bits_per_fpn(), 4.0);
    }

    #[test]
    fn value_path_scales_per_token() {
        let (q, _, v) = setup(4, 0.0);
        let mut vq = v.clone();
        q.apply(KvKind::Value, &mut vq);
        let mse = vq.sqdiff(&v) / v.numel() as f64;
        assert!(mse < 1.0, "mse={mse}");
        assert_ne!(vq, v);
    }
}

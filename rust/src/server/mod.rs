//! TCP inference server + client (line-delimited JSON protocol).
//!
//! Request line:  `{"prompt": "...", "max_tokens": 32, "temperature": 0.8,
//!                  "top_k": 40}`
//! Response line: `{"id": 1, "text": "...", "prompt_tokens": 12,
//!                  "prefix_hit_tokens": 8, "gen_tokens": 32,
//!                  "prefill_ms": ..., "decode_ms": ..., "cache_bytes": ...}`
//!
//! Connection threads are thin: they parse, forward to the serve pool's
//! router, and stream the response back.  All model work happens on the
//! pool's engine worker threads (`coordinator::pool` + `serve_loop`); the
//! router spreads concurrent connections across workers least-loaded-first.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Request, Response, ServePool};
use crate::util::json::Json;

/// Parse one request line into a [`Request`].
pub fn parse_request(line: &str, id: u64) -> Result<Request> {
    let j = Json::parse(line).context("request JSON")?;
    Ok(Request {
        id,
        prompt: j.str_or("prompt", ""),
        max_new: j.num_or("max_tokens", 32.0) as usize,
        temperature: j.num_or("temperature", 0.0) as f32,
        top_k: j.num_or("top_k", 0.0) as usize,
        seed: j.num_or("seed", id as f64) as u64,
    })
}

/// Serialize a [`Response`] to its wire line.
pub fn format_response(r: &Response) -> String {
    Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("text", Json::Str(r.text.clone())),
        ("prompt_tokens", Json::Num(r.prompt_tokens as f64)),
        ("prefix_hit_tokens", Json::Num(r.prefix_hit_tokens as f64)),
        ("gen_tokens", Json::Num(r.gen_tokens as f64)),
        ("prefill_ms", Json::Num((r.prefill_ms * 100.0).round() / 100.0)),
        ("decode_ms", Json::Num((r.decode_ms * 100.0).round() / 100.0)),
        ("cache_bytes", Json::Num(r.cache_bytes as f64)),
    ])
    .dump()
}

/// Serve on `addr` until `stop` is raised.  Each connection may pipeline
/// multiple newline-delimited requests; concurrent connections are routed
/// across the pool's workers.
pub fn serve_tcp(pool: &ServePool, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    println!("[server] listening on {addr}");
    let next_id = Arc::new(AtomicU64::new(1));
    std::thread::scope(|scope| -> Result<()> {
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::info!("connection from {peer}");
                    let ids = next_id.clone();
                    let p = pool;
                    scope.spawn(move || {
                        if let Err(e) = handle_conn(p, stream, &ids) {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
    })
}

fn handle_conn(pool: &ServePool, stream: TcpStream, ids: &AtomicU64) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let id = ids.fetch_add(1, Ordering::Relaxed);
        let resp = match parse_request(&line, id) {
            Ok(req) => pool.submit(req)?,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![
                    ("error", Json::Str(format!("{e:#}"))),
                ]).dump())?;
                continue;
            }
        };
        writeln!(writer, "{}", format_response(&resp))?;
    }
    Ok(())
}

/// Blocking client: send one prompt, return the parsed response line.
pub fn client_request(addr: &str, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let req = Json::obj(vec![
        ("prompt", Json::Str(prompt.to_string())),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("temperature", Json::Num(temperature as f64)),
    ]);
    writeln!(stream, "{}", req.dump())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(line.trim())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_fields_and_defaults() {
        let r = parse_request(r#"{"prompt": "hi", "max_tokens": 8}"#, 3).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new, 8);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.seed, 3);
        assert!(parse_request("not json", 1).is_err());
    }

    #[test]
    fn response_roundtrips_through_wire_format() {
        let r = Response {
            id: 9,
            text: "abc\ndef".into(),
            prompt_tokens: 4,
            prefix_hit_tokens: 3,
            gen_tokens: 7,
            queue_ms: 0.0,
            prefill_ms: 1.25,
            decode_ms: 10.5,
            cache_bytes: 1234,
        };
        let line = format_response(&r);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.num_or("id", 0.0), 9.0);
        assert_eq!(j.str_or("text", ""), "abc\ndef");
        assert_eq!(j.num_or("cache_bytes", 0.0), 1234.0);
        assert_eq!(j.num_or("prefix_hit_tokens", 0.0), 3.0);
    }
}

//! TCP inference server + client (line-delimited JSON, protocol v2.4).
//!
//! **v1 (non-streaming)** — one request line, one response line:
//!
//! ```text
//! -> {"prompt": "...", "max_tokens": 32, "temperature": 0.8, "top_k": 40,
//!     "seed": 7, "session": 12, "priority": "interactive"}
//! <- {"id": 1, "text": "...", "prompt_tokens": 12, "prefix_hit_tokens": 8,
//!     "gen_tokens": 32, "queue_ms": ..., "ttft_ms": ..., "prefill_ms": ...,
//!     "decode_ms": ..., "cache_bytes": ...}
//! ```
//!
//! `"priority"` is `"interactive"` (default) or `"batch"`, and drives the
//! chunked-prefill scheduler: workers prefer interactive prefill chunks and
//! lane admissions over batch ones, and the router's optional
//! `--ttft-slo-chunks` gate rejects interactive requests (retryably, with
//! `[rejected: ttft slo]`) whose estimated first token would queue behind
//! too deep a prefill backlog.  Batch requests are never TTFT-gated.  Any
//! other `"priority"` string is a protocol error.
//!
//! **v2 (streaming)** — add `"stream": true` and the same connection
//! receives NDJSON event frames as the worker produces them:
//!
//! ```text
//! <- {"event": "started", "id": 1}
//! <- {"event": "token", "id": 1, "index": 0, "text": "T"}
//! <- ...
//! <- {"event": "done", "id": 1, "text": "...", "ttft_ms": ..., ...}   (or)
//! <- {"event": "failed", "id": 1, "error": "..."}
//! ```
//!
//! The terminal `done` frame carries the full v1 response fields (including
//! `ttft_ms` and `queue_ms`).  A failed frame write — the client
//! disconnected mid-stream — cancels the request on its worker: the decode
//! lane frees and the shard's reserved blocks return to the budget instead
//! of burning until `max_new`.  Malformed requests (including a missing or
//! empty `prompt`) get an `{"error": ...}` line and the connection lives
//! on.  `"session": N` keys multi-turn continuation: a follow-up turn sends
//! only its new text and resumes from the session's radix-cached history.
//! Note the byte-level tokenizer: token frames carry per-byte text, so
//! non-ASCII output surfaces as replacement characters in frames while the
//! terminal `text` decodes the full byte string.
//!
//! **Error frames (v2.1, fault-tolerant serving).**  Every `failed` frame
//! carries `"retryable": bool` alongside `"error"`:
//!
//! ```text
//! <- {"event": "failed", "id": 1, "error": "...", "retryable": true}
//! ```
//!
//! * `retryable: true` — transient capacity or infrastructure failure
//!   (`[rejected: pool budget]`, `[rejected: cache budget]`,
//!   `[rejected: ttft slo]`, `[error: serve worker died]`,
//!   `[error: no live serve workers]`): resubmitting the identical request
//!   can succeed.  A worker crash is invisible for requests that were still
//!   queued **or anywhere mid-prefill** — prefill runs in chunks and the
//!   request's stream is only pinned to a worker once its first token is
//!   sampled, so the pool supervisor re-dispatches it to a live shard and
//!   the stream simply starts late (a re-dispatched request may emit
//!   `started` again).
//! * `retryable: false` — resubmitting the same line cannot help:
//!   `[cancelled]`, prefill errors, and the two **session signals**:
//!   - `[session_evicted: ...]` — the session idled past its TTL or was
//!     LRU-evicted from the worker's bounded table; resend the full
//!     conversation history as the next turn's prompt (the session id is
//!     reusable and starts fresh);
//!   - `[resend_history: ...]` — the worker holding the session's history
//!     died; same client action, after which the pool re-registers the
//!     session on a live shard.
//!
//! **Cancellation** (dropping the v2 connection mid-stream, or an explicit
//! pool-side cancel) takes effect at the next scheduler yield point: a
//! decoding request stops at its next token, a mid-prefill request stops at
//! its next chunk boundary — partial prefill work is rolled back and the
//! reserved blocks return to the budget.  Either way the stream terminates
//! with `[cancelled]` (`retryable: false`).
//!
//! **Admin ops (v2.2, observability).**  A line whose JSON object carries
//! an `"op"` key is an admin op, not an inference request: it is answered
//! inline by the connection thread from the pool's shared metrics — admin
//! ops never consume a lane, never allocate a request id, and never touch
//! a worker queue, so they stay answerable while every lane is saturated.
//! One response line per op; the connection lives on (ops pipeline freely
//! between inference requests).  Catalog:
//!
//! ```text
//! -> {"op": "metrics"}
//! <- {"op": "metrics", "ok": true, "snapshot": {...}, "rates": {...}|null}
//! -> {"op": "metrics", "format": "prometheus"}
//! <- {"op": "metrics", "ok": true, "format": "prometheus", "text": "..."}
//! -> {"op": "health"}
//! <- {"op": "health", "ok": true, "n_workers": N, "live_workers": L,
//!     "workers": [{"worker": 0, "alive": true, "queue_depth": q,
//!                  "free_lanes": f, "prefill_backlog_tokens": t,
//!                  "live_sessions": s}, ...]}
//! -> {"op": "trace"}                      (optional "worker": N filter)
//! <- {"op": "trace", "ok": true,
//!     "workers": [{"worker": 0, "capacity": ..., "dropped": ...,
//!                  "live": [...], "finished": [...], "crashed": [...]}]}
//! ```
//!
//! `"snapshot"` is the full [`crate::metrics::export::MetricsSnapshot`]
//! (every pool/worker counter, gauge and raw histogram bucket); `"rates"`
//! is tok/s / chunks/s / req/s derived against the server's previous
//! `metrics` scrape (`null` on the first scrape).  The `prometheus` text
//! variant ships the same snapshot as an exposition-format payload inside
//! one JSON line.  `trace` returns each worker's flight recorder
//! ([`crate::metrics::trace::TraceRecorder`]) including the crash-dump
//! traces a retired worker left behind.  An unknown `"op"` gets
//! `{"ok": false, "error": ...}`.
//!
//! **Per-tenant policies (v2.3).**  A request may carry
//! `"policy": "<name>"` naming one of the quantization policies the pool
//! was started with (`--policies`, see
//! [`crate::quant::policy::PolicyDescriptor`]):
//!
//! ```text
//! -> {"prompt": "...", "max_tokens": 32, "policy": "cq-8c10b-w64-s4"}
//! ```
//!
//! The name selects the codec/precision tier and the fp retention window
//! the request's cache entries live under, and — because different
//! policies cost different bytes per token — prices the request's pool and
//! shard admission at its own rate.  A policy the pool does not serve is a
//! non-retryable `[rejected: unknown policy ...]` failure; a request
//! without the field uses the worker's native cache mode, exactly as in
//! v2.2.  The field is omitted (not defaulted) on the wire when unset.
//!
//! **Event-driven frontend + broadcast fan-out (v2.4).**  The frontend is
//! a readiness-driven reactor ([`reactor`]): one event-loop thread owns
//! every socket (nonblocking accept + epoll on Linux), connection state
//! machines ([`conn`]) parse request lines incrementally, and frames go
//! out from bounded per-connection queues on write-readiness — thread
//! count is O(1) in connections, and backpressure pauses a connection's
//! *read interest* instead of parking a thread.  New wire surface:
//!
//! ```text
//! -> {"op": "watch", "id": 3}
//! <- {"op": "watch", "ok": true, "id": 3}      (then that stream's frames)
//! <- {"op": "watch", "ok": false, "id": 3, "error": "no live generation 3"}
//! -> {"op": "metrics", "scraper": "prober-a"}
//! ```
//!
//! `watch` attaches the connection to a live generation's event stream
//! ([`broadcast`]): N watchers share one upstream stream, each behind its
//! own bounded buffer (`--client-buffer`).  A slow reader hits its buffer
//! policy (`--client-buffer-policy`) instead of stalling anything:
//! `drop-oldest` discards its oldest droppable frames and tells it with
//! `{"event":"lagged","id":N,"dropped":K,"total_dropped":T}` (terminal
//! frames are never dropped); `disconnect` clamps the queue to one
//! `{"event":"disconnected","error":...}` frame and closes.  When a
//! generation's last subscriber disconnects, the request is cancelled
//! upstream.  The `"scraper"` tag keys the `metrics` op's rate baseline so
//! concurrent scrapers see independent `Rates` windows (untagged scrapers
//! share the `""` baseline, preserving the v2.2 behavior).  Two more typed
//! error lines: an over-long request line (`--max-line-bytes`) gets
//! `{"error": ..., "code": "line_too_long"}` with the connection intact,
//! and a connect past `--max-conns` gets `{"error": ...,
//! "code": "max_conns"}` before the socket drops.  Because responses are
//! queued asynchronously, a client must keep its connection open until its
//! terminal frame arrives (half-close after the request line is treated as
//! a disconnect and cancels the request).
//!
//! All model work happens on the pool's engine worker threads
//! (`coordinator::pool` + `serve_loop`); the reactor only parses, routes,
//! and flushes.  Shutdown is a condvar [`StopSignal`] whose waker pokes
//! the reactor's loopback waker socket, so `stop` latency is one poller
//! wakeup, not a poll tick.

pub mod broadcast;
pub mod conn;
pub mod reactor;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Event, Priority, Request, Response, ServePool};
use crate::metrics::export::{prometheus_text, MetricsSnapshot, Rates};
use crate::util::json::Json;

pub use conn::{BufferPolicy, OverflowPolicy};
pub use reactor::ServerConfig;

/// Condvar-backed stop flag for [`serve_tcp`]: `raise()` wakes the waiter
/// immediately (no sleep-poll anywhere on the shutdown path).
pub struct StopSignal {
    raised: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl StopSignal {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<StopSignal> {
        Arc::new(StopSignal {
            raised: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        })
    }

    /// Raise the signal and wake every waiter.  Idempotent.
    pub fn raise(&self) {
        self.raised.store(true, Ordering::SeqCst);
        let _guard = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    pub fn raised(&self) -> bool {
        self.raised.load(Ordering::SeqCst)
    }

    /// Park until the signal is raised (condvar wait, zero wakeups while
    /// idle).
    pub fn wait(&self) {
        let mut guard = self.lock.lock().unwrap();
        while !self.raised() {
            guard = self.cv.wait(guard).unwrap();
        }
    }
}

/// Parse one request line into a [`Request`] plus its `stream` flag.
/// A missing or empty `prompt` is a protocol error (the old behavior of
/// silently serving the empty prompt hid client bugs).
pub fn parse_request(line: &str, id: u64) -> Result<(Request, bool)> {
    let j = Json::parse(line).context("request JSON")?;
    let prompt = j.str_or("prompt", "");
    if prompt.is_empty() {
        bail!("missing or empty 'prompt'");
    }
    let priority = match j.str_or("priority", "interactive").as_str() {
        "interactive" => Priority::Interactive,
        "batch" => Priority::Batch,
        other => bail!("unknown 'priority' {other:?} (use \"interactive\" or \"batch\")"),
    };
    let req = Request {
        id,
        prompt,
        max_new: j.num_or("max_tokens", 32.0) as usize,
        temperature: j.num_or("temperature", 0.0) as f32,
        top_k: j.num_or("top_k", 0.0) as usize,
        seed: j.num_or("seed", id as f64) as u64,
        session_id: j.get("session").and_then(Json::as_f64).map(|s| s as u64),
        priority,
        policy: j
            .get("policy")
            .and_then(Json::as_str)
            .filter(|p| !p.is_empty())
            .map(str::to_string),
    };
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    Ok((req, stream))
}

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

/// The wire fields of a [`Response`] (shared by the v1 response line and
/// the v2 terminal `done` frame).
fn response_fields(r: &Response) -> Vec<(&'static str, Json)> {
    vec![
        ("id", Json::Num(r.id as f64)),
        ("text", Json::Str(r.text.clone())),
        ("prompt_tokens", Json::Num(r.prompt_tokens as f64)),
        ("prefix_hit_tokens", Json::Num(r.prefix_hit_tokens as f64)),
        ("gen_tokens", Json::Num(r.gen_tokens as f64)),
        ("queue_ms", Json::Num(round2(r.queue_ms))),
        ("ttft_ms", Json::Num(round2(r.ttft_ms))),
        ("prefill_ms", Json::Num(round2(r.prefill_ms))),
        ("decode_ms", Json::Num(round2(r.decode_ms))),
        ("cache_bytes", Json::Num(r.cache_bytes as f64)),
    ]
}

/// Serialize a [`Response`] to its v1 wire line.
pub fn format_response(r: &Response) -> String {
    Json::obj(response_fields(r)).dump()
}

/// Serialize one lifecycle [`Event`] to its v2 NDJSON frame.
pub fn format_event(ev: &Event) -> String {
    match ev {
        Event::Started { id } => Json::obj(vec![
            ("event", Json::Str("started".into())),
            ("id", Json::Num(*id as f64)),
        ])
        .dump(),
        Event::Token { id, index, text } => Json::obj(vec![
            ("event", Json::Str("token".into())),
            ("id", Json::Num(*id as f64)),
            ("index", Json::Num(*index as f64)),
            ("text", Json::Str(text.clone())),
        ])
        .dump(),
        Event::Done(r) => {
            let mut fields = response_fields(r);
            fields.push(("event", Json::Str("done".into())));
            Json::obj(fields).dump()
        }
        Event::Failed { id, reason, retryable } => Json::obj(vec![
            ("event", Json::Str("failed".into())),
            ("id", Json::Num(*id as f64)),
            ("error", Json::Str(reason.clone())),
            ("retryable", Json::Bool(*retryable)),
        ])
        .dump(),
    }
}

/// Serve on `addr` until `stop` is raised, with default [`ServerConfig`]
/// limits.  Connections may pipeline newline-delimited requests; all
/// socket I/O runs on the reactor's event loop ([`reactor::serve`]).
pub fn serve_tcp(pool: &ServePool, addr: &str, stop: Arc<StopSignal>) -> Result<()> {
    reactor::serve(pool, addr, stop, ServerConfig::default())
}

/// [`serve_tcp`] with explicit frontend limits (`--max-conns`,
/// `--max-line-bytes`, `--client-buffer`, `--client-buffer-policy`).
pub fn serve_tcp_cfg(
    pool: &ServePool,
    addr: &str,
    stop: Arc<StopSignal>,
    cfg: ServerConfig,
) -> Result<()> {
    reactor::serve(pool, addr, stop, cfg)
}

/// Detect an admin-op line: a JSON object carrying an `"op"` key.  Returns
/// the parsed object so the dispatcher never re-parses; inference requests
/// (no `"op"`) and malformed lines fall through to [`parse_request`].
fn parse_admin_op(line: &str) -> Option<Json> {
    let j = Json::parse(line.trim()).ok()?;
    j.get("op")?;
    Some(j)
}

/// Answer one admin op from the pool's shared metrics.  Never blocks on a
/// worker: everything read here lives behind the metrics `Arc`s, so these
/// stay answerable while every lane is saturated or every worker is dead.
/// `baselines` holds the previous `metrics` scrape per `"scraper"` tag
/// (`""` when untagged), so concurrent scrapers that tag themselves get
/// independent rate windows instead of corrupting one shared slot.
fn admin_response(
    pool: &ServePool,
    op: &Json,
    baselines: &mut HashMap<String, MetricsSnapshot>,
) -> Json {
    match op.str_or("op", "").as_str() {
        "metrics" => {
            let snap = MetricsSnapshot::collect(&pool.metrics, pool.live_workers());
            // Swap this scrape in as this scraper's new rate baseline.
            let prev = baselines.insert(op.str_or("scraper", ""), snap.clone());
            if op.str_or("format", "json") == "prometheus" {
                return Json::obj(vec![
                    ("op", Json::Str("metrics".into())),
                    ("ok", Json::Bool(true)),
                    ("format", Json::Str("prometheus".into())),
                    ("text", Json::Str(prometheus_text(&snap))),
                ]);
            }
            let rates = prev
                .as_ref()
                .and_then(|p| Rates::between(p, &snap))
                .map(|r| r.to_json())
                .unwrap_or(Json::Null);
            Json::obj(vec![
                ("op", Json::Str("metrics".into())),
                ("ok", Json::Bool(true)),
                ("snapshot", snap.to_json()),
                ("rates", rates),
            ])
        }
        "health" => {
            let loads = pool.loads();
            let workers: Vec<Json> = (0..pool.n_workers())
                .map(|w| {
                    let m = pool.metrics.worker(w);
                    Json::obj(vec![
                        ("worker", Json::Num(w as f64)),
                        ("alive", Json::Bool(pool.worker_alive(w))),
                        ("queue_depth", Json::Num(loads[w].0 as f64)),
                        ("free_lanes", Json::Num(loads[w].1 as f64)),
                        (
                            "prefill_backlog_tokens",
                            Json::Num(m.prefill_backlog_tokens.get() as f64),
                        ),
                        (
                            "live_sessions",
                            Json::Num(m.session_tokens.live_sessions() as f64),
                        ),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("op", Json::Str("health".into())),
                ("ok", Json::Bool(true)),
                ("n_workers", Json::Num(pool.n_workers() as f64)),
                ("live_workers", Json::Num(pool.live_workers() as f64)),
                ("workers_dead", Json::Num(pool.metrics.workers_dead.get() as f64)),
                ("workers", Json::Arr(workers)),
            ])
        }
        "trace" => {
            let only = op.get("worker").and_then(Json::as_f64).map(|w| w as usize);
            let workers: Vec<Json> = (0..pool.n_workers())
                .filter(|&w| match only {
                    Some(o) => o == w,
                    None => true,
                })
                .map(|w| {
                    let mut fields = vec![("worker", Json::Num(w as f64))];
                    if let Json::Obj(rec) = pool.metrics.worker(w).trace.to_json() {
                        for (k, v) in rec {
                            match k.as_str() {
                                "capacity" => fields.push(("capacity", v)),
                                "dropped" => fields.push(("dropped", v)),
                                "live" => fields.push(("live", v)),
                                "finished" => fields.push(("finished", v)),
                                "crashed" => fields.push(("crashed", v)),
                                _ => {}
                            }
                        }
                    }
                    Json::obj(fields)
                })
                .collect();
            Json::obj(vec![
                ("op", Json::Str("trace".into())),
                ("ok", Json::Bool(true)),
                ("workers", Json::Arr(workers)),
            ])
        }
        other => Json::obj(vec![
            ("op", Json::Str(other.to_string())),
            ("ok", Json::Bool(false)),
            ("error", Json::Str(format!("unknown admin op {other:?}"))),
        ]),
    }
}

/// Blocking v1 client: send one raw request line, return the parsed
/// response line.
pub fn client_request_line(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Json::parse(resp.trim())
}

/// Blocking client: send one prompt, return the parsed response line.
/// `seed: None` lets the server derive its default (the request id).
pub fn client_request(
    addr: &str,
    prompt: &str,
    max_tokens: usize,
    temperature: f32,
    top_k: usize,
    seed: Option<u64>,
) -> Result<Json> {
    let mut pairs = vec![
        ("prompt", Json::Str(prompt.to_string())),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("temperature", Json::Num(temperature as f64)),
        ("top_k", Json::Num(top_k as f64)),
    ];
    if let Some(s) = seed {
        pairs.push(("seed", Json::Num(s as f64)));
    }
    client_request_line(addr, &Json::obj(pairs).dump())
}

/// Streaming v2 client: send one raw request line (the caller sets
/// `"stream": true`), invoke `on_frame` for every NDJSON frame, and return
/// the terminal (`done`/`failed`) frame.
pub fn client_stream(
    addr: &str,
    line: &str,
    mut on_frame: impl FnMut(&Json),
) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    writeln!(stream, "{line}")?;
    let reader = BufReader::new(stream);
    for frame_line in reader.lines() {
        let frame_line = frame_line?;
        if frame_line.trim().is_empty() {
            continue;
        }
        let frame = Json::parse(frame_line.trim())?;
        on_frame(&frame);
        let ev = frame.str_or("event", "");
        if ev == "done" || ev == "failed" || frame.get("error").is_some() {
            return Ok(frame);
        }
    }
    bail!("stream ended without a terminal frame")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_fields_and_defaults() {
        let (r, stream) = parse_request(r#"{"prompt": "hi", "max_tokens": 8}"#, 3).unwrap();
        assert!(!stream, "v1 requests default to non-streaming");
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new, 8);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.seed, 3);
        assert_eq!(r.session_id, None);
        assert_eq!(r.priority, Priority::Interactive, "priority defaults to interactive");
        assert_eq!(r.policy, None, "policy is opt-in, absent by default");
        assert!(parse_request("not json", 1).is_err());
    }

    #[test]
    fn parse_request_policy_field() {
        let (r, _) =
            parse_request(r#"{"prompt": "hi", "policy": "cq-8c10b-w64-s4"}"#, 9).unwrap();
        assert_eq!(r.policy.as_deref(), Some("cq-8c10b-w64-s4"));
        // An empty policy string is treated as unset, not as a policy name.
        let (r2, _) = parse_request(r#"{"prompt": "hi", "policy": ""}"#, 10).unwrap();
        assert_eq!(r2.policy, None);
        // Non-string values are ignored (type-lenient, like "session").
        let (r3, _) = parse_request(r#"{"prompt": "hi", "policy": 7}"#, 11).unwrap();
        assert_eq!(r3.policy, None);
    }

    #[test]
    fn parse_request_v2_fields() {
        let (r, stream) = parse_request(
            r#"{"prompt": "hi", "stream": true, "session": 12, "top_k": 5, "seed": 99}"#,
            4,
        )
        .unwrap();
        assert!(stream);
        assert_eq!(r.session_id, Some(12));
        assert_eq!(r.top_k, 5);
        assert_eq!(r.seed, 99);
        // stream: false is the explicit v1 form.
        let (_, s2) = parse_request(r#"{"prompt": "x", "stream": false}"#, 5).unwrap();
        assert!(!s2);
        // Priority is parsed, and unknown values are protocol errors.
        let (rb, _) = parse_request(r#"{"prompt": "x", "priority": "batch"}"#, 6).unwrap();
        assert_eq!(rb.priority, Priority::Batch);
        let (ri, _) = parse_request(r#"{"prompt": "x", "priority": "interactive"}"#, 7).unwrap();
        assert_eq!(ri.priority, Priority::Interactive);
        let err = parse_request(r#"{"prompt": "x", "priority": "urgent"}"#, 8).unwrap_err();
        assert!(err.to_string().contains("priority"), "{err}");
    }

    #[test]
    fn missing_or_empty_prompt_is_rejected() {
        for bad in [r#"{"max_tokens": 4}"#, r#"{"prompt": ""}"#, "{}"] {
            let err = parse_request(bad, 1).unwrap_err();
            assert!(err.to_string().contains("prompt"), "{bad}: {err}");
        }
    }

    fn sample_response() -> Response {
        Response {
            id: 9,
            text: "abc\ndef".into(),
            prompt_tokens: 4,
            prefix_hit_tokens: 3,
            gen_tokens: 7,
            queue_ms: 3.456,
            ttft_ms: 1.234,
            prefill_ms: 1.25,
            decode_ms: 10.5,
            cache_bytes: 1234,
        }
    }

    #[test]
    fn response_roundtrips_through_wire_format() {
        let r = sample_response();
        let line = format_response(&r);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.num_or("id", 0.0), 9.0);
        assert_eq!(j.str_or("text", ""), "abc\ndef");
        assert_eq!(j.num_or("cache_bytes", 0.0), 1234.0);
        assert_eq!(j.num_or("prefix_hit_tokens", 0.0), 3.0);
        // queue_ms and ttft_ms are on the wire (rounded to 2 decimals).
        assert_eq!(j.num_or("queue_ms", 0.0), 3.46);
        assert_eq!(j.num_or("ttft_ms", 0.0), 1.23);
    }

    #[test]
    fn event_frames_serialize_and_roundtrip() {
        let started = Json::parse(&format_event(&Event::Started { id: 2 })).unwrap();
        assert_eq!(started.str_or("event", ""), "started");
        assert_eq!(started.num_or("id", 0.0), 2.0);

        let token = Json::parse(&format_event(&Event::Token {
            id: 2,
            index: 5,
            text: "x".into(),
        }))
        .unwrap();
        assert_eq!(token.str_or("event", ""), "token");
        assert_eq!(token.num_or("index", 0.0), 5.0);
        assert_eq!(token.str_or("text", ""), "x");

        let done = Json::parse(&format_event(&Event::Done(sample_response()))).unwrap();
        assert_eq!(done.str_or("event", ""), "done");
        assert_eq!(done.str_or("text", ""), "abc\ndef");
        assert_eq!(done.num_or("ttft_ms", 0.0), 1.23);
        assert_eq!(done.num_or("queue_ms", 0.0), 3.46);

        let failed = Json::parse(&format_event(&Event::Failed {
            id: 3,
            reason: "[cancelled]".into(),
            retryable: false,
        }))
        .unwrap();
        assert_eq!(failed.str_or("event", ""), "failed");
        assert_eq!(failed.str_or("error", ""), "[cancelled]");
        assert_eq!(failed.get("retryable").and_then(Json::as_bool), Some(false));

        let died = Json::parse(&format_event(&Event::Failed {
            id: 4,
            reason: "[error: serve worker died]".into(),
            retryable: true,
        }))
        .unwrap();
        assert_eq!(died.get("retryable").and_then(Json::as_bool), Some(true));

        let evicted = Json::parse(&format_event(&Event::Failed {
            id: 5,
            reason: "[session_evicted: session 9 expired; resend history]".into(),
            retryable: false,
        }))
        .unwrap();
        assert!(evicted.str_or("error", "").contains("session_evicted"));
        assert_eq!(evicted.get("retryable").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn admin_ops_are_detected_before_request_parsing() {
        // An "op" key marks an admin line, whatever else rides along.
        assert!(parse_admin_op(r#"{"op": "metrics"}"#).is_some());
        assert!(parse_admin_op(r#"{"op": "metrics", "format": "prometheus"}"#).is_some());
        assert!(parse_admin_op(r#"{"op": "trace", "worker": 1}"#).is_some());
        // Inference requests and malformed lines fall through to the
        // request parser (which owns the error reply).
        assert!(parse_admin_op(r#"{"prompt": "hi"}"#).is_none());
        assert!(parse_admin_op("not json").is_none());
        assert!(parse_admin_op("").is_none());
    }

    #[test]
    fn stop_signal_wakes_a_parked_waiter() {
        let stop = StopSignal::new();
        assert!(!stop.raised());
        let s2 = stop.clone();
        let waiter = std::thread::spawn(move || {
            s2.wait();
            s2.raised()
        });
        // Give the waiter a moment to park, then raise.
        std::thread::sleep(std::time::Duration::from_millis(20));
        stop.raise();
        assert!(waiter.join().unwrap());
        stop.raise(); // idempotent
        assert!(stop.raised());
    }
}

//! Splaycast-style broadcast fan-out: one upstream event stream per
//! generation, N subscribed connections, bounded per-client buffers.
//!
//! Every in-flight request owns one [`Hub`] entry, registered *before* the
//! request is submitted so no event can slip past the subscription.  The
//! pump thread publishes each pool [`Event`] exactly once; the hub formats
//! it per subscriber mode and pushes the frame into each subscriber's
//! [`ConnQueue`].  Slow readers are the queue's problem (its
//! [`BufferPolicy`](super::conn::BufferPolicy) clamps them) — publishing
//! never blocks, so a lagging client can never stall the pump, the
//! reactor, or any decode lane.
//!
//! Subscriber modes:
//!
//! * [`SubMode::Stream`] — the requester asked for `"stream": true`: every
//!   frame (started/token/done/failed) is delivered; token frames are
//!   droppable under buffer pressure, terminal frames never are.
//! * [`SubMode::V1`] — a non-streaming request: only the terminal event is
//!   delivered, formatted as the v1 response line.
//! * [`SubMode::Watch`] — a `{"op":"watch","id":N}` subscriber: same
//!   frames as `Stream`, attached to a generation some other connection
//!   started.
//!
//! When the last subscriber of a generation disconnects, the hub cancels
//! the request upstream — nobody is listening, so the lane and its
//! reserved cache blocks go back to the pool.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::{CancelHandle, Event, Response};
use crate::metrics::PoolMetrics;
use crate::util::json::Json;

use super::conn::{ConnQueue, Notifier, PushOutcome};
use super::{format_event, format_response};

/// How a subscriber wants a generation's events rendered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubMode {
    /// Full v2 NDJSON frame stream.
    Stream,
    /// Terminal line only, in the v1 response format.
    V1,
    /// Full frame stream for a generation another connection started.
    Watch,
}

struct Sub {
    conn: Arc<ConnQueue>,
    mode: SubMode,
}

struct Entry {
    subs: Vec<Sub>,
    cancel: Option<CancelHandle>,
}

/// Fan-out registry: request id → live subscribers.
pub struct Hub {
    inner: Mutex<HashMap<u64, Entry>>,
    metrics: Arc<PoolMetrics>,
    notifier: Arc<Notifier>,
}

impl Hub {
    pub fn new(metrics: Arc<PoolMetrics>, notifier: Arc<Notifier>) -> Hub {
        Hub { inner: Mutex::new(HashMap::new()), metrics, notifier }
    }

    /// Register the primary subscriber of a new request.  Must happen
    /// before the request is submitted: router-terminal failures publish
    /// synchronously, and an unregistered id would drop them.
    pub fn register(&self, id: u64, conn: &Arc<ConnQueue>, mode: SubMode) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        conn.add_sub();
        g.insert(id, Entry { subs: vec![Sub { conn: conn.clone(), mode }], cancel: None });
        self.update_gauge(&g);
    }

    /// Attach the upstream cancel handle once submission returns.  The
    /// entry may already be gone (router-terminal events publish during
    /// submit); that is fine — a terminal request needs no cancel.
    pub fn set_cancel(&self, id: u64, cancel: CancelHandle) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = g.get_mut(&id) {
            e.cancel = Some(cancel);
        }
    }

    /// Attach a watcher to a live generation.  `false` when the id is
    /// unknown or already terminal.
    pub fn watch(&self, id: u64, conn: &Arc<ConnQueue>) -> bool {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Some(e) = g.get_mut(&id) else { return false };
        conn.add_sub();
        e.subs.push(Sub { conn: conn.clone(), mode: SubMode::Watch });
        self.update_gauge(&g);
        true
    }

    /// Publish one upstream event to every subscriber of its generation.
    /// Terminal events retire the entry.  Never blocks: buffer pressure is
    /// resolved frame-by-frame by each subscriber's queue policy.
    pub fn publish(&self, ev: &Event) {
        let id = match ev {
            Event::Started { id } | Event::Failed { id, .. } => *id,
            Event::Token { id, .. } => *id,
            Event::Done(r) => r.id,
        };
        let terminal = ev.is_terminal();
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Some(entry) = g.get_mut(&id) else {
            // All subscribers left (the request was cancelled) or the id
            // was never registered; nothing is listening.
            return;
        };
        let stream_line = format_event(ev);
        for sub in &entry.subs {
            let line: Option<(String, bool)> = match sub.mode {
                SubMode::Stream | SubMode::Watch => Some((stream_line.clone(), !terminal)),
                SubMode::V1 => match ev {
                    Event::Done(r) => Some((format_response(r), false)),
                    Event::Failed { id, reason, .. } => {
                        Some((format_response(&Response::failure(*id, reason.clone())), false))
                    }
                    _ => None,
                },
            };
            let Some((line, droppable)) = line else { continue };
            match sub.conn.push(&line, droppable) {
                PushOutcome::Queued => {}
                PushOutcome::Dropped(n) => {
                    self.metrics.frames_dropped.add(n);
                    let lag = Json::obj(vec![
                        ("event", Json::Str("lagged".into())),
                        ("id", Json::Num(id as f64)),
                        ("dropped", Json::Num(n as f64)),
                        ("total_dropped", Json::Num(sub.conn.dropped_total() as f64)),
                    ])
                    .dump();
                    if let PushOutcome::Dropped(m) = sub.conn.push(&lag, true) {
                        self.metrics.frames_dropped.add(m);
                    }
                }
                PushOutcome::Killed => {
                    // Disconnect policy fired: the queue holds only the
                    // goodbye frame now; the reactor closes the socket on
                    // its next flush and `drop_conn` cancels upstream.
                    self.metrics.conns_dropped_slow.add(1);
                }
            }
            self.notifier.mark(&sub.conn);
        }
        if terminal {
            if let Some(e) = g.remove(&id) {
                for s in &e.subs {
                    s.conn.remove_sub();
                }
            }
            self.update_gauge(&g);
        }
    }

    /// A connection closed: detach it from every generation.  Generations
    /// left with zero subscribers are cancelled upstream — nobody is
    /// listening, so decoding to `max_new` would burn a lane for nothing.
    pub fn drop_conn(&self, conn: &Arc<ConnQueue>) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.retain(|_, e| {
            let before = e.subs.len();
            e.subs.retain(|s| !Arc::ptr_eq(&s.conn, conn));
            for _ in e.subs.len()..before {
                conn.remove_sub();
            }
            if e.subs.is_empty() {
                if let Some(c) = &e.cancel {
                    c.cancel();
                }
                false
            } else {
                true
            }
        });
        self.update_gauge(&g);
    }

    /// Live subscriptions across all generations (the gauge's source).
    pub fn subscriber_count(&self) -> usize {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.values().map(|e| e.subs.len()).sum()
    }

    /// Whether a generation still has a live hub entry (test hook).
    pub fn is_live(&self, id: u64) -> bool {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).contains_key(&id)
    }

    fn update_gauge(&self, g: &HashMap<u64, Entry>) {
        let total: usize = g.values().map(|e| e.subs.len()).sum();
        self.metrics.fanout_subscribers.set(total as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::super::conn::{BufferPolicy, OverflowPolicy};
    use super::*;
    use crate::metrics::ServeMetrics;

    fn hub() -> (Hub, Arc<PoolMetrics>) {
        let metrics = Arc::new(PoolMetrics::new(vec![Arc::new(ServeMetrics::default())]));
        (Hub::new(metrics.clone(), Notifier::new(None)), metrics)
    }

    fn queue(token: u64) -> Arc<ConnQueue> {
        let policy = BufferPolicy { max_bytes: 1 << 16, on_full: OverflowPolicy::Disconnect };
        ConnQueue::new(token, policy)
    }

    fn drain(q: &ConnQueue) -> Vec<Json> {
        let mut sink = Vec::new();
        q.write_to(&mut sink).unwrap();
        String::from_utf8(sink)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect()
    }

    fn token_event(id: u64, index: usize) -> Event {
        Event::Token { id, index, text: "x".into() }
    }

    fn done_event(id: u64) -> Event {
        Event::Done(Response::failure(id, String::new()))
    }

    #[test]
    fn stream_subscribers_get_every_frame_and_terminal_retires() {
        let (hub, m) = hub();
        let a = queue(1);
        hub.register(7, &a, SubMode::Stream);
        assert_eq!(m.fanout_subscribers.get(), 1);
        assert!(hub.is_live(7));
        hub.publish(&Event::Started { id: 7 });
        hub.publish(&token_event(7, 0));
        hub.publish(&done_event(7));
        let frames = drain(&a);
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0].str_or("event", ""), "started");
        assert_eq!(frames[1].str_or("event", ""), "token");
        assert_eq!(frames[2].str_or("event", ""), "done");
        assert!(!hub.is_live(7), "terminal event retires the entry");
        assert_eq!(m.fanout_subscribers.get(), 0);
        assert_eq!(a.subs(), 0);
        // Late events for a retired id are dropped silently.
        hub.publish(&token_event(7, 1));
        assert!(drain(&a).is_empty());
    }

    #[test]
    fn v1_subscribers_see_only_the_terminal_line() {
        let (hub, _) = hub();
        let a = queue(1);
        hub.register(3, &a, SubMode::V1);
        hub.publish(&Event::Started { id: 3 });
        hub.publish(&token_event(3, 0));
        assert!(drain(&a).is_empty(), "no frames before terminal");
        hub.publish(&done_event(3));
        let frames = drain(&a);
        assert_eq!(frames.len(), 1);
        assert!(frames[0].get("event").is_none(), "v1 line, not a v2 frame");
        assert_eq!(frames[0].num_or("id", 0.0), 3.0);
        // A failed v1 request gets the v1 failure-shaped response line.
        let b = queue(2);
        hub.register(4, &b, SubMode::V1);
        hub.publish(&Event::Failed { id: 4, reason: "[cancelled]".into(), retryable: false });
        let frames = drain(&b);
        assert_eq!(frames.len(), 1);
        assert!(frames[0].str_or("text", "").contains("[cancelled]"));
    }

    #[test]
    fn watchers_share_one_upstream_stream() {
        let (hub, m) = hub();
        let a = queue(1);
        let b = queue(2);
        let c = queue(3);
        hub.register(9, &a, SubMode::Stream);
        assert!(hub.watch(9, &b));
        assert!(hub.watch(9, &c));
        assert_eq!(m.fanout_subscribers.get(), 3);
        hub.publish(&token_event(9, 0));
        for q in [&a, &b, &c] {
            let frames = drain(q);
            assert_eq!(frames.len(), 1, "every subscriber sees the frame");
            assert_eq!(frames[0].str_or("event", ""), "token");
        }
        // Watching an unknown or finished id is refused.
        assert!(!hub.watch(42, &b));
        hub.publish(&done_event(9));
        assert!(!hub.watch(9, &b), "terminal id cannot be watched");
    }

    #[test]
    fn slow_watcher_is_clamped_without_touching_others() {
        let (hub, m) = hub();
        let fast = queue(1);
        // Slow reader with a tiny buffer under the Disconnect policy.
        let slow_policy = BufferPolicy { max_bytes: 64, on_full: OverflowPolicy::Disconnect };
        let slow = ConnQueue::new(2, slow_policy);
        hub.register(5, &fast, SubMode::Stream);
        assert!(hub.watch(5, &slow));
        for i in 0..16 {
            hub.publish(&token_event(5, i));
        }
        assert!(slow.killed(), "slow watcher hit the disconnect policy");
        assert_eq!(m.conns_dropped_slow.get(), 1);
        // The fast subscriber got every frame regardless.
        assert_eq!(drain(&fast).len(), 16);
        // Terminal frames still deliver everywhere they can.
        hub.publish(&done_event(5));
        assert_eq!(drain(&fast).len(), 1);
    }

    #[test]
    fn drop_oldest_watcher_gets_lagged_frames() {
        let (hub, m) = hub();
        let lossy_policy = BufferPolicy { max_bytes: 96, on_full: OverflowPolicy::DropOldest };
        let lossy = ConnQueue::new(1, lossy_policy);
        hub.register(6, &lossy, SubMode::Stream);
        for i in 0..24 {
            hub.publish(&token_event(6, i));
        }
        hub.publish(&done_event(6));
        assert!(m.frames_dropped.get() > 0, "buffer pressure dropped frames");
        let frames = drain(&lossy);
        let lagged: Vec<&Json> =
            frames.iter().filter(|f| f.str_or("event", "") == "lagged").collect();
        assert!(!lagged.is_empty(), "client was told about the gap");
        assert!(lagged.iter().all(|f| f.num_or("dropped", 0.0) >= 1.0));
        assert_eq!(
            frames.last().unwrap().str_or("event", ""),
            "done",
            "terminal frame survives any amount of pressure"
        );
    }

    #[test]
    fn last_subscriber_leaving_drops_the_entry() {
        let (hub, m) = hub();
        let a = queue(1);
        let b = queue(2);
        hub.register(8, &a, SubMode::Stream);
        assert!(hub.watch(8, &b));
        hub.drop_conn(&a);
        assert!(hub.is_live(8), "watcher still listening");
        assert_eq!(m.fanout_subscribers.get(), 1);
        hub.drop_conn(&b);
        assert!(!hub.is_live(8), "no subscribers left; entry cancelled away");
        assert_eq!(m.fanout_subscribers.get(), 0);
    }
}

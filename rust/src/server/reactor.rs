//! The frontend event loop: nonblocking accept + readiness-driven I/O on
//! one thread, whatever the connection count.
//!
//! One reactor thread owns the listener, a loopback waker socket, and
//! every client connection.  On Linux the poller is raw `epoll` (declared
//! directly against libc, which std already links); elsewhere a portable
//! scan poller reports registered interests on a short tick and relies on
//! nonblocking sockets tolerating spurious readiness.  Two more threads
//! complete the frontend: a *pump* that drains the pool's single shared
//! event channel into the broadcast [`Hub`], and a parked stop-waker that
//! pokes the reactor when [`StopSignal`] is raised.  Total frontend
//! threads: 3 — O(1) in connections, where the old frontend spawned one
//! blocking thread per accepted socket.
//!
//! Data flow per request: the reactor parses a line, registers the
//! connection with the hub, and submits via
//! [`ServePool::submit_stream_with`] with the shared event sender.  Worker
//! events arrive id-tagged on that one channel; the pump publishes them to
//! the hub, which pushes formatted frames into each subscriber's
//! [`ConnQueue`] and marks the connection dirty via the [`Notifier`].  The
//! reactor flushes dirty connections on its next wakeup — only dirty ones,
//! never an O(connections) scan.
//!
//! Backpressure never parks a thread: a connection whose outbound queue
//! grows past half its buffer (or with too many in-flight subscriptions)
//! simply loses read interest until the queue drains — the kernel's TCP
//! window then pushes back on the client.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Event, ServePool};
use crate::metrics::export::MetricsSnapshot;
use crate::util::json::Json;

use self::poller::Poller;
use super::broadcast::{Hub, SubMode};
use super::conn::{BufferPolicy, Conn, ConnQueue, LineEvent, Notifier};
use super::{admin_response, parse_admin_op, parse_request, StopSignal};

/// Poller token of the accept listener.
const TOK_LISTENER: u64 = 0;
/// Poller token of the loopback waker's read end.
const TOK_WAKER: u64 = 1;
/// First token handed to an accepted connection (tokens are never reused).
const FIRST_CONN_TOKEN: u64 = 2;

/// A connection subscribed to this many generations at once stops being
/// read until some of them finish (per-connection in-flight bound).
const MAX_CONN_SUBS: usize = 64;

/// Frontend tunables (`--max-conns`, `--max-line-bytes`,
/// `--client-buffer`, `--client-buffer-policy` on the serve command).
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Accepted-connection cap; excess connects get a typed `max_conns`
    /// error line and are dropped.
    pub max_conns: usize,
    /// Request-line byte cap (the unbounded-`read_line` OOM fix); an
    /// oversized line gets a typed `line_too_long` error and the rest of
    /// the line is discarded.
    pub max_line_bytes: usize,
    /// Per-client outbound buffer bound + slow-reader policy.
    pub buffer: BufferPolicy,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 10_000,
            max_line_bytes: 256 * 1024,
            buffer: BufferPolicy::default(),
        }
    }
}

/// What one `accept()` error means for the accept loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcceptDisposition {
    /// `WouldBlock`: the backlog is drained; return to the poller.
    Drained,
    /// The *accepted* socket died (reset/aborted mid-handshake) or the
    /// call was interrupted: log and keep accepting.
    Transient,
    /// fd exhaustion (`EMFILE`/`ENFILE`): pause briefly so in-flight
    /// closes can release descriptors, then resume.
    Backoff,
    /// The listener itself is broken: tear the frontend down.
    Fatal,
}

/// Classify an `accept()` error.  The old frontend treated every error as
/// fatal, so one aborted handshake or fd-pressure blip killed the server.
pub fn classify_accept_error(e: &io::Error) -> AcceptDisposition {
    match e.kind() {
        io::ErrorKind::WouldBlock => AcceptDisposition::Drained,
        io::ErrorKind::Interrupted
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::ConnectionReset => AcceptDisposition::Transient,
        // ENFILE (23) / EMFILE (24) carry no dedicated ErrorKind on stable.
        _ => match e.raw_os_error() {
            Some(23) | Some(24) => AcceptDisposition::Backoff,
            _ => AcceptDisposition::Fatal,
        },
    }
}

/// Build the reactor's self-wake channel: a connected loopback TCP pair
/// (std offers no portable pipe).  The returned `(rx, tx)` ends are both
/// nonblocking; the transient listener is dropped before returning.
fn waker_pair() -> Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind waker listener")?;
    let addr = listener.local_addr()?;
    let tx = TcpStream::connect(addr).context("connect waker pair")?;
    let local = tx.local_addr()?;
    // Accept until we see our own connect; a stranger racing the ephemeral
    // port is dropped on the floor.
    for _ in 0..16 {
        let (rx, peer) = listener.accept().context("accept waker pair")?;
        if peer == local {
            rx.set_nonblocking(true)?;
            tx.set_nonblocking(true)?;
            return Ok((rx, tx));
        }
    }
    bail!("waker pair: loopback accept never returned our own connection")
}

/// Serve until `stop` is raised.  Spawns the pump and stop-waker threads
/// in a scope and runs the reactor loop on the calling thread.
pub fn serve(pool: &ServePool, addr: &str, stop: Arc<StopSignal>, cfg: ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    println!("[server] listening on {addr}");
    let (wake_rx, wake_tx) = waker_pair().context("frontend waker pair")?;
    let notifier = Notifier::new(Some(wake_tx));
    let hub = Arc::new(Hub::new(pool.metrics.clone(), notifier.clone()));
    let (ev_tx, ev_rx) = channel::<Event>();
    std::thread::scope(|scope| -> Result<()> {
        // Stop-waker: parks on the condvar (zero idle wakeups) and pokes
        // the reactor out of its poller wait when the signal is raised.
        {
            let stop = stop.clone();
            let notifier = notifier.clone();
            scope.spawn(move || {
                stop.wait();
                notifier.wake();
            });
        }
        // Pump: single consumer of the pool's shared event channel.
        {
            let hub = hub.clone();
            let stop = stop.clone();
            scope.spawn(move || pump_loop(ev_rx, &hub, &stop));
        }
        let poller = Poller::new()?;
        poller.add(&listener, TOK_LISTENER, true, false)?;
        poller.add(&wake_rx, TOK_WAKER, true, false)?;
        let mut reactor = Reactor {
            pool,
            listener,
            wake_rx,
            notifier,
            hub,
            ev_tx,
            cfg,
            stop: stop.clone(),
            poller,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            next_req_id: 0,
            scrape_baselines: HashMap::new(),
            read_paused_count: 0,
        };
        let res = reactor.run();
        // Every exit path raises stop so the waker and pump threads join
        // and the scope can close.
        stop.raise();
        res
    })
}

/// Drain the pool's shared event channel into the broadcast hub.  Blocking
/// `recv` with a short timeout so a raised stop is noticed promptly; no
/// busy polling.
fn pump_loop(ev_rx: Receiver<Event>, hub: &Hub, stop: &StopSignal) {
    loop {
        if stop.raised() {
            return;
        }
        match ev_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => hub.publish(&ev),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

struct Reactor<'p> {
    pool: &'p ServePool,
    listener: TcpListener,
    wake_rx: TcpStream,
    notifier: Arc<Notifier>,
    hub: Arc<Hub>,
    ev_tx: Sender<Event>,
    cfg: ServerConfig,
    stop: Arc<StopSignal>,
    poller: Poller,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    next_req_id: u64,
    /// `{"op":"metrics"}` rate baselines, keyed by the caller-supplied
    /// `"scraper"` tag (`""` for untagged scrapers) so concurrent scrapers
    /// never corrupt each other's deltas.
    scrape_baselines: HashMap<String, MetricsSnapshot>,
    read_paused_count: usize,
}

impl Reactor<'_> {
    fn run(&mut self) -> Result<()> {
        loop {
            let events = self.poller.wait(500)?;
            if self.stop.raised() {
                self.shutdown_conns();
                return Ok(());
            }
            for ev in &events {
                match ev.token {
                    TOK_LISTENER => self.accept_ready()?,
                    TOK_WAKER => self.drain_waker(),
                    t => self.conn_event(t, *ev),
                }
            }
            self.flush_dirty();
        }
    }

    /// Accept until the backlog drains.  Transient errors log and
    /// continue; fd pressure backs off; only a broken listener is fatal.
    fn accept_ready(&mut self) -> Result<()> {
        loop {
            if self.stop.raised() {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, peer)) => self.admit(stream, peer),
                Err(e) => match classify_accept_error(&e) {
                    AcceptDisposition::Drained => return Ok(()),
                    AcceptDisposition::Transient => {
                        self.pool.metrics.accept_transient_errors.add(1);
                        log::warn!("transient accept error: {e}");
                    }
                    AcceptDisposition::Backoff => {
                        self.pool.metrics.accept_transient_errors.add(1);
                        log::warn!("accept hit fd pressure ({e}); backing off");
                        std::thread::sleep(Duration::from_millis(20));
                        return Ok(());
                    }
                    AcceptDisposition::Fatal => {
                        return Err(e).context("accept on frontend listener");
                    }
                },
            }
        }
    }

    fn admit(&mut self, stream: TcpStream, peer: SocketAddr) {
        if self.conns.len() >= self.cfg.max_conns {
            let mut s = stream;
            let msg = Json::obj(vec![
                (
                    "error",
                    Json::Str(format!("server at max connections ({})", self.cfg.max_conns)),
                ),
                ("code", Json::Str("max_conns".into())),
            ])
            .dump();
            // Best-effort typed rejection; the socket drops either way.
            let _ = s.write_all((msg + "\n").as_bytes());
            log::warn!("rejecting connection from {peer}: at --max-conns");
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let t = self.next_token;
        self.next_token += 1;
        if let Err(e) = self.poller.add(&stream, t, true, false) {
            log::warn!("poller add for {peer}: {e:#}");
            return;
        }
        let q = ConnQueue::new(t, self.cfg.buffer);
        self.conns.insert(t, Conn::new(stream, peer.to_string(), self.cfg.max_line_bytes, q));
        self.pool.metrics.conns_open.set(self.conns.len() as u64);
        log::info!("connection from {peer}");
    }

    /// Drain the waker socket (its bytes carry no data, only readiness).
    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }

    fn conn_event(&mut self, t: u64, ev: poller::PollEvent) {
        if !self.conns.contains_key(&t) {
            return; // closed earlier in this dispatch round
        }
        if ev.readable {
            self.conn_readable(t);
        }
        if !self.conns.contains_key(&t) {
            return;
        }
        if ev.writable {
            self.flush_conn(t);
        }
        if ev.hangup && !ev.readable && self.conns.contains_key(&t) {
            self.close_conn(t, "peer hung up");
        }
    }

    fn conn_readable(&mut self, t: u64) {
        let mut line_events = Vec::new();
        let closed = match self.conns.get_mut(&t) {
            // A paused connection keeps no read interest, but the fallback
            // poller (and a late epoll event) may still report readiness.
            Some(c) if !c.read_paused => c.read_ready(&mut line_events),
            _ => false,
        };
        for le in line_events {
            match le {
                LineEvent::Line(line) => self.process_line(t, &line),
                LineEvent::Oversize => {
                    let msg = Json::obj(vec![
                        (
                            "error",
                            Json::Str(format!(
                                "request line exceeds {} bytes",
                                self.cfg.max_line_bytes
                            )),
                        ),
                        ("code", Json::Str("line_too_long".into())),
                    ])
                    .dump();
                    self.push_to(t, &msg);
                }
            }
        }
        if closed {
            self.close_conn(t, "peer closed");
            return;
        }
        self.flush_conn(t);
    }

    /// Dispatch one complete request line: admin op, watch, or inference
    /// request.  Inference requests register their hub subscription BEFORE
    /// submission so synchronously-published router-terminal events cannot
    /// be lost.
    fn process_line(&mut self, t: u64, raw: &str) {
        let line = raw.trim();
        if line.is_empty() {
            return;
        }
        if let Some(op) = parse_admin_op(line) {
            if op.str_or("op", "") == "watch" {
                self.handle_watch(t, &op);
            } else {
                let reply = admin_response(self.pool, &op, &mut self.scrape_baselines);
                self.push_to(t, &reply.dump());
            }
            return;
        }
        self.next_req_id += 1;
        let id = self.next_req_id;
        match parse_request(line, id) {
            Err(e) => {
                let msg = Json::obj(vec![("error", Json::Str(format!("{e:#}")))]).dump();
                self.push_to(t, &msg);
            }
            Ok((req, streaming)) => {
                let Some(c) = self.conns.get(&t) else { return };
                let q = c.out.clone();
                let mode = if streaming { SubMode::Stream } else { SubMode::V1 };
                self.hub.register(id, &q, mode);
                let cancel = self.pool.submit_stream_with(req, &self.ev_tx);
                self.hub.set_cancel(id, cancel);
            }
        }
    }

    /// `{"op":"watch","id":N}`: attach this connection to a live
    /// generation's event stream (broadcast fan-out).
    fn handle_watch(&mut self, t: u64, op: &Json) {
        let id = op.get("id").and_then(Json::as_f64).map(|v| v as u64);
        let reply = match id {
            Some(id) => {
                let Some(c) = self.conns.get(&t) else { return };
                let q = c.out.clone();
                if self.hub.watch(id, &q) {
                    Json::obj(vec![
                        ("op", Json::Str("watch".into())),
                        ("ok", Json::Bool(true)),
                        ("id", Json::Num(id as f64)),
                    ])
                } else {
                    Json::obj(vec![
                        ("op", Json::Str("watch".into())),
                        ("ok", Json::Bool(false)),
                        ("id", Json::Num(id as f64)),
                        ("error", Json::Str(format!("no live generation {id}"))),
                    ])
                }
            }
            None => Json::obj(vec![
                ("op", Json::Str("watch".into())),
                ("ok", Json::Bool(false)),
                ("error", Json::Str("watch needs a numeric \"id\"".into())),
            ]),
        };
        self.push_to(t, &reply.dump());
    }

    /// Queue a reactor-origin reply (never droppable).
    fn push_to(&mut self, t: u64, line: &str) {
        if let Some(c) = self.conns.get(&t) {
            let _ = c.out.push(line, false);
        }
    }

    /// Flush every connection the pump marked dirty since the last round.
    /// Disarm-before-take ordering guarantees a mark landing mid-drain
    /// still produces a wake.
    fn flush_dirty(&mut self) {
        self.notifier.disarm();
        for t in self.notifier.take_dirty() {
            if let Some(c) = self.conns.get(&t) {
                c.out.clear_dirty();
            }
            self.flush_conn(t);
        }
    }

    /// One write round for a connection, then recompute poller interest:
    /// write interest iff bytes remain queued; read interest withdrawn
    /// (backpressure) while the queue is above half its cap or too many
    /// generations are in flight.
    fn flush_conn(&mut self, t: u64) {
        let res = match self.conns.get_mut(&t) {
            Some(c) => c.flush(),
            None => return,
        };
        let st = match res {
            Ok(st) => st,
            Err(e) => {
                self.close_conn(t, &format!("write error: {e}"));
                return;
            }
        };
        if st.killed {
            // Buffer policy condemned it; the goodbye frame had its write
            // attempt (best effort — the client wasn't reading anyway).
            self.close_conn(t, "slow reader hit the disconnect policy");
            return;
        }
        let want_write = st.remaining > 0;
        let subs = self.conns.get(&t).map_or(0, |c| c.out.subs());
        let pause = st.remaining > self.cfg.buffer.max_bytes / 2 || subs >= MAX_CONN_SUBS;
        self.set_interest(t, !pause, want_write);
    }

    /// Reconcile a connection's poller registration with the desired
    /// read/write interest; no-op when nothing changed.
    fn set_interest(&mut self, t: u64, read: bool, write: bool) {
        let Some(c) = self.conns.get_mut(&t) else { return };
        let paused = !read;
        if c.read_paused == paused && c.want_write == write {
            return;
        }
        if let Err(e) = self.poller.modify(&c.stream, t, read, write) {
            log::warn!("poller modify for {}: {e:#}", c.peer);
            return;
        }
        c.want_write = write;
        if c.read_paused != paused {
            c.read_paused = paused;
            if paused {
                self.read_paused_count += 1;
            } else {
                self.read_paused_count -= 1;
            }
            self.pool.metrics.conns_read_paused.set(self.read_paused_count as u64);
        }
    }

    fn close_conn(&mut self, t: u64, why: &str) {
        let Some(c) = self.conns.remove(&t) else { return };
        let _ = self.poller.remove(&c.stream, t);
        if c.read_paused {
            self.read_paused_count -= 1;
            self.pool.metrics.conns_read_paused.set(self.read_paused_count as u64);
        }
        // Detach from every generation; ones left without subscribers are
        // cancelled upstream.
        self.hub.drop_conn(&c.out);
        self.pool.metrics.conns_open.set(self.conns.len() as u64);
        log::info!("connection closed ({why}): {}", c.peer);
    }

    fn shutdown_conns(&mut self) {
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            self.close_conn(t, "server stopping");
        }
    }
}

/// One readiness event out of the poller.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

#[cfg(target_os = "linux")]
mod poller {
    //! Raw epoll, declared directly against libc (std links it already;
    //! the workspace vendors no `libc` crate).

    use std::os::raw::c_int;
    use std::os::unix::io::{AsRawFd, RawFd};

    use anyhow::{bail, Result};

    pub(crate) use super::PollEvent;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const MAX_EVENTS: usize = 128;

    // The kernel ABI packs epoll_event on x86-64 only.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    pub(crate) struct Poller {
        epfd: c_int,
    }

    impl Poller {
        pub fn new() -> Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                bail!("epoll_create1: {}", std::io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, read: bool, write: bool) -> Result<()> {
            // Always watch for peer half-close so an idle paused connection
            // still reports its death.
            let mut events = EPOLLRDHUP;
            if read {
                events |= EPOLLIN;
            }
            if write {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
                bail!("epoll_ctl(op={op}): {}", std::io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add<T: AsRawFd>(&self, io: &T, token: u64, read: bool, write: bool) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, io.as_raw_fd(), token, read, write)
        }

        pub fn modify<T: AsRawFd>(
            &self,
            io: &T,
            token: u64,
            read: bool,
            write: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, io.as_raw_fd(), token, read, write)
        }

        pub fn remove<T: AsRawFd>(&self, io: &T, _token: u64) -> Result<()> {
            let rc = unsafe {
                epoll_ctl(self.epfd, EPOLL_CTL_DEL, io.as_raw_fd(), std::ptr::null_mut())
            };
            if rc < 0 {
                bail!("epoll_ctl(DEL): {}", std::io::Error::last_os_error());
            }
            Ok(())
        }

        /// Wait up to `timeout_ms` for readiness; `EINTR` reports as an
        /// empty round.
        pub fn wait(&self, timeout_ms: i32) -> Result<Vec<PollEvent>> {
            let mut buf: Vec<EpollEvent> = Vec::with_capacity(MAX_EVENTS);
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), MAX_EVENTS as c_int, timeout_ms)
            };
            if n < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    return Ok(Vec::new());
                }
                bail!("epoll_wait: {e}");
            }
            // SAFETY: the kernel initialized the first n entries.
            unsafe { buf.set_len(n as usize) };
            Ok(buf
                .iter()
                .map(|e| {
                    // Copy out of the (possibly packed) struct by value.
                    let flags = e.events;
                    let token = e.data;
                    PollEvent {
                        token,
                        readable: flags & EPOLLIN != 0,
                        writable: flags & EPOLLOUT != 0,
                        hangup: flags & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                    }
                })
                .collect())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            let _ = unsafe { close(self.epfd) };
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod poller {
    //! Portable fallback: no OS readiness facility, so every registered
    //! interest is reported on a short fixed tick.  All sockets are
    //! nonblocking, so a spurious report costs one `WouldBlock` syscall.
    //! Functionally equivalent to the epoll poller, with idle CPU cost —
    //! production deployments are Linux.

    use std::collections::BTreeMap;
    use std::sync::Mutex;
    use std::time::Duration;

    use anyhow::Result;

    pub(crate) use super::PollEvent;

    pub(crate) struct Poller {
        interests: Mutex<BTreeMap<u64, (bool, bool)>>,
    }

    impl Poller {
        pub fn new() -> Result<Poller> {
            Ok(Poller { interests: Mutex::new(BTreeMap::new()) })
        }

        pub fn add<T>(&self, _io: &T, token: u64, read: bool, write: bool) -> Result<()> {
            self.interests.lock().unwrap().insert(token, (read, write));
            Ok(())
        }

        pub fn modify<T>(&self, _io: &T, token: u64, read: bool, write: bool) -> Result<()> {
            self.add(_io, token, read, write)
        }

        pub fn remove<T>(&self, _io: &T, token: u64) -> Result<()> {
            self.interests.lock().unwrap().remove(&token);
            Ok(())
        }

        pub fn wait(&self, timeout_ms: i32) -> Result<Vec<PollEvent>> {
            let tick = i64::from(timeout_ms).clamp(1, 2) as u64;
            std::thread::sleep(Duration::from_millis(tick));
            Ok(self
                .interests
                .lock()
                .unwrap()
                .iter()
                .map(|(&token, &(read, write))| PollEvent {
                    token,
                    readable: read,
                    writable: write,
                    hangup: false,
                })
                .collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_error_classification() {
        let would_block = io::Error::new(io::ErrorKind::WouldBlock, "drained");
        assert_eq!(classify_accept_error(&would_block), AcceptDisposition::Drained);
        for kind in [
            io::ErrorKind::Interrupted,
            io::ErrorKind::ConnectionAborted,
            io::ErrorKind::ConnectionReset,
        ] {
            let e = io::Error::new(kind, "blip");
            assert_eq!(classify_accept_error(&e), AcceptDisposition::Transient, "{kind:?}");
        }
        // ECONNABORTED by raw errno resolves through its ErrorKind too.
        let aborted = io::Error::from_raw_os_error(103);
        assert_eq!(classify_accept_error(&aborted), AcceptDisposition::Transient);
        // ENFILE / EMFILE: fd pressure backs off instead of dying.
        for errno in [23, 24] {
            let e = io::Error::from_raw_os_error(errno);
            assert_eq!(classify_accept_error(&e), AcceptDisposition::Backoff, "errno {errno}");
        }
        // Anything else (here EBADF) is a broken listener.
        let ebadf = io::Error::from_raw_os_error(9);
        assert_eq!(classify_accept_error(&ebadf), AcceptDisposition::Fatal);
    }

    #[test]
    fn server_config_defaults() {
        let cfg = ServerConfig::default();
        assert_eq!(cfg.max_conns, 10_000);
        assert_eq!(cfg.max_line_bytes, 256 * 1024);
        assert_eq!(cfg.buffer.max_bytes, 1 << 20);
    }

    #[test]
    fn waker_pair_carries_a_wake_byte() {
        let (mut rx, tx) = waker_pair().unwrap();
        let mut buf = [0u8; 8];
        assert_eq!(
            rx.read(&mut buf).unwrap_err().kind(),
            io::ErrorKind::WouldBlock,
            "no byte before a wake"
        );
        let notifier = Notifier::new(Some(tx));
        notifier.wake();
        notifier.wake(); // coalesced: at most one byte per disarm window
        // Nonblocking read may race the loopback delivery; retry briefly.
        let n = (0..100)
            .find_map(|_| match rx.read(&mut buf) {
                Ok(n) => Some(n),
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(2));
                    None
                }
            })
            .expect("wake byte arrives");
        assert_eq!(n, 1, "second wake was coalesced");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_poller_reports_listener_readiness() {
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller.add(&listener, 42, true, false).unwrap();
        assert!(
            poller.wait(0).unwrap().is_empty(),
            "no readiness before a client connects"
        );
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let events = poller.wait(2000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 42 && e.readable),
            "pending accept surfaces as read-readiness"
        );
        poller.remove(&listener, 42).unwrap();
        let _ = TcpStream::connect(listener.local_addr().unwrap());
        assert!(
            poller.wait(10).unwrap().iter().all(|e| e.token != 42),
            "deregistered fd reports nothing"
        );
    }
}

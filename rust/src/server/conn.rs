//! Per-connection state for the event-driven frontend: incremental NDJSON
//! line assembly on the read side, a policy-bounded outbound frame queue on
//! the write side, and the dirty-list notifier that carries "this
//! connection has frames to flush" from the pump thread to the reactor.
//!
//! The pieces compose into the connection state machine DESIGN.md §9
//! documents:
//!
//! * [`LineReader`] — reads are readiness-driven and arrive in arbitrary
//!   chunks, so request lines are assembled incrementally.  A line that
//!   exceeds the configured cap yields exactly one [`LineEvent::Oversize`]
//!   and the reader discards bytes until the next newline; the connection
//!   survives (the reactor answers with a typed `line_too_long` error).
//! * [`ConnQueue`] — every frame destined for a client (token events,
//!   admin replies, v1 responses) is queued here and written out on
//!   write-readiness.  The queue is shared between the reactor thread
//!   (writer/drainer) and the pump thread (producer), and it is *bounded*:
//!   a slow reader hits its [`BufferPolicy`] instead of growing the queue
//!   or blocking any worker thread.
//! * [`Notifier`] — the pump marks connections dirty and pokes the
//!   reactor's waker socket; the reactor swaps the dirty list and flushes
//!   only those connections (never an O(connections) scan per event).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// What happens to a client whose outbound buffer is full (it is reading
/// slower than its subscribed generations produce frames).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the oldest droppable frames to make room and tell the client
    /// with a `{"event":"lagged","dropped":N}` frame.  Terminal frames are
    /// never dropped, so every stream still ends with `done`/`failed`.
    DropOldest,
    /// Clamp hard: clear the queue, send one typed
    /// `{"event":"disconnected"}` frame best-effort, and close the
    /// connection.  Its in-flight requests are cancelled so no decode lane
    /// keeps producing for a reader that cannot keep up.
    Disconnect,
}

/// Per-client outbound buffer bound (`--client-buffer` /
/// `--client-buffer-policy` on the serve command).
#[derive(Clone, Copy, Debug)]
pub struct BufferPolicy {
    /// Queued (unflushed) frame bytes allowed per connection.
    pub max_bytes: usize,
    pub on_full: OverflowPolicy,
}

impl Default for BufferPolicy {
    fn default() -> BufferPolicy {
        BufferPolicy { max_bytes: 1 << 20, on_full: OverflowPolicy::Disconnect }
    }
}

/// One incremental-read event out of [`LineReader::ingest`].
#[derive(Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// A complete request line (newline stripped, one trailing `\r`
    /// tolerated for telnet-style clients).
    Line(String),
    /// The line under assembly exceeded the cap; its remaining bytes are
    /// being discarded until the next newline.  Emitted once per oversized
    /// line.
    Oversize,
}

/// Incremental NDJSON line assembler with a hard per-line byte cap — the
/// fix for the unbounded `read_line` the thread-per-connection frontend
/// used (one client streaming an endless line could OOM the server).
pub struct LineReader {
    buf: Vec<u8>,
    cap: usize,
    discarding: bool,
}

impl LineReader {
    pub fn new(cap: usize) -> LineReader {
        LineReader { buf: Vec::new(), cap, discarding: false }
    }

    /// Feed one chunk of bytes; completed lines (and oversize events) are
    /// appended to `out` in arrival order.
    pub fn ingest(&mut self, data: &[u8], out: &mut Vec<LineEvent>) {
        let mut rest = data;
        while let Some(pos) = rest.iter().position(|&b| b == b'\n') {
            let (head, tail) = rest.split_at(pos);
            rest = &tail[1..];
            if self.discarding {
                // Tail of an already-reported oversized line.
                self.discarding = false;
                self.buf.clear();
                continue;
            }
            self.buf.extend_from_slice(head);
            if self.buf.len() > self.cap {
                // The line completed within this chunk but still over cap.
                self.buf.clear();
                out.push(LineEvent::Oversize);
                continue;
            }
            if self.buf.last() == Some(&b'\r') {
                self.buf.pop();
            }
            out.push(LineEvent::Line(String::from_utf8_lossy(&self.buf).into_owned()));
            self.buf.clear();
        }
        if self.discarding {
            return;
        }
        self.buf.extend_from_slice(rest);
        if self.buf.len() > self.cap {
            self.buf.clear();
            self.discarding = true;
            out.push(LineEvent::Oversize);
        }
    }
}

/// One queued outbound frame (a full NDJSON line, newline included).
struct Frame {
    bytes: Vec<u8>,
    /// Whether the buffer policy may discard this frame under pressure.
    /// Terminal frames and reactor-origin replies are not droppable.
    droppable: bool,
}

/// Outcome of one [`ConnQueue::push`].
#[derive(Debug, PartialEq, Eq)]
pub enum PushOutcome {
    Queued,
    /// Queued after the `DropOldest` policy discarded this many older
    /// frames to make room (or discarded the new frame itself when nothing
    /// older could go).
    Dropped(u64),
    /// The `Disconnect` policy fired: the queue was clamped to one typed
    /// goodbye frame and the connection must be closed by the reactor.
    Killed,
}

#[derive(Default)]
struct OutInner {
    frames: VecDeque<Frame>,
    bytes: usize,
    /// Bytes of the head frame already on the wire (a frame can straddle
    /// several write-readiness rounds; a partially-written head is never
    /// dropped, or the client would see corrupt framing).
    head_written: usize,
    killed: Option<String>,
    dropped_total: u64,
}

/// Progress report from one [`ConnQueue::write_to`] round.
#[derive(Debug, Clone, Copy)]
pub struct WriteStatus {
    /// Queued bytes still waiting for write-readiness.
    pub remaining: usize,
    /// The buffer policy condemned this connection; close it once the
    /// goodbye frame had its write attempt.
    pub killed: bool,
}

/// Shared outbound frame queue of one connection.  The reactor thread
/// drains it into the socket; the pump thread (via the broadcast hub)
/// pushes into it.  All bounds are enforced here, at push time, so no
/// producer ever blocks on a slow consumer.
pub struct ConnQueue {
    token: u64,
    policy: BufferPolicy,
    inner: Mutex<OutInner>,
    /// Live stream subscriptions (primary requests + watches) delivering
    /// into this queue; the reactor reads it for read-pause backpressure.
    subs: AtomicUsize,
    /// Set while the token sits on the notifier's dirty list (dedup).
    dirty: AtomicBool,
}

impl ConnQueue {
    pub fn new(token: u64, policy: BufferPolicy) -> Arc<ConnQueue> {
        Arc::new(ConnQueue {
            token,
            policy,
            inner: Mutex::new(OutInner::default()),
            subs: AtomicUsize::new(0),
            dirty: AtomicBool::new(false),
        })
    }

    pub fn token(&self) -> u64 {
        self.token
    }

    pub fn add_sub(&self) {
        self.subs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn remove_sub(&self) {
        self.subs.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn subs(&self) -> usize {
        self.subs.load(Ordering::Relaxed)
    }

    /// Mark dirty; `true` exactly when the caller must enqueue the token on
    /// the notifier (it was clean before).
    pub fn mark_dirty(&self) -> bool {
        !self.dirty.swap(true, Ordering::AcqRel)
    }

    pub fn clear_dirty(&self) {
        self.dirty.store(false, Ordering::Release);
    }

    /// Frames dropped by the `DropOldest` policy over this connection's
    /// lifetime.
    pub fn dropped_total(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).dropped_total
    }

    pub fn queued_bytes(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).bytes
    }

    pub fn killed(&self) -> bool {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).killed.is_some()
    }

    /// Queue one NDJSON line (newline appended).  Non-droppable frames
    /// always queue — a terminal frame per stream is small and bounded —
    /// while droppable frames are what the [`BufferPolicy`] arbitrates.
    pub fn push(&self, line: &str, droppable: bool) -> PushOutcome {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.killed.is_some() {
            // Condemned connection: the reactor will close it; swallow.
            return PushOutcome::Queued;
        }
        let flen = line.len() + 1;
        let mut dropped = 0u64;
        if droppable && g.bytes + flen > self.policy.max_bytes {
            match self.policy.on_full {
                OverflowPolicy::Disconnect => {
                    Self::kill_locked(&mut g, "client buffer overflow (policy=disconnect)");
                    return PushOutcome::Killed;
                }
                OverflowPolicy::DropOldest => {
                    // Drop from the oldest end, skipping the partially
                    // written head and anything non-droppable.
                    while g.bytes + flen > self.policy.max_bytes {
                        let start = usize::from(g.head_written > 0);
                        let victim = (start..g.frames.len()).find(|&i| g.frames[i].droppable);
                        match victim {
                            Some(i) => {
                                let f = g.frames.remove(i).expect("victim index in range");
                                g.bytes -= f.bytes.len();
                                dropped += 1;
                            }
                            None => {
                                // Nothing droppable left: discard the new
                                // frame instead of growing past the cap.
                                g.dropped_total += dropped + 1;
                                return PushOutcome::Dropped(dropped + 1);
                            }
                        }
                    }
                    g.dropped_total += dropped;
                }
            }
        }
        let mut bytes = Vec::with_capacity(flen);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        g.bytes += bytes.len();
        g.frames.push_back(Frame { bytes, droppable });
        if dropped > 0 {
            PushOutcome::Dropped(dropped)
        } else {
            PushOutcome::Queued
        }
    }

    /// Condemn the connection: clamp the queue to one typed goodbye frame.
    /// The reactor closes the socket after that frame's write attempt.
    pub fn kill(&self, reason: &str) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.killed.is_none() {
            Self::kill_locked(&mut g, reason);
        }
    }

    fn kill_locked(g: &mut OutInner, reason: &str) {
        g.frames.clear();
        g.bytes = 0;
        g.head_written = 0;
        g.killed = Some(reason.to_string());
        let line = Json::obj(vec![
            ("event", Json::Str("disconnected".into())),
            ("error", Json::Str(reason.to_string())),
        ])
        .dump();
        let mut bytes = line.into_bytes();
        bytes.push(b'\n');
        g.bytes = bytes.len();
        g.frames.push_back(Frame { bytes, droppable: false });
    }

    /// Drain queued frames into `w` until empty or `WouldBlock`.  Frames go
    /// out whole and in order; a partial write is resumed on the next
    /// write-readiness round.
    pub fn write_to(&self, w: &mut impl Write) -> io::Result<WriteStatus> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            let Some(front) = g.frames.front() else { break };
            let len = front.bytes.len();
            let chunk = &front.bytes[g.head_written..];
            match w.write(chunk) {
                Ok(0) => {
                    return Err(io::Error::new(io::ErrorKind::WriteZero, "socket wrote zero"));
                }
                Ok(n) => {
                    g.head_written += n;
                    if g.head_written == len {
                        g.frames.pop_front();
                        g.bytes -= len;
                        g.head_written = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(WriteStatus { remaining: g.bytes, killed: g.killed.is_some() })
    }
}

/// Dirty-connection hand-off from the pump thread to the reactor: marked
/// tokens accumulate here and one coalesced byte on the waker socket gets
/// the reactor out of `epoll_wait`.
pub struct Notifier {
    dirty: Mutex<Vec<u64>>,
    wake_tx: Option<TcpStream>,
    /// Coalesces waker-socket writes: armed until the reactor disarms at
    /// the top of its dispatch, so an event burst costs one wake byte.
    armed: AtomicBool,
}

impl Notifier {
    /// `wake_tx` is the write end of the reactor's loopback waker pair
    /// (`None` in unit tests, where nothing sleeps in a poller).
    pub fn new(wake_tx: Option<TcpStream>) -> Arc<Notifier> {
        Arc::new(Notifier { dirty: Mutex::new(Vec::new()), wake_tx, armed: AtomicBool::new(false) })
    }

    /// Record that `q`'s connection has frames to flush and wake the
    /// reactor (deduplicated per flush round).
    pub fn mark(&self, q: &ConnQueue) {
        if q.mark_dirty() {
            self.dirty.lock().unwrap_or_else(|e| e.into_inner()).push(q.token());
        }
        self.wake();
    }

    /// Poke the reactor's waker socket (coalesced; send-buffer-full means a
    /// wake is already pending, so errors are ignored).
    pub fn wake(&self) {
        if !self.armed.swap(true, Ordering::AcqRel) {
            if let Some(tx) = &self.wake_tx {
                let mut tx = tx;
                let _ = tx.write(&[1u8]);
            }
        }
    }

    /// Reactor side: re-arm the waker before draining, so marks landing
    /// mid-drain still produce a wake.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Release);
    }

    /// Reactor side: swap out the dirty token list.
    pub fn take_dirty(&self) -> Vec<u64> {
        std::mem::take(&mut *self.dirty.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

/// One live connection, owned by the reactor thread.
pub struct Conn {
    pub stream: TcpStream,
    pub peer: String,
    pub lines: LineReader,
    pub out: Arc<ConnQueue>,
    /// Read interest withdrawn (backpressure); restored when the outbound
    /// queue drains and the in-flight count falls.
    pub read_paused: bool,
    /// Write interest currently registered with the poller.
    pub want_write: bool,
}

impl Conn {
    pub fn new(stream: TcpStream, peer: String, line_cap: usize, out: Arc<ConnQueue>) -> Conn {
        Conn {
            stream,
            peer,
            lines: LineReader::new(line_cap),
            out,
            read_paused: false,
            want_write: false,
        }
    }

    /// Drain readable bytes into the line assembler.  Returns `true` when
    /// the connection is gone (EOF or a hard read error).
    pub fn read_ready(&mut self, out_events: &mut Vec<LineEvent>) -> bool {
        let mut buf = [0u8; 4096];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => return true,
                Ok(n) => self.lines.ingest(&buf[..n], out_events),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return true,
            }
        }
    }

    /// One write round: drain the outbound queue into the socket.
    pub fn flush(&mut self) -> io::Result<WriteStatus> {
        self.out.write_to(&mut self.stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_of(events: &[LineEvent]) -> Vec<String> {
        events
            .iter()
            .filter_map(|e| match e {
                LineEvent::Line(l) => Some(l.clone()),
                LineEvent::Oversize => None,
            })
            .collect()
    }

    #[test]
    fn line_reader_assembles_across_chunks() {
        let mut r = LineReader::new(64);
        let mut out = Vec::new();
        r.ingest(b"{\"a\":", &mut out);
        assert!(out.is_empty(), "no newline yet");
        r.ingest(b"1}\n{\"b\":2}\n{\"c\"", &mut out);
        assert_eq!(lines_of(&out), vec!["{\"a\":1}", "{\"b\":2}"]);
        out.clear();
        r.ingest(b":3}\r\n", &mut out);
        assert_eq!(lines_of(&out), vec!["{\"c\":3}"], "trailing \\r stripped");
    }

    #[test]
    fn oversized_line_reports_once_and_resyncs() {
        let mut r = LineReader::new(8);
        let mut out = Vec::new();
        // 20 bytes with no newline: one Oversize, then silence while the
        // rest of the poisoned line streams in.
        r.ingest(b"aaaaaaaaaaaaaaaaaaaa", &mut out);
        assert_eq!(out, vec![LineEvent::Oversize]);
        out.clear();
        r.ingest(b"aaaa", &mut out);
        assert!(out.is_empty(), "still discarding, no duplicate report");
        // The newline ends the poisoned line; the next one parses normally.
        r.ingest(b"aaa\n{\"x\":1}\n", &mut out);
        assert_eq!(out, vec![LineEvent::Line("{\"x\":1}".into())]);
    }

    #[test]
    fn oversized_line_completed_in_one_chunk_is_rejected() {
        let mut r = LineReader::new(4);
        let mut out = Vec::new();
        r.ingest(b"toolongline\nok\n", &mut out);
        assert_eq!(out, vec![LineEvent::Oversize, LineEvent::Line("ok".into())]);
    }

    fn q(max_bytes: usize, on_full: OverflowPolicy) -> Arc<ConnQueue> {
        ConnQueue::new(7, BufferPolicy { max_bytes, on_full })
    }

    #[test]
    fn push_and_write_preserve_frame_order() {
        let q = q(1024, OverflowPolicy::Disconnect);
        assert_eq!(q.push("one", true), PushOutcome::Queued);
        assert_eq!(q.push("two", false), PushOutcome::Queued);
        let mut sink = Vec::new();
        let st = q.write_to(&mut sink).unwrap();
        assert_eq!(st.remaining, 0);
        assert!(!st.killed);
        assert_eq!(String::from_utf8(sink).unwrap(), "one\ntwo\n");
    }

    /// Writer that accepts `cap` bytes total, then `WouldBlock`s.
    struct Throttled {
        cap: usize,
        data: Vec<u8>,
    }

    impl Write for Throttled {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.data.len() >= self.cap {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.cap - self.data.len());
            self.data.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn drop_oldest_never_drops_partially_written_head_or_terminals() {
        // Cap fits ~2 frames of "xxxxxxxx\n" (9 bytes each).
        let q = q(20, OverflowPolicy::DropOldest);
        assert_eq!(q.push("aaaaaaaa", true), PushOutcome::Queued);
        assert_eq!(q.push("bbbbbbbb", true), PushOutcome::Queued);
        // Partially flush the head frame (3 bytes of "aaaaaaaa\n").
        let mut w = Throttled { cap: 3, data: Vec::new() };
        let st = q.write_to(&mut w).unwrap();
        assert!(st.remaining > 0);
        // A third frame must evict "bbbbbbbb" (the head is pinned).
        assert_eq!(q.push("cccccccc", true), PushOutcome::Dropped(1));
        assert_eq!(q.dropped_total(), 1);
        let mut sink = Vec::new();
        let st = q.write_to(&mut sink).unwrap();
        assert_eq!(st.remaining, 0);
        assert_eq!(String::from_utf8(sink).unwrap(), "aaaaa\ncccccccc\n".to_string());
    }

    #[test]
    fn drop_oldest_spares_non_droppable_frames() {
        let q = q(20, OverflowPolicy::DropOldest);
        assert_eq!(q.push("terminal", false), PushOutcome::Queued);
        assert_eq!(q.push("droppable1", true), PushOutcome::Queued);
        // Over cap: only the droppable frame can go.
        assert_eq!(q.push("droppable2", true), PushOutcome::Dropped(1));
        let mut sink = Vec::new();
        q.write_to(&mut sink).unwrap();
        let text = String::from_utf8(sink).unwrap();
        assert!(text.contains("terminal\n"), "{text}");
        assert!(!text.contains("droppable1"), "{text}");
        assert!(text.contains("droppable2\n"), "{text}");
        // A frame that cannot fit even after evicting everything droppable
        // is itself discarded rather than growing the queue.
        let q2 = q_all_pinned();
        assert_eq!(q2.push(&"y".repeat(30), true), PushOutcome::Dropped(1));
    }

    fn q_all_pinned() -> Arc<ConnQueue> {
        let q = q(20, OverflowPolicy::DropOldest);
        assert_eq!(q.push("pinned-frame-here", false), PushOutcome::Queued);
        q
    }

    #[test]
    fn non_droppable_frames_always_queue() {
        let q = q(10, OverflowPolicy::Disconnect);
        assert_eq!(q.push(&"t".repeat(40), false), PushOutcome::Queued);
        assert!(!q.killed(), "terminal frames never trip the policy");
    }

    #[test]
    fn disconnect_policy_clamps_to_typed_goodbye() {
        let q = q(16, OverflowPolicy::Disconnect);
        assert_eq!(q.push("first-frame", true), PushOutcome::Queued);
        assert_eq!(q.push("second-frame-over", true), PushOutcome::Killed);
        assert!(q.killed());
        // Pushes after the kill are swallowed, not queued.
        assert_eq!(q.push("late", true), PushOutcome::Queued);
        let mut sink = Vec::new();
        let st = q.write_to(&mut sink).unwrap();
        assert!(st.killed);
        assert_eq!(st.remaining, 0);
        let text = String::from_utf8(sink).unwrap();
        let j = Json::parse(text.trim()).unwrap();
        assert_eq!(j.str_or("event", ""), "disconnected");
        assert!(j.str_or("error", "").contains("buffer overflow"), "{text}");
        assert!(!text.contains("first-frame"), "queue was clamped: {text}");
    }

    #[test]
    fn notifier_dedups_marks_until_taken() {
        let n = Notifier::new(None);
        let q = q(64, OverflowPolicy::Disconnect);
        n.mark(&q);
        n.mark(&q);
        assert_eq!(n.take_dirty(), vec![7], "second mark coalesced");
        // Until the reactor clears the flag, further marks stay coalesced.
        n.mark(&q);
        assert!(n.take_dirty().is_empty());
        q.clear_dirty();
        n.mark(&q);
        assert_eq!(n.take_dirty(), vec![7]);
    }
}

//! Minimal host-side shaped tensors.
//!
//! The coordinator moves flat buffers in and out of PJRT; this module gives
//! them just enough structure (shape + row-major indexing + file I/O) without
//! pulling in an ndarray dependency.  Only f32 and i32 exist in the system.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

macro_rules! tensor_common {
    ($t:ident, $elem:ty) => {
        impl $t {
            pub fn zeros(shape: &[usize]) -> Self {
                Self { shape: shape.to_vec(), data: vec![<$elem>::default(); numel(shape)] }
            }

            pub fn from_vec(shape: &[usize], data: Vec<$elem>) -> Result<Self> {
                if numel(shape) != data.len() {
                    bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
                }
                Ok(Self { shape: shape.to_vec(), data })
            }

            pub fn numel(&self) -> usize {
                self.data.len()
            }

            pub fn rank(&self) -> usize {
                self.shape.len()
            }

            /// Row-major strides.
            pub fn strides(&self) -> Vec<usize> {
                let mut s = vec![1; self.shape.len()];
                for i in (0..self.shape.len().saturating_sub(1)).rev() {
                    s[i] = s[i + 1] * self.shape[i + 1];
                }
                s
            }

            /// Flat offset of a multi-index.
            pub fn offset(&self, idx: &[usize]) -> usize {
                debug_assert_eq!(idx.len(), self.shape.len());
                let st = self.strides();
                idx.iter().zip(&st).map(|(i, s)| i * s).sum()
            }

            pub fn at(&self, idx: &[usize]) -> $elem {
                self.data[self.offset(idx)]
            }

            pub fn set(&mut self, idx: &[usize], v: $elem) {
                let o = self.offset(idx);
                self.data[o] = v;
            }

            /// Reinterpret with a new shape of identical element count.
            pub fn reshaped(mut self, shape: &[usize]) -> Result<Self> {
                if numel(shape) != self.data.len() {
                    bail!("reshape {:?} -> {:?} changes element count", self.shape, shape);
                }
                self.shape = shape.to_vec();
                Ok(self)
            }
        }
    };
}

tensor_common!(TensorF, f32);
tensor_common!(TensorI, i32);

impl TensorF {
    /// Read a raw little-endian f32 file (checkpoints, init params).
    pub fn read_f32_file(path: &std::path::Path, shape: &[usize]) -> Result<TensorF> {
        let bytes = std::fs::read(path)?;
        if bytes.len() != numel(shape) * 4 {
            bail!(
                "{}: expected {} f32 ({} bytes), file has {} bytes",
                path.display(),
                numel(shape),
                numel(shape) * 4,
                bytes.len()
            );
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(TensorF { shape: shape.to_vec(), data })
    }

    /// Write raw little-endian f32 bytes.
    pub fn write_f32_file(&self, path: &std::path::Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.data.len() * 4);
        for x in &self.data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    /// Sum of squared differences against another tensor (quantization error
    /// metric used throughout the paper: ||A - cq(A)||_F^2).
    pub fn sqdiff(&self, other: &TensorF) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_indexing() {
        let mut t = TensorF::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.at(&[1, 2, 3]), 7.0);
        assert_eq!(t.data[23], 7.0);
    }

    #[test]
    fn from_vec_validates() {
        assert!(TensorF::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(TensorI::from_vec(&[2, 2], vec![0; 4]).is_ok());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = TensorF::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let r = t.clone().reshaped(&[3, 2]).unwrap();
        assert_eq!(r.data, t.data);
        assert!(t.clone().reshaped(&[4, 2]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cq_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let t = TensorF::from_vec(&[5], vec![1.0, -2.5, 3.25, 0.0, 9.75]).unwrap();
        t.write_f32_file(&p).unwrap();
        let r = TensorF::read_f32_file(&p, &[5]).unwrap();
        assert_eq!(t, r);
        assert!(TensorF::read_f32_file(&p, &[6]).is_err());
    }

    #[test]
    fn sqdiff_matches_manual() {
        let a = TensorF::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = TensorF::from_vec(&[3], vec![1.0, 0.0, 6.0]).unwrap();
        assert!((a.sqdiff(&b) - (4.0 + 9.0)).abs() < 1e-12);
    }
}

//! Debug probe: run a single-input f32[4,8] -> 1-tuple HLO text file from
//! /tmp/probe_<name>.hlo.txt and compare against /tmp/probe_<name>.ref.bin.
//! Used to bisect xla_extension numerical issues (see EXPERIMENTS.md notes).
fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap();
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(format!("/tmp/probe_{name}.hlo.txt"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x: Vec<f32> = (0..32).map(|i| i as f32).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[4, 8])?;
    let out = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    let got = out.to_tuple1()?.to_vec::<f32>()?;
    let refb = std::fs::read(format!("/tmp/probe_{name}.ref.bin"))?;
    let want: Vec<f32> = refb.chunks_exact(4).map(|c| f32::from_le_bytes([c[0],c[1],c[2],c[3]])).collect();
    let maxd = got.iter().zip(&want).map(|(a,b)| (a-b).abs()).fold(0.0f32, f32::max);
    println!("{name}: got[0..4]={:?} want[0..4]={:?} maxdiff={maxd}", &got[..4], &want[..4]);
    Ok(())
}

//! Shared scaffolding for `benches/` and `examples/`: guarantees a trained
//! checkpoint + calibration data exist (training on demand if needed) and
//! caches learned CQ codebooks on disk so repeated bench runs are cheap.

use std::path::PathBuf;

use anyhow::Result;

use crate::calib::{calibrate, CalibData};
use crate::data::corpus::{CorpusKind, CorpusSpec, Split};
use crate::data::{eval_batches, Dataset};
use crate::quant::cq::{CqCodebooks, CqCodec, CqSpec, LearnCfg};
use crate::quant::factory::{build_codec, needs_calibration, FactoryCfg};
use crate::quant::Codec;
use crate::runtime::Engine;
use crate::tensor::{TensorF, TensorI};
use crate::train::{ckpt_dir, load_checkpoint, save_checkpoint, train, TrainCfg};
use crate::eval::{perplexity, PplMode};
use crate::quant::factory::table_rows;
use crate::util::bench::Table;
use crate::util::cli::Args;

/// A ready-to-measure pipeline for one model.
pub struct Pipeline {
    pub engine: Engine,
    pub model: String,
    pub params: TensorF,
    pub calib: CalibData,
    pub dir: PathBuf,
}

impl Pipeline {
    /// Load (or create) the trained + calibrated state for `model`.
    /// Training steps are only spent when no checkpoint exists.
    pub fn ensure(model: &str) -> Result<Pipeline> {
        let engine = Engine::load_default()?;
        let dir = ckpt_dir(model);
        let params = match load_checkpoint(&engine, model, &dir) {
            Ok(p) => p,
            Err(_) => {
                eprintln!("[bench_support] no checkpoint for '{model}', training…");
                let ds = Dataset::from_corpus(
                    CorpusSpec::new(CorpusKind::Wiki2s, Split::Train),
                    2_000_000,
                );
                let steps = if model == "tiny" { 250 } else { 350 };
                let r = train(&engine, model, engine.init_params(model)?, &ds,
                              &TrainCfg { steps, ..Default::default() })?;
                save_checkpoint(&dir, model, &r.params, &r.losses)?;
                r.params
            }
        };
        let calib = match CalibData::load(&dir) {
            Ok(c) => c,
            Err(_) => {
                eprintln!("[bench_support] no calibration for '{model}', capturing…");
                let ds = Dataset::from_corpus(
                    CorpusSpec::new(CorpusKind::Wiki2s, Split::Train),
                    2_000_000,
                );
                let c = calibrate(&engine, model, &params, &ds, 16)?;
                c.save(&dir)?;
                c
            }
        };
        Ok(Pipeline { engine, model: model.to_string(), params, calib, dir })
    }

    /// Deterministic eval batches of the given corpus test split.
    pub fn eval_set(&self, kind: CorpusKind, n_batches: usize) -> Vec<TensorI> {
        let mm = self.engine.manifest.model(&self.model).unwrap();
        let ds = Dataset::from_corpus(
            CorpusSpec::new(kind, Split::Test),
            n_batches * 4 * mm.eval_ctx + 4096,
        );
        eval_batches(&ds, 4, mm.eval_ctx, n_batches)
    }

    /// Build a codec by table-row name; CQ codebooks are cached on disk
    /// (keyed by spec + fisher flag) since centroid learning dominates.
    pub fn codec(&self, name: &str, fisher: bool, iters: usize) -> Result<Box<dyn Codec>> {
        let lname = name.to_lowercase();
        if let Some(rest) = lname.strip_prefix("cq-") {
            let spec = crate::quant::factory::parse_cq(rest)?;
            return Ok(Box::new(self.cq_codec(spec, fisher, iters)?));
        }
        let calib = needs_calibration(&lname).then_some(&self.calib);
        build_codec(&lname, calib, FactoryCfg { fisher, max_iters: iters, seed: 0 })
    }

    /// CQ codec with disk-cached codebooks.
    pub fn cq_codec(&self, spec: CqSpec, fisher: bool, iters: usize) -> Result<CqCodec> {
        let suffix = if fisher { "" } else { "_uniform" };
        let path = self.dir.join(format!("cq_{}{}.cqb", spec.tag(), suffix));
        if let Ok(books) = CqCodebooks::load(&path) {
            if books.spec == spec {
                let codec = if fisher {
                    CqCodec::new(books)
                } else {
                    CqCodec::with_label(books, &format!("CQ-{}-uniform", spec.tag()))
                };
                return Ok(codec);
            }
        }
        let books = CqCodebooks::learn(
            spec,
            &self.calib.k,
            &self.calib.v,
            fisher.then_some(&self.calib.gk),
            fisher.then_some(&self.calib.gv),
            LearnCfg { fisher, max_iters: iters, seed: 0 },
        );
        books.save(&path)?;
        let codec = if fisher {
            CqCodec::new(books)
        } else {
            CqCodec::with_label(books, &format!("CQ-{}-uniform", spec.tag()))
        };
        Ok(codec)
    }
}

/// Shared driver for the Table-1/2 perplexity benches.
pub fn run_ppl_table(kind: CorpusKind, slug: &str, title: &str) {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let n_batches = args.usize("batches", 4);
    let iters = args.usize("iters", 40);
    let mode = if args.flag("exact") { PplMode::Exact } else { PplMode::Fast };

    let pipe = Pipeline::ensure("small").expect("pipeline");
    let batches = pipe.eval_set(kind, n_batches);
    let mut table = Table::new(title, &["codec", "bits/FPN", "ppl", "k_err", "v_err"]);
    for name in table_rows() {
        let t0 = std::time::Instant::now();
        let codec = pipe.codec(name, true, iters).expect("codec");
        let r = perplexity(&pipe.engine, &pipe.model, &pipe.params, codec.as_ref(), &batches, mode)
            .expect("ppl");
        eprintln!(
            "  {:<16} ppl {:>10.3}   ({:.1}s)",
            codec.name(),
            r.ppl(),
            t0.elapsed().as_secs_f64()
        );
        table.row(vec![
            codec.name(),
            format!("{:.2}", codec.bits_per_fpn()),
            format!("{:.3}", r.ppl()),
            format!("{:.1}", r.k_err),
            format!("{:.1}", r.v_err),
        ]);
    }
    println!(
        "(model=small, corpus={}, {} eval tokens, mode={mode:?})",
        kind.name(),
        n_batches * 4 * 255
    );
    table.emit(slug);
}

//! Data substrate: synthetic corpora, byte-level tokenizer, batch assembly.
//!
//! The paper evaluates on WikiText-2 and C4 with public LLaMA checkpoints;
//! this image is offline, so we train our own models on deterministic
//! synthetic corpora whose *structure* supports the same experiments
//! (DESIGN.md §2): an encyclopedic register (`wiki2s`) and a web register
//! (`c4s`), with embedded regularities (subject–verb agreement, adjective–
//! noun collocations, spelled-out arithmetic) that the zero-shot suites in
//! `eval::tasks` probe.

pub mod corpus;
pub mod dataset;
pub mod tokenizer;

pub use corpus::{CorpusKind, CorpusSpec, Split};
pub use dataset::{eval_batches, train_batch, Dataset};
pub use tokenizer::ByteTokenizer;

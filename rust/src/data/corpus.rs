//! Deterministic synthetic corpora.
//!
//! `wiki2s` ("WikiText-2-style"): encyclopedic prose with section headings,
//! years, and consistent grammar.  `c4s` ("C4-style"): web text with URLs,
//! list bullets and boilerplate, over a shifted vocabulary mixture.  Both
//! are generated from a seeded PCG so every experiment is reproducible;
//! train/test splits use disjoint RNG streams.
//!
//! The grammar embeds three regularities the zero-shot suites probe:
//!   1. subject–verb agreement   (singular -> "is"/"was", plural -> "are"/"were")
//!   2. adjective–noun collocations (each adjective has a licensed noun set)
//!   3. spelled-out arithmetic   ("three plus four equals seven")
//! A byte-level LM trained on the corpus learns all three, so quantization
//! damage shows up as task-accuracy loss exactly as in the paper's Table 3.

use crate::util::rng::Pcg64;

/// Which corpus to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    Wiki2s,
    C4s,
}

impl CorpusKind {
    pub fn parse(s: &str) -> Option<CorpusKind> {
        match s {
            "wiki2s" => Some(CorpusKind::Wiki2s),
            "c4s" => Some(CorpusKind::C4s),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::Wiki2s => "wiki2s",
            CorpusKind::C4s => "c4s",
        }
    }
}

/// Train/test split (disjoint RNG streams).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Full corpus specification.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub kind: CorpusKind,
    pub split: Split,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn new(kind: CorpusKind, split: Split) -> CorpusSpec {
        CorpusSpec { kind, split, seed: 0x5eed }
    }

    fn stream(&self) -> u64 {
        let k = match self.kind {
            CorpusKind::Wiki2s => 1,
            CorpusKind::C4s => 2,
        };
        let s = match self.split {
            Split::Train => 10,
            Split::Test => 20,
        };
        k * 1000 + s
    }

    /// Generate at least `n_bytes` of corpus text.
    pub fn generate(&self, n_bytes: usize) -> String {
        let mut rng = Pcg64::new(self.seed, self.stream());
        let mut out = String::with_capacity(n_bytes + 256);
        while out.len() < n_bytes {
            match self.kind {
                CorpusKind::Wiki2s => wiki_document(&mut rng, &mut out),
                CorpusKind::C4s => web_document(&mut rng, &mut out),
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Vocabulary: nouns carry number; adjectives license noun subsets.
// ---------------------------------------------------------------------------

pub const SING_NOUNS: &[&str] = &[
    "castle", "river", "engine", "garden", "bridge", "museum", "library",
    "harbor", "village", "mountain", "temple", "forest", "canal", "tower",
];
pub const PLUR_NOUNS: &[&str] = &[
    "castles", "rivers", "engines", "gardens", "bridges", "museums",
    "libraries", "harbors", "villages", "mountains", "temples", "forests",
];
/// Adjective -> licensed nouns (collocation regularity for the PIQA-like
/// suite).  Each adjective appears ONLY with its licensed nouns in corpus.
pub const COLLOCATIONS: &[(&str, &[&str])] = &[
    ("ancient", &["castle", "temple", "bridge", "tower"]),
    ("flowing", &["river", "canal"]),
    ("mechanical", &["engine", "tower"]),
    ("blooming", &["garden", "forest"]),
    ("crowded", &["museum", "library", "harbor", "village"]),
    ("misty", &["mountain", "forest", "river"]),
];
pub const PLACES: &[&str] = &[
    "Aldenport", "Brimholt", "Carvel", "Dunmere", "Eastvale", "Fenwick",
    "Grendale", "Halloway",
];
pub const VERBS_SING: &[&str] = &["is", "was", "stands", "remains"];
pub const VERBS_PLUR: &[&str] = &["are", "were", "stand", "remain"];
pub const DIGITS: &[&str] = &[
    "zero", "one", "two", "three", "four", "five", "six", "seven", "eight",
    "nine",
];
const TAILS: &[&str] = &[
    "near the old town", "in the northern district", "by the coast",
    "under royal charter", "according to early records", "for many years",
];

/// Spell a number 0..=18 (sum of two digits).
pub fn spell_number(n: usize) -> String {
    const TEENS: &[&str] = &[
        "ten", "eleven", "twelve", "thirteen", "fourteen", "fifteen",
        "sixteen", "seventeen", "eighteen",
    ];
    if n < 10 {
        DIGITS[n].to_string()
    } else {
        TEENS[n - 10].to_string()
    }
}

fn agreement_sentence(rng: &mut Pcg64) -> String {
    let singular = rng.next_f64() < 0.5;
    let (noun, verb): (&str, &str) = if singular {
        (*rng.choose(SING_NOUNS), *rng.choose(VERBS_SING))
    } else {
        (*rng.choose(PLUR_NOUNS), *rng.choose(VERBS_PLUR))
    };
    format!(
        "The {} of {} {} notable {}.",
        noun,
        rng.choose(PLACES),
        verb,
        rng.choose(TAILS)
    )
}

fn collocation_sentence(rng: &mut Pcg64) -> String {
    let (adj, nouns) = rng.choose(COLLOCATIONS);
    let noun = *rng.choose(nouns);
    format!(
        "Travellers often mention the {} {} {}.",
        adj,
        noun,
        rng.choose(TAILS)
    )
}

fn arithmetic_sentence(rng: &mut Pcg64) -> String {
    let a = rng.below(10);
    let b = rng.below(10);
    format!(
        "In the ledger, {} plus {} equals {}.",
        DIGITS[a],
        DIGITS[b],
        spell_number(a + b)
    )
}

fn year_sentence(rng: &mut Pcg64) -> String {
    let year = 1400 + rng.below(500);
    format!(
        "It was rebuilt in {} after the great storm.",
        year
    )
}

fn wiki_sentence(rng: &mut Pcg64) -> String {
    let x = rng.next_f64();
    if x < 0.40 {
        agreement_sentence(rng)
    } else if x < 0.65 {
        collocation_sentence(rng)
    } else if x < 0.85 {
        arithmetic_sentence(rng)
    } else {
        year_sentence(rng)
    }
}

fn wiki_document(rng: &mut Pcg64, out: &mut String) {
    out.push_str(&format!(
        "\n= {} {} =\n\n",
        rng.choose(PLACES),
        rng.choose(&["History", "Geography", "Architecture", "Economy"])
    ));
    let sentences = 6 + rng.below(10);
    for i in 0..sentences {
        out.push_str(&wiki_sentence(rng));
        out.push(if i % 4 == 3 { '\n' } else { ' ' });
    }
    out.push('\n');
}

fn web_document(rng: &mut Pcg64, out: &mut String) {
    // Web register: boilerplate + URLs + lists around the same grammar, so
    // it is a distribution shift, not a disjoint language (paper: calibrate
    // on WikiText-2, evaluate on C4).
    out.push_str(&format!(
        "\nwww.{}.example/{}\n",
        rng.choose(PLACES).to_lowercase(),
        rng.below(1000)
    ));
    if rng.next_f64() < 0.5 {
        out.push_str("Sign up for our newsletter today. ");
    }
    let items = 2 + rng.below(4);
    for _ in 0..items {
        out.push_str("- ");
        out.push_str(&wiki_sentence(rng));
        out.push('\n');
    }
    if rng.next_f64() < 0.4 {
        out.push_str(&format!(
            "Read more about {} here. Contact us for details.\n",
            rng.choose(SING_NOUNS)
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let s = CorpusSpec::new(CorpusKind::Wiki2s, Split::Train);
        assert_eq!(s.generate(2000), s.generate(2000));
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let tr = CorpusSpec::new(CorpusKind::Wiki2s, Split::Train).generate(4000);
        let te = CorpusSpec::new(CorpusKind::Wiki2s, Split::Test).generate(4000);
        assert_ne!(tr, te);
        // No long shared substring at the same offset (streams independent).
        assert_ne!(&tr[..200], &te[..200]);
    }

    #[test]
    fn corpora_differ_by_register() {
        let w = CorpusSpec::new(CorpusKind::Wiki2s, Split::Test).generate(4000);
        let c = CorpusSpec::new(CorpusKind::C4s, Split::Test).generate(4000);
        assert!(w.contains("= "), "wiki has headings");
        assert!(c.contains("www."), "web has urls");
        assert!(!w.contains("www."));
    }

    #[test]
    fn agreement_regularity_holds() {
        // In the generated text, "castles ... is" must never occur —
        // the grammar enforces number agreement.
        let text = CorpusSpec::new(CorpusKind::Wiki2s, Split::Train).generate(200_000);
        for plural in PLUR_NOUNS {
            assert!(
                !text.contains(&format!("The {plural} of Aldenport is")),
                "agreement violated for {plural}"
            );
        }
        assert!(text.contains(" is ") && text.contains(" are "));
    }

    #[test]
    fn collocations_are_exclusive() {
        let text = CorpusSpec::new(CorpusKind::Wiki2s, Split::Train).generate(200_000);
        // "flowing" licenses only river/canal; "flowing castle" must not occur.
        assert!(!text.contains("flowing castle"));
        assert!(!text.contains("ancient river"));
        assert!(text.contains("flowing river") || text.contains("flowing canal"));
    }

    #[test]
    fn arithmetic_is_correct_in_corpus() {
        let text = CorpusSpec::new(CorpusKind::Wiki2s, Split::Train).generate(100_000);
        assert!(text.contains("plus"));
        // Spot-check: "two plus two equals four" style lines are consistent.
        assert!(!text.contains("two plus two equals five"));
    }

    #[test]
    fn spell_number_covers_range() {
        assert_eq!(spell_number(0), "zero");
        assert_eq!(spell_number(9), "nine");
        assert_eq!(spell_number(10), "ten");
        assert_eq!(spell_number(18), "eighteen");
    }

    #[test]
    fn generates_requested_length() {
        let s = CorpusSpec::new(CorpusKind::C4s, Split::Train).generate(50_000);
        assert!(s.len() >= 50_000);
        assert!(s.is_ascii(), "byte tokenizer expects ascii corpus");
    }
}

//! Batch assembly for training, calibration and evaluation.

use crate::tensor::TensorI;
use crate::util::rng::Pcg64;

use super::corpus::CorpusSpec;
use super::tokenizer::{ByteTokenizer, Tokenizer};

/// A tokenized corpus with batch samplers.
pub struct Dataset {
    pub tokens: Vec<i32>,
    pub name: String,
}

impl Dataset {
    /// Generate and tokenize `n_bytes` of a corpus.
    pub fn from_corpus(spec: CorpusSpec, n_bytes: usize) -> Dataset {
        let text = spec.generate(n_bytes);
        Dataset {
            tokens: ByteTokenizer.encode(&text),
            name: format!("{}-{:?}", spec.kind.name(), spec.split),
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Sample a `[batch, ctx]` training batch at random offsets.
pub fn train_batch(ds: &Dataset, batch: usize, ctx: usize, rng: &mut Pcg64) -> TensorI {
    assert!(ds.len() > ctx + 1, "corpus too small for ctx {ctx}");
    let mut data = Vec::with_capacity(batch * ctx);
    for _ in 0..batch {
        let start = rng.below(ds.len() - ctx - 1);
        data.extend_from_slice(&ds.tokens[start..start + ctx]);
    }
    TensorI::from_vec(&[batch, ctx], data).unwrap()
}

/// Deterministic, non-overlapping eval batches covering a prefix of the
/// corpus: `n_batches` of shape `[batch, ctx]`.
pub fn eval_batches(ds: &Dataset, batch: usize, ctx: usize, n_batches: usize) -> Vec<TensorI> {
    let needed = n_batches * batch * ctx;
    assert!(
        ds.len() >= needed,
        "corpus has {} tokens, eval needs {needed}",
        ds.len()
    );
    let mut out = Vec::with_capacity(n_batches);
    let mut off = 0;
    for _ in 0..n_batches {
        let mut data = Vec::with_capacity(batch * ctx);
        for _ in 0..batch {
            data.extend_from_slice(&ds.tokens[off..off + ctx]);
            off += ctx;
        }
        out.push(TensorI::from_vec(&[batch, ctx], data).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusKind, Split};

    fn ds() -> Dataset {
        Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Train), 20_000)
    }

    #[test]
    fn train_batch_shape_and_range() {
        let d = ds();
        let mut rng = Pcg64::seed(0);
        let b = train_batch(&d, 4, 65, &mut rng);
        assert_eq!(b.shape, vec![4, 65]);
        assert!(b.data.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn eval_batches_non_overlapping_and_deterministic() {
        let d = ds();
        let a = eval_batches(&d, 2, 64, 3);
        let b = eval_batches(&d, 2, 64, 3);
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].data, b[0].data);
        // Consecutive batches tile the corpus without overlap.
        assert_eq!(a[0].data[64..128], d.tokens[64..128]);
        assert_eq!(a[1].data[..64], d.tokens[128..192]);
    }

    #[test]
    #[should_panic(expected = "eval needs")]
    fn eval_batches_guard_corpus_size() {
        let d = Dataset {
            tokens: vec![0; 100],
            name: "t".into(),
        };
        eval_batches(&d, 4, 64, 2);
    }
}

//! Byte-level tokenizer.
//!
//! The models are byte-level (vocab 256) like the smallest LLaMA-family
//! ablations; a tokenizer trait keeps the serving stack tokenizer-agnostic
//! should a subword scheme be added later.

/// Tokenizer interface used by the coordinator and evaluation harness.
pub trait Tokenizer: Send + Sync {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    fn decode(&self, tokens: &[i32]) -> String;
}

/// Identity byte tokenizer: token id == byte value.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .map(|&t| t.clamp(0, 255) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "The castle of Aldenport is notable.";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.vocab_size(), 256);
    }

    #[test]
    fn encode_is_byte_identity() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("Az"), vec![65, 122]);
    }

    #[test]
    fn decode_clamps_out_of_range() {
        let t = ByteTokenizer;
        let s = t.decode(&[72, 105, 999, -5]);
        assert!(s.starts_with("Hi"));
    }
}

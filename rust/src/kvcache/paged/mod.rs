//! Paged block-pool KV cache with radix-tree prefix sharing.
//!
//! * [`block`] — fixed-size packed-code blocks (`block_tokens` ×
//!   `bytes_per_token`), the allocation/refcount unit.
//! * [`pool`]  — slab [`BlockPool`]: free-list allocation, hard block cap.
//! * [`radix`] — [`RadixIndex`]: token-id prefixes → frozen block chains,
//!   block-aligned splits, LRU eviction of cold prefixes.
//!
//! [`PagedSeqCache`] replaces the old flat per-sequence `Vec<u8>`: a chain
//! of **shared** prefix blocks (attached from the radix index, read-only)
//! plus **private** tail blocks the sequence appends into.  On divergence
//! nothing is copied eagerly — the divergent span is simply quantized into
//! private blocks (copy-on-write at block granularity).
//!
//! [`PagedShard`] is one serve-loop worker's cache: pool + index +
//! [`CacheManager`] block accounting, with the admission / completion /
//! eviction protocol the serve loop drives:
//!
//! ```text
//! admit:  radix match → retain hit blocks → reserve (evict LRU on miss)
//! serve:  quantize+store ONLY tokens [hit..); decode appends go to
//!         private blocks
//! finish: promote full blocks into the radix (skip spans already cached),
//!         release the sequence's references + reservation
//! ```
//!
//! **Full-precision retention (DESIGN.md §5).**  A sequence built with
//! [`PagedSeqCache::with_retention`] holds its first `sinks` tokens and
//! trailing `window` tokens in an unpacked **pen** — accounted at the
//! policy's fp16 byte rate — and packs a token into pool blocks only when
//! it ages past the window (*quantize-on-retire*).  The retire path is the
//! exact pack path a plain sequence uses, so retired records are
//! byte-identical to direct quantization; sink tokens never retire.
//! Retention sequences opt out of radix prefix sharing (their pool chain
//! starts after the sink pen, so block chains are not prefix-aligned).

pub mod block;
pub mod pool;
pub mod radix;

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::metrics::ServeMetrics;
use crate::quant::pack::{pack_into, unpack_codes_ref, unpack_into};
use crate::quant::policy::Retention;
use crate::tensor::TensorF;

use super::{CacheGeom, CacheManager};
pub use block::{BlockConfig, BlockId};
pub use pool::BlockPool;
pub use radix::RadixIndex;

/// Default paging granularity (tokens per block).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Per-sequence view over pool blocks: shared radix prefix + private tail.
pub struct PagedSeqCache {
    pub geom: CacheGeom,
    /// Total cached tokens (shared + private, or logical count when
    /// unstored).
    pub len: usize,
    /// Frozen prefix blocks borrowed from the radix index (one pool
    /// reference each, taken at admission).
    shared: Vec<BlockId>,
    shared_tokens: usize,
    /// Blocks this sequence appends into; only the last may be partial.
    private: Vec<BlockId>,
    scratch: Vec<u32>,
    /// Reusable packed-record buffer: appends pack into this instead of
    /// allocating a fresh record per token.
    rec_scratch: Vec<u8>,
    /// `false` for fp-cache sequences: length/block accounting only, the
    /// actual floats live in the serve loop's staging tensors.
    stored: bool,
    /// fp-mode only: prefill K/V (`[L,1,H,T,hd]`) held until the sequence is
    /// admitted into a staging lane, then dropped.
    pub fp_seed: Option<(TensorF, TensorF)>,
    /// Sliding-window policy, if any (see module doc: quantize-on-retire).
    retention: Option<Retention>,
    /// Attention-sink pen: the first `sinks` tokens, held unpacked forever.
    sink_pen: Vec<(Vec<u32>, Vec<u32>)>,
    /// Window pen: the trailing `window` tokens, held unpacked; the front
    /// retires into pool blocks as new tokens push past the window.
    tail_pen: VecDeque<(Vec<u32>, Vec<u32>)>,
    /// Tokens that have aged past the window and been packed into blocks.
    pub retired_tokens: u64,
    /// Byte rate charged for pen-resident (or unstored) tokens; 0 falls
    /// back to the quantized `geom.bytes_per_token()` rate.
    fp_bytes_per_token: usize,
}

impl PagedSeqCache {
    pub fn new(geom: CacheGeom) -> PagedSeqCache {
        PagedSeqCache {
            geom,
            len: 0,
            shared: Vec::new(),
            shared_tokens: 0,
            private: Vec::new(),
            scratch: Vec::new(),
            rec_scratch: Vec::new(),
            stored: true,
            fp_seed: None,
            retention: None,
            sink_pen: Vec::new(),
            tail_pen: VecDeque::new(),
            retired_tokens: 0,
            fp_bytes_per_token: 0,
        }
    }

    /// Accounting-only cache (fp16 serving baseline): tracks length and
    /// logical blocks without storing codes.
    pub fn new_unstored(geom: CacheGeom) -> PagedSeqCache {
        PagedSeqCache { stored: false, ..PagedSeqCache::new(geom) }
    }

    /// Stored sequence under a retention policy: the first `r.sinks` and
    /// trailing `r.window` tokens stay in unpacked pens charged at
    /// `fp_bytes_per_token`; everything else quantizes-on-retire into pool
    /// blocks through the exact pack path [`Self::append`] uses.
    pub fn with_retention(
        geom: CacheGeom,
        r: Retention,
        fp_bytes_per_token: usize,
    ) -> PagedSeqCache {
        PagedSeqCache { retention: Some(r), fp_bytes_per_token, ..PagedSeqCache::new(geom) }
    }

    /// Override the byte rate charged for unstored tokens (an fp16 tenant
    /// pays fp16 bytes, not the pool's quantized rate).
    pub fn set_fp_cost(&mut self, bytes_per_token: usize) {
        self.fp_bytes_per_token = bytes_per_token;
    }

    /// The retention policy this sequence was admitted under.
    pub fn retention(&self) -> Option<Retention> {
        self.retention
    }

    /// Whether codes are pool-backed (`false` for unstored fp16 accounting).
    pub fn is_stored(&self) -> bool {
        self.stored
    }

    /// Attach an already-retained shared prefix (radix hit).  Must happen
    /// before any append.
    pub fn attach_prefix(&mut self, blocks: Vec<BlockId>, tokens: usize) {
        assert_eq!(self.len, 0, "prefix attaches to an empty sequence");
        assert!(self.stored, "fp sequences share nothing");
        assert!(self.retention.is_none(), "retention sequences do not share prefixes");
        self.shared = blocks;
        self.shared_tokens = tokens;
        self.len = tokens;
    }

    /// Tokens covered by the shared radix prefix.
    pub fn shared_tokens(&self) -> usize {
        self.shared_tokens
    }

    /// Bump the token count without storing codes (unstored mode).
    pub fn append_unstored(&mut self) -> Result<()> {
        if self.len >= self.geom.tmax {
            bail!("cache full ({} tokens)", self.geom.tmax);
        }
        self.len += 1;
        Ok(())
    }

    /// Append one token's codes (`k`/`v` laid out `[L, H, G]`).  Without a
    /// retention policy the codes pack straight into the private tail; under
    /// one, the token lands in the sink or window pen and the *oldest*
    /// window token retires into the pool instead (same pack path, so
    /// retired records are byte-identical to direct appends).
    pub fn append(&mut self, pool: &mut BlockPool, k_codes: &[u32], v_codes: &[u32]) -> Result<()> {
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        if k_codes.len() != per_side || v_codes.len() != per_side {
            bail!(
                "append: want {per_side} codes per side, got {}/{}",
                k_codes.len(),
                v_codes.len()
            );
        }
        if self.len >= self.geom.tmax {
            bail!("cache full ({} tokens)", self.geom.tmax);
        }
        match self.retention {
            None => {
                self.pack_token(pool, k_codes, v_codes)?;
                self.len += 1;
                Ok(())
            }
            Some(r) => {
                if self.sink_pen.len() < r.sinks {
                    self.sink_pen.push((k_codes.to_vec(), v_codes.to_vec()));
                    self.len += 1;
                    return Ok(());
                }
                self.tail_pen.push_back((k_codes.to_vec(), v_codes.to_vec()));
                self.len += 1;
                while self.tail_pen.len() > r.window {
                    let (rk, rv) = self.tail_pen.pop_front().unwrap();
                    self.pack_token(pool, &rk, &rv)?;
                    self.retired_tokens += 1;
                }
                Ok(())
            }
        }
    }

    /// Pack one token's codes into the private tail, allocating a fresh
    /// block when the tail is full.  Packing reuses the sequence's scratch
    /// buffers — steady-state appends touch the allocator only when a new
    /// block is needed.  Does NOT bump `len`: this is the shared storage
    /// step under both the direct append and retire paths.
    fn pack_token(&mut self, pool: &mut BlockPool, k_codes: &[u32], v_codes: &[u32]) -> Result<()> {
        let tail_full = self
            .private
            .last()
            .map(|&b| pool.is_full(b))
            .unwrap_or(true);
        if tail_full {
            self.private.push(pool.alloc()?);
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(k_codes);
        self.scratch.extend_from_slice(v_codes);
        let bpt = self.geom.bytes_per_token();
        if self.rec_scratch.len() != bpt {
            self.rec_scratch.resize(bpt, 0);
        }
        // pack_into assigns every output byte, so the reused buffer needs no
        // re-zeroing between tokens.
        pack_into(&self.scratch, self.geom.bits, &mut self.rec_scratch);
        pool.push_token(*self.private.last().unwrap(), &self.rec_scratch)?;
        Ok(())
    }

    /// Retire every window-pen token into pool blocks (oldest first, the
    /// same order natural aging would use).  Sink tokens stay penned — once
    /// pooled tokens exist behind them, packing sinks would reorder the
    /// chain.  Returns the number of tokens retired.  Tests use this to
    /// prove retire/direct byte-identity; the serve loop never drains (a
    /// finished sequence releases its blocks without a final pack pass).
    pub fn drain_window(&mut self, pool: &mut BlockPool) -> Result<usize> {
        let mut n = 0;
        while let Some((k, v)) = self.tail_pen.pop_front() {
            self.pack_token(pool, &k, &v)?;
            self.retired_tokens += 1;
            n += 1;
        }
        Ok(n)
    }

    /// Tokens currently pen-resident at full precision (sinks + window).
    pub fn window_tokens(&self) -> usize {
        self.sink_pen.len() + self.tail_pen.len()
    }

    /// Tokens packed into pool blocks (shared + private).
    pub fn pooled_tokens(&self) -> usize {
        self.len - self.window_tokens()
    }

    /// Pen lookup for logical token `t`: `Some(codes)` when the token is
    /// fp-resident, `None` when it lives in the pool chain.
    fn pen_codes(&self, t: usize) -> Option<(&[u32], &[u32])> {
        let s = self.sink_pen.len();
        if t < s {
            let (k, v) = &self.sink_pen[t];
            return Some((k, v));
        }
        let pooled = self.len - s - self.tail_pen.len();
        if t < s + pooled {
            return None;
        }
        let (k, v) = &self.tail_pen[t - s - pooled];
        Some((k, v))
    }

    /// Bulk append: `n` tokens' codes, token-major `[n, per_side]` per side
    /// (the layout `CqCodebooks::encode_span_parallel` produces).  Same
    /// record format as [`Self::append`], one call per prefill span.
    pub fn append_span(
        &mut self,
        pool: &mut BlockPool,
        k_all: &[u32],
        v_all: &[u32],
        n: usize,
    ) -> Result<()> {
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        if k_all.len() != n * per_side || v_all.len() != n * per_side {
            bail!(
                "append_span: want {n}x{per_side} codes per side, got {}/{}",
                k_all.len(),
                v_all.len()
            );
        }
        for i in 0..n {
            self.append(
                pool,
                &k_all[i * per_side..(i + 1) * per_side],
                &v_all[i * per_side..(i + 1) * per_side],
            )?;
        }
        Ok(())
    }

    /// Block + record index holding logical token `t`.
    #[inline]
    fn locate(&self, pool: &BlockPool, t: usize) -> (BlockId, usize) {
        let bt = pool.cfg.block_tokens;
        if t < self.shared_tokens {
            (self.shared[t / bt], t % bt)
        } else {
            let u = t - self.shared_tokens;
            (self.private[u / bt], u % bt)
        }
    }

    /// Bulk readout: unpack tokens `[t0, t0+n)` into `out`, token-major
    /// `[n, 2*per_side]` (k codes then v codes per token).  The block chain
    /// is walked span-by-span: when records pack densely (codes-per-token ×
    /// bits is byte-aligned with no padding) each block's resident records
    /// decode with ONE word-level `unpack_into` call over
    /// [`BlockPool::records_bytes`]; otherwise record-at-a-time — both into
    /// caller-owned memory, so a warm reload allocates nothing.
    pub fn read_span_into(&self, pool: &BlockPool, t0: usize, n: usize, out: &mut [u32]) {
        assert!(self.stored, "unstored (fp) cache holds no codes");
        assert!(t0 + n <= self.len, "span {t0}+{n} beyond {} tokens", self.len);
        let cpt = 2 * self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        assert_eq!(out.len(), n * cpt);
        let bt = pool.cfg.block_tokens;
        let bpt = pool.cfg.bytes_per_token;
        let bits = self.geom.bits;
        if self.retention.is_some() {
            // Mixed pen/pool readout, token at a time: pen tokens copy
            // straight from the unpacked codes, pooled tokens (whose chain
            // index is offset by the sink pen) unpack per record.
            let per_side = cpt / 2;
            let s = self.sink_pen.len();
            for i in 0..n {
                let t = t0 + i;
                let dst = &mut out[i * cpt..(i + 1) * cpt];
                match self.pen_codes(t) {
                    Some((k, v)) => {
                        dst[..per_side].copy_from_slice(k);
                        dst[per_side..].copy_from_slice(v);
                    }
                    None => {
                        let u = t - s;
                        let blk = self.private[u / bt];
                        let bytes = pool.records_bytes(blk);
                        let rec = u % bt;
                        unpack_into(&bytes[rec * bpt..(rec + 1) * bpt], bits, dst);
                    }
                }
            }
            return;
        }
        let dense = (cpt * bits as usize) % 8 == 0;
        let mut done = 0usize;
        while done < n {
            let t = t0 + done;
            let (blk, rec) = self.locate(pool, t);
            // Contiguous records available in this block, clipped to the
            // shared/private boundary (shared spans are block-aligned by
            // construction; the clip keeps this correct regardless).
            let mut here = (bt - rec).min(n - done);
            if t < self.shared_tokens {
                here = here.min(self.shared_tokens - t);
            }
            let bytes = pool.records_bytes(blk);
            let span_out = &mut out[done * cpt..(done + here) * cpt];
            if dense {
                unpack_into(&bytes[rec * bpt..(rec + here) * bpt], bits, span_out);
            } else {
                for r in 0..here {
                    unpack_into(
                        &bytes[(rec + r) * bpt..(rec + r + 1) * bpt],
                        bits,
                        &mut span_out[r * cpt..(r + 1) * cpt],
                    );
                }
            }
            done += here;
        }
    }

    /// Read one token's codes back as (k `[L,H,G]`, v `[L,H,G]`).
    pub fn token(&self, pool: &BlockPool, t: usize) -> (Vec<u32>, Vec<u32>) {
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        let mut all = vec![0u32; 2 * per_side];
        self.read_span_into(pool, t, 1, &mut all);
        let v = all.split_off(per_side);
        (all, v)
    }

    /// The pre-PR readout path: per-record slice + bit-at-a-time unpack +
    /// fresh allocations.  Not on any hot path — kept as the equivalence
    /// oracle for property tests and the `quant_hot_path` bench baseline.
    pub fn token_reference(&self, pool: &BlockPool, t: usize) -> (Vec<u32>, Vec<u32>) {
        assert!(self.stored, "unstored (fp) cache holds no codes");
        assert!(self.retention.is_none(), "oracle path predates retention pens");
        assert!(t < self.len);
        let (blk, rec) = self.locate(pool, t);
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        let all = unpack_codes_ref(pool.token_bytes(blk, rec), self.geom.bits, 2 * per_side);
        (all[..per_side].to_vec(), all[per_side..].to_vec())
    }

    /// Logical footprint at the sequence's policy rates: pooled tokens at
    /// the quantized `geom.bytes_per_token()`, pen-resident (and unstored)
    /// tokens at the policy's fp rate — which defaults to the quantized
    /// rate when no explicit fp cost was set, preserving the pre-policy
    /// accounting for legacy fp-mode sequences.
    pub fn logical_bytes(&self) -> usize {
        let fp_bpt = if self.fp_bytes_per_token > 0 {
            self.fp_bytes_per_token
        } else {
            self.geom.bytes_per_token()
        };
        if !self.stored {
            return self.len * fp_bpt;
        }
        self.pooled_tokens() * self.geom.bytes_per_token() + self.window_tokens() * fp_bpt
    }

    /// Pool pages held (shared + private), in bytes.
    pub fn block_bytes_held(&self, pool: &BlockPool) -> usize {
        (self.shared.len() + self.private.len()) * pool.cfg.block_bytes()
    }

    /// The full-block-aligned prefix of this sequence: `(tokens, chain)` —
    /// what can be promoted into the radix index.
    fn full_block_chain(&self, pool: &BlockPool) -> (usize, Vec<BlockId>) {
        let mut chain = self.shared.clone();
        let mut tokens = self.shared_tokens;
        for &b in &self.private {
            if !pool.is_full(b) {
                break;
            }
            chain.push(b);
            tokens += pool.cfg.block_tokens;
        }
        (tokens, chain)
    }

    /// Drop every pool reference this sequence holds (shared + private) and
    /// empty the retention pens.
    pub fn release(&mut self, pool: &mut BlockPool) {
        for &b in self.shared.iter().chain(&self.private) {
            pool.release(b);
        }
        self.shared.clear();
        self.private.clear();
        self.shared_tokens = 0;
        self.sink_pen.clear();
        self.tail_pen.clear();
        self.len = 0;
    }
}

/// Admission result: the fresh sequence plus what was matched and reserved.
pub struct Admission {
    pub seq: PagedSeqCache,
    /// Prompt tokens covered by cached blocks (quantize+store is skipped
    /// for exactly this span).
    pub hit_tokens: usize,
    /// Blocks reserved against the shard budget; pass back to
    /// [`PagedShard::finish`] / [`PagedShard::abort`].
    pub reserved_blocks: usize,
}

/// One serve-loop worker's paged cache: pool + prefix index + accounting.
pub struct PagedShard {
    pub geom: CacheGeom,
    pub pool: BlockPool,
    pub radix: RadixIndex,
    pub mgr: CacheManager,
    pub prefix_sharing: bool,
}

impl PagedShard {
    /// `budget_blocks` caps both the accounting (`CacheManager`) and the
    /// slab itself (`BlockPool::cap_blocks`) — the pool's pages can never
    /// exceed the configured budget.
    pub fn new(
        geom: CacheGeom,
        block_tokens: usize,
        budget_blocks: Option<usize>,
        prefix_sharing: bool,
    ) -> PagedShard {
        let cfg = BlockConfig::new(block_tokens, geom.bytes_per_token());
        PagedShard {
            geom,
            pool: BlockPool::new(cfg, budget_blocks),
            radix: RadixIndex::new(block_tokens),
            mgr: match budget_blocks {
                Some(b) => CacheManager::with_budget(b),
                None => CacheManager::default(),
            },
            prefix_sharing,
        }
    }

    pub fn block_bytes(&self) -> usize {
        self.pool.cfg.block_bytes()
    }

    /// True when the shard is at its idle baseline: no active reservations
    /// and every live pool block owned by the radix cache.  Every request
    /// teardown path (finish / cancel / abort — including the paths driven
    /// by worker-crash recovery) must restore this; the chaos suite and the
    /// serve loop's shutdown assert it.
    pub fn idle(&self) -> bool {
        self.mgr.blocks_in_use == 0 && self.pool.live_blocks() == self.radix.cached_blocks
    }

    /// Reserve `need` blocks, evicting cold cached prefixes to cover a
    /// shortfall.  Metric side effects: eviction + released bytes.
    fn reserve_with_eviction(&mut self, need: usize, metrics: &ServeMetrics) -> Result<()> {
        // A reservation no amount of eviction can satisfy must not destroy
        // the warm prefix cache on its way to the inevitable rejection:
        // active reservations are as unevictable as the request itself, so
        // feasibility is `in_use + need <= budget`.
        if let Some(b) = self.mgr.budget_blocks {
            if self.mgr.blocks_in_use + need > b {
                bail!(
                    "reservation of {need} blocks cannot fit shard budget of {b} \
                     ({} already reserved)",
                    self.mgr.blocks_in_use
                );
            }
        }
        if self.mgr.reserve(need).is_err() {
            let short = self.mgr.shortfall(need);
            let freed = self.radix.evict_lru(&mut self.pool, short);
            self.mgr.note_evicted(freed);
            metrics.blocks_evicted.add(freed as u64);
            metrics
                .cache_released_bytes
                .add((freed * self.block_bytes()) as u64);
            self.mgr.reserve(need)?;
        }
        metrics
            .cache_reserved_bytes
            .add((need * self.block_bytes()) as u64);
        metrics
            .cache_peak_bytes
            .observe_max((self.mgr.total_blocks() * self.block_bytes()) as u64);
        Ok(())
    }

    /// Admit a stored (CQ) sequence: match the prompt against the radix
    /// index, pin the hit blocks, and reserve pool budget for the rest of
    /// the prompt plus `max_new` decode tokens.
    pub fn admit_stored(
        &mut self,
        prompt_ids: &[i32],
        max_new: usize,
        metrics: &ServeMetrics,
    ) -> Result<Admission> {
        let (hit_tokens, hit_blocks) = if self.prefix_sharing {
            let m = self.radix.match_prefix(prompt_ids);
            (m.hit_tokens, m.blocks)
        } else {
            (0, Vec::new())
        };
        // Pin before reserving: eviction during our own admission must not
        // free the span we are about to attach.
        for &b in &hit_blocks {
            self.pool.retain(b);
        }
        metrics.prefix_lookup_tokens.add(prompt_ids.len() as u64);
        metrics.prefix_hit_tokens.add(hit_tokens as u64);
        let need_tokens = prompt_ids.len() - hit_tokens + max_new;
        let need = self.pool.cfg.blocks_for_tokens(need_tokens);
        if let Err(e) = self.reserve_with_eviction(need, metrics) {
            for &b in &hit_blocks {
                self.pool.release(b);
            }
            return Err(e);
        }
        let mut seq = PagedSeqCache::new(self.geom);
        seq.attach_prefix(hit_blocks, hit_tokens);
        Ok(Admission { seq, hit_tokens, reserved_blocks: need })
    }

    /// Admit an accounting-only (fp16) sequence: same block reservation,
    /// no storage and no sharing.
    pub fn admit_unstored(
        &mut self,
        prompt_tokens: usize,
        max_new: usize,
        metrics: &ServeMetrics,
    ) -> Result<Admission> {
        let need = self.pool.cfg.blocks_for_tokens(prompt_tokens + max_new);
        self.reserve_with_eviction(need, metrics)?;
        Ok(Admission {
            seq: PagedSeqCache::new_unstored(self.geom),
            hit_tokens: 0,
            reserved_blocks: need,
        })
    }

    /// Admit an accounting-only sequence charged at an explicit byte rate:
    /// the per-tenant fix for fp16 tenants being admitted against quantized
    /// block math.  The reservation converts the tenant's byte demand into
    /// budget-equivalent blocks.
    pub fn admit_unstored_bytes(
        &mut self,
        prompt_tokens: usize,
        max_new: usize,
        bytes_per_token: usize,
        metrics: &ServeMetrics,
    ) -> Result<Admission> {
        let bytes = (prompt_tokens + max_new) * bytes_per_token;
        let need = bytes.div_ceil(self.block_bytes().max(1));
        self.reserve_with_eviction(need, metrics)?;
        let mut seq = PagedSeqCache::new_unstored(self.geom);
        seq.set_fp_cost(bytes_per_token);
        Ok(Admission { seq, hit_tokens: 0, reserved_blocks: need })
    }

    /// Admit a stored sequence under a retention policy.  No radix matching
    /// (the pool chain starts after the sink pen, so block chains are not
    /// prefix-aligned with plain sequences); the budget charge is the
    /// policy's mixed rate — quantized blocks for the tokens that will
    /// retire plus fp-equivalent blocks for the resident window + sinks
    /// (penned tokens hold no pool pages, but their bytes still count
    /// against the shard budget).
    pub fn admit_retained(
        &mut self,
        prompt_tokens: usize,
        max_new: usize,
        retention: Retention,
        fp_bytes_per_token: usize,
        metrics: &ServeMetrics,
    ) -> Result<Admission> {
        let total = prompt_tokens + max_new;
        let fp_tokens = total.min(retention.window + retention.sinks);
        let q_tokens = total - fp_tokens;
        let q_blocks = self.pool.cfg.blocks_for_tokens(q_tokens);
        let fp_blocks = (fp_tokens * fp_bytes_per_token).div_ceil(self.block_bytes().max(1));
        let need = q_blocks + fp_blocks;
        self.reserve_with_eviction(need, metrics)?;
        Ok(Admission {
            seq: PagedSeqCache::with_retention(self.geom, retention, fp_bytes_per_token),
            hit_tokens: 0,
            reserved_blocks: need,
        })
    }

    /// Complete a sequence: promote its full-block prefix into the radix
    /// index (`token_ids` must cover `seq.len` cached tokens — prompt plus
    /// generated), then release the sequence's references and reservation.
    /// Returns the number of blocks newly cached.
    pub fn finish(
        &mut self,
        seq: &mut PagedSeqCache,
        token_ids: &[i32],
        reserved_blocks: usize,
        metrics: &ServeMetrics,
    ) -> usize {
        let mut promoted = 0;
        if self.prefix_sharing && seq.stored && seq.retention.is_none() {
            let (full_tokens, chain) = seq.full_block_chain(&self.pool);
            if full_tokens > 0 && token_ids.len() >= full_tokens {
                promoted = self
                    .radix
                    .insert(&token_ids[..full_tokens], &chain, &mut self.pool);
                metrics.blocks_promoted.add(promoted as u64);
            }
        }
        seq.release(&mut self.pool);
        // Settle the reservation before accounting the promoted blocks as
        // cached — they are the same physical blocks, not new demand.
        self.mgr.release(reserved_blocks);
        self.mgr.note_cached(promoted);
        debug_assert_eq!(
            self.mgr.cached_blocks, self.radix.cached_blocks,
            "manager/index cached-block accounting drifted"
        );
        // Promoted blocks stay resident (now owned by the index); only the
        // rest of the reservation returns to the budget.
        metrics
            .cache_released_bytes
            .add((reserved_blocks.saturating_sub(promoted) * self.block_bytes()) as u64);
        metrics
            .cache_frag_bytes
            .observe_max(self.pool.frag_bytes() as u64);
        promoted
    }

    /// Tear down a **cancelled** sequence mid-decode.  Identical settlement
    /// to [`Self::finish`]: the tokens decoded before the cancel landed are
    /// real, so their completed full blocks still promote into the radix
    /// index (the interrupted turn's prefix stays warm for a session
    /// follow-up), while the partial tail block and the whole reservation
    /// return to the budget immediately.  Returns promoted blocks.
    pub fn cancel(
        &mut self,
        seq: &mut PagedSeqCache,
        token_ids: &[i32],
        reserved_blocks: usize,
        metrics: &ServeMetrics,
    ) -> usize {
        self.finish(seq, token_ids, reserved_blocks, metrics)
    }

    /// Tear down a sequence that never completed (prefill failure): release
    /// its blocks and the whole reservation.
    pub fn abort(&mut self, seq: &mut PagedSeqCache, reserved_blocks: usize, metrics: &ServeMetrics) {
        seq.release(&mut self.pool);
        self.mgr.release(reserved_blocks);
        metrics
            .cache_released_bytes
            .add((reserved_blocks * self.block_bytes()) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> CacheGeom {
        CacheGeom { n_layers: 1, n_heads: 1, groups: 2, bits: 4, tmax: 64 }
    }

    const BT: usize = 4;

    fn shard(budget_blocks: Option<usize>) -> PagedShard {
        PagedShard::new(geom(), BT, budget_blocks, true)
    }

    /// Deterministic per-token codes derived from the token id.
    fn codes(id: i32) -> (Vec<u32>, Vec<u32>) {
        let k = vec![(id as u32) % 16, (id as u32 + 5) % 16];
        let v = vec![(id as u32 + 9) % 16, (id as u32 + 2) % 16];
        (k, v)
    }

    /// Drive one client through the full admit → store → decode → finish
    /// protocol; returns (hit_tokens, promoted_blocks).
    fn run_client(
        sh: &mut PagedShard,
        prompt: &[i32],
        gen: &[i32],
        metrics: &ServeMetrics,
    ) -> (usize, usize) {
        let adm = sh.admit_stored(prompt, gen.len(), metrics).expect("admit");
        let mut seq = adm.seq;
        // Quantize+store ONLY the unmatched prompt span — the prefix hit.
        for &id in &prompt[adm.hit_tokens..] {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        let mut ids = prompt.to_vec();
        for &id in gen {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
            ids.push(id);
        }
        let promoted = sh.finish(&mut seq, &ids, adm.reserved_blocks, metrics);
        (adm.hit_tokens, promoted)
    }

    #[test]
    fn append_and_read_roundtrip_across_blocks() {
        let mut sh = shard(None);
        let mut seq = PagedSeqCache::new(geom());
        let toks: Vec<i32> = (0..11).collect(); // spans 3 blocks of 4
        for &t in &toks {
            let (k, v) = codes(t);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        assert_eq!(seq.len, 11);
        assert_eq!(sh.pool.live_blocks(), 3);
        for &t in &[7i32, 0, 10, 4, 3] {
            let (k, v) = seq.token(&sh.pool, t as usize);
            assert_eq!((k, v), codes(t), "token {t}");
        }
        assert_eq!(seq.logical_bytes(), 11 * geom().bytes_per_token());
        assert_eq!(seq.block_bytes_held(&sh.pool), 3 * sh.block_bytes());
        seq.release(&mut sh.pool);
        assert_eq!(sh.pool.live_blocks(), 0, "release frees everything");
    }

    #[test]
    fn prop_bulk_span_readout_matches_per_token_reads() {
        // read_span_into (block-bulk unpack) must agree with token() for
        // every sub-span, across block boundaries, for dense (byte-aligned
        // record) and ragged (padded record) geometries alike.
        use crate::util::proptest::run_prop;
        run_prop(25, 61, |rng| {
            let geom = CacheGeom {
                n_layers: 1 + rng.below(2),
                n_heads: 1 + rng.below(2),
                groups: 1 + rng.below(5),
                bits: 1 + rng.below(10) as u32,
                tmax: 64,
            };
            let bt = 1 + rng.below(6);
            let mut pool = BlockPool::new(BlockConfig::new(bt, geom.bytes_per_token()), None);
            let per_side = geom.n_layers * geom.n_heads * geom.groups;
            let maxc = 1usize << geom.bits;
            let mut seq = PagedSeqCache::new(geom);
            let n_tok = 2 + rng.below(20);
            let mut expect: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
            for _ in 0..n_tok {
                let k: Vec<u32> = (0..per_side).map(|_| rng.below(maxc) as u32).collect();
                let v: Vec<u32> = (0..per_side).map(|_| rng.below(maxc) as u32).collect();
                seq.append(&mut pool, &k, &v).map_err(|e| e.to_string())?;
                expect.push((k, v));
            }
            let cpt = 2 * per_side;
            for _ in 0..6 {
                let t0 = rng.below(n_tok);
                let n = 1 + rng.below(n_tok - t0);
                let mut out = vec![0u32; n * cpt];
                seq.read_span_into(&pool, t0, n, &mut out);
                for i in 0..n {
                    let (k, v) = seq.token(&pool, t0 + i);
                    let rec = &out[i * cpt..(i + 1) * cpt];
                    if rec[..per_side] != k[..] || rec[per_side..] != v[..] {
                        return Err(format!(
                            "span ({t0},{n}) token {i} mismatch (bits={}, bt={bt})",
                            geom.bits
                        ));
                    }
                    if (k, v) != expect[t0 + i] {
                        return Err(format!("token({}) drifted from appended", t0 + i));
                    }
                    // And the pre-PR bit-loop path agrees with both.
                    if seq.token_reference(&pool, t0 + i) != expect[t0 + i] {
                        return Err(format!("token_reference({}) diverged", t0 + i));
                    }
                }
            }
            seq.release(&mut pool);
            Ok(())
        });
    }

    #[test]
    fn bulk_readout_spans_shared_and_private_blocks() {
        // A radix-hit sequence reads its shared prefix and private tail
        // through the same bulk call.
        let mut sh = shard(None);
        let m = ServeMetrics::default();
        let prompt: Vec<i32> = (0..8).collect(); // 2 full blocks
        run_client(&mut sh, &prompt, &[50, 51], &m);
        let adm = sh.admit_stored(&prompt, 4, &m).expect("admit");
        assert_eq!(adm.hit_tokens, 8);
        let mut seq = adm.seq;
        for id in [90i32, 91, 92] {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        let per_side = 2;
        let cpt = 2 * per_side;
        let mut out = vec![0u32; 11 * cpt];
        seq.read_span_into(&sh.pool, 0, 11, &mut out);
        for (t, want_id) in (0..8).map(|t| (t, t as i32)).chain([(8, 90), (9, 91), (10, 92)]) {
            let (k, v) = codes(want_id);
            let rec = &out[t * cpt..(t + 1) * cpt];
            assert_eq!(&rec[..per_side], &k[..], "token {t}");
            assert_eq!(&rec[per_side..], &v[..], "token {t}");
        }
        sh.abort(&mut seq, adm.reserved_blocks, &m);
    }

    #[test]
    fn append_span_matches_token_by_token_append() {
        let mut sh = shard(None);
        let per_side = 2;
        let n = 9usize;
        let mut k_all = Vec::new();
        let mut v_all = Vec::new();
        for id in 0..n as i32 {
            let (k, v) = codes(id);
            k_all.extend(k);
            v_all.extend(v);
        }
        let mut seq = PagedSeqCache::new(geom());
        seq.append_span(&mut sh.pool, &k_all, &v_all, n).unwrap();
        assert_eq!(seq.len, n);
        for t in 0..n {
            assert_eq!(seq.token(&sh.pool, t), codes(t as i32), "token {t}");
        }
        // Length mismatches are rejected before any mutation.
        assert!(seq
            .append_span(&mut sh.pool, &k_all[..per_side], &v_all, 1)
            .is_err());
        seq.release(&mut sh.pool);
    }

    #[test]
    fn shared_system_prompt_is_stored_once() {
        let mut sh = shard(None);
        let m = ServeMetrics::default();
        let prompt: Vec<i32> = (0..16).collect(); // 4 full blocks

        let (hit_a, promoted_a) = run_client(&mut sh, &prompt, &[100, 101, 102], &m);
        assert_eq!(hit_a, 0, "cold cache: no hit");
        assert_eq!(promoted_a, 4, "prompt blocks promoted");
        assert_eq!(sh.pool.live_blocks(), 4, "only the cached prefix survives");

        let (hit_b, promoted_b) = run_client(&mut sh, &prompt, &[200, 201], &m);
        assert_eq!(hit_b, 16, "whole prompt served from cache");
        assert_eq!(promoted_b, 0, "nothing new to store");
        assert_eq!(
            sh.pool.live_blocks(),
            4,
            "two clients sharing a system prompt produce ONE stored prefix"
        );
        // The acceptance metric: quantize+store was skipped for 16 tokens.
        assert_eq!(m.prefix_hit_tokens.get(), 16);
        assert_eq!(m.prefix_lookup_tokens.get(), 32);
        // Reservation shrank with the hit: B needed 1 block (2 decode
        // tokens), not 5.
        assert_eq!(sh.mgr.blocks_in_use, 0, "reservations fully returned");
        assert_eq!(sh.mgr.cached_blocks, 4);
    }

    #[test]
    fn divergent_client_copies_only_the_divergent_span() {
        let mut sh = shard(None);
        let m = ServeMetrics::default();
        let prompt_a: Vec<i32> = (0..16).collect();
        run_client(&mut sh, &prompt_a, &[100], &m);
        // B shares 2 blocks then diverges mid-block (token 10).
        let mut prompt_b = prompt_a[..10].to_vec();
        prompt_b.extend([70, 71, 72, 73, 74, 75]);
        let (hit_b, promoted_b) = run_client(&mut sh, &prompt_b, &[201], &m);
        assert_eq!(hit_b, 8, "mid-block divergence floors to 2 blocks");
        assert_eq!(promoted_b, 2, "B's divergent 2 blocks cached separately");
        assert_eq!(sh.pool.live_blocks(), 6, "4 of A + 2 divergent of B");
        // Both prefixes stay readable through the index.
        assert_eq!(sh.radix.match_prefix(&prompt_a).hit_tokens, 16);
        assert_eq!(sh.radix.match_prefix(&prompt_b).hit_tokens, 16);
    }

    #[test]
    fn eviction_under_pressure_recovers_reservations() {
        let budget = 6usize;
        let mut sh = shard(Some(budget));
        let m = ServeMetrics::default();
        let prompt_a: Vec<i32> = (0..16).collect(); // 4 blocks
        run_client(&mut sh, &prompt_a, &[], &m);
        assert_eq!(sh.mgr.cached_blocks, 4);
        assert!(sh.pool.live_bytes() <= budget * sh.block_bytes());

        // B needs 4 blocks; 0 in use + 4 cached + 4 > 6 → evict A's prefix.
        let prompt_b: Vec<i32> = (100..116).collect();
        let (hit_b, _) = run_client(&mut sh, &prompt_b, &[], &m);
        assert_eq!(hit_b, 0);
        assert_eq!(m.blocks_evicted.get(), 4, "A's cold prefix was evicted");
        assert_eq!(sh.radix.match_prefix(&prompt_a).hit_tokens, 0, "A gone");
        assert_eq!(sh.radix.match_prefix(&prompt_b).hit_tokens, 16, "B cached");
        assert!(sh.pool.live_bytes() <= budget * sh.block_bytes());

        // A reservation that can never fit (8 blocks > budget 6) must be
        // rejected WITHOUT evicting the warm cache on the way out.
        let prompt_big: Vec<i32> = (300..332).collect();
        assert!(sh.admit_stored(&prompt_big, 0, &m).is_err());
        assert_eq!(
            sh.radix.match_prefix(&prompt_b).hit_tokens,
            16,
            "infeasible request must not cold-start the cache"
        );

        // A pinned prefix is not evictable: admit C while holding B's
        // blocks, then ask for more than the unpinned remainder.
        let adm = sh.admit_stored(&prompt_b, 0, &m).expect("hit needs 0 blocks");
        assert_eq!(adm.hit_tokens, 16);
        let prompt_d: Vec<i32> = (200..212).collect(); // 3 blocks; 4 pinned + 3 > 6
        assert!(
            sh.admit_stored(&prompt_d, 0, &m).is_err(),
            "pinned blocks cannot be evicted to make room"
        );
        assert_eq!(sh.radix.match_prefix(&prompt_b).hit_tokens, 16, "B survives");
        let mut seq = adm.seq;
        sh.finish(&mut seq, &prompt_b, adm.reserved_blocks, &m);
        assert!(sh.pool.live_bytes() <= budget * sh.block_bytes());
        assert_eq!(sh.mgr.blocks_in_use, 0);
    }

    #[test]
    fn cancel_mid_decode_promotes_full_blocks_and_frees_reservation() {
        let mut sh = shard(Some(8));
        let m = ServeMetrics::default();
        let prompt: Vec<i32> = (0..8).collect(); // 2 full blocks of 4
        let adm = sh.admit_stored(&prompt, 8, &m).expect("admit");
        assert_eq!(adm.reserved_blocks, 4, "prompt (2) + max_new (2) blocks");
        let in_use_before = sh.mgr.blocks_in_use;
        let mut seq = adm.seq;
        let mut ids = prompt.clone();
        for &id in &prompt {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        // Three decode tokens land before the cancel: 11 cached tokens =
        // 2 full blocks + 1 partial tail.
        for &id in &[100i32, 101, 102] {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
            ids.push(id);
        }
        let promoted = sh.cancel(&mut seq, &ids, adm.reserved_blocks, &m);
        assert_eq!(promoted, 2, "completed full blocks stay warm");
        assert_eq!(
            sh.mgr.blocks_in_use,
            in_use_before - adm.reserved_blocks,
            "reservation fully returned"
        );
        assert_eq!(sh.mgr.cached_blocks, 2);
        assert_eq!(sh.pool.live_blocks(), 2, "partial tail block freed");
        // The interrupted turn's prefix is immediately matchable (a session
        // follow-up attaches to these blocks).
        assert_eq!(sh.radix.match_prefix(&ids).hit_tokens, 8);
        // Budget is genuinely recovered: the same admission succeeds again.
        assert!(sh.admit_stored(&prompt, 8, &m).is_ok());
    }

    #[test]
    fn abort_returns_blocks_and_reservation() {
        let mut sh = shard(Some(4));
        let m = ServeMetrics::default();
        let prompt: Vec<i32> = (0..8).collect();
        let adm = sh.admit_stored(&prompt, 4, &m).unwrap();
        let mut seq = adm.seq;
        for &id in &prompt[..5] {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        assert!(sh.pool.live_blocks() > 0);
        sh.abort(&mut seq, adm.reserved_blocks, &m);
        assert_eq!(sh.pool.live_blocks(), 0);
        assert_eq!(sh.mgr.blocks_in_use, 0);
        // Budget fully recovered: the same admission succeeds again.
        let adm2 = sh.admit_stored(&prompt, 4, &m).unwrap();
        assert_eq!(adm2.reserved_blocks, 3);
    }

    #[test]
    fn quantize_on_retire_is_byte_identical_to_direct_packing() {
        // The acceptance invariant: a token that ages past the window packs
        // into exactly the bytes a plain sequence would have stored for it.
        let mut sh_plain = shard(None);
        let mut sh_ret = shard(None);
        let mut plain = PagedSeqCache::new(geom());
        let r = Retention { window: 3, sinks: 0 };
        let mut ret = PagedSeqCache::with_retention(geom(), r, 4);
        for id in 0..11 {
            let (k, v) = codes(id);
            plain.append(&mut sh_plain.pool, &k, &v).unwrap();
            ret.append(&mut sh_ret.pool, &k, &v).unwrap();
        }
        assert_eq!(ret.pooled_tokens(), 8, "11 appended, 3 still in the window");
        assert_eq!(ret.retired_tokens, 8);
        // Retired records already match the plain chain byte for byte.
        for (i, (&pb, &rb)) in plain.private.iter().zip(&ret.private).enumerate() {
            let n = sh_ret.pool.records_bytes(rb).len();
            assert_eq!(
                sh_plain.pool.records_bytes(pb)[..n],
                sh_ret.pool.records_bytes(rb)[..],
                "block {i}"
            );
        }
        // Drain the rest and the chains are fully identical.
        assert_eq!(ret.drain_window(&mut sh_ret.pool).unwrap(), 3);
        assert_eq!(ret.retired_tokens, 11);
        assert_eq!(ret.window_tokens(), 0);
        assert_eq!(plain.private.len(), ret.private.len());
        for (&pb, &rb) in plain.private.iter().zip(&ret.private) {
            assert_eq!(sh_plain.pool.records_bytes(pb), sh_ret.pool.records_bytes(rb));
        }
        for t in 0..11 {
            assert_eq!(ret.token(&sh_ret.pool, t), codes(t as i32), "token {t}");
        }
        plain.release(&mut sh_plain.pool);
        ret.release(&mut sh_ret.pool);
    }

    #[test]
    fn window_and_sinks_stay_fp_until_retire() {
        let mut sh = shard(None);
        let r = Retention { window: 4, sinks: 2 };
        let fp_bpt = 3 * geom().bytes_per_token();
        let mut seq = PagedSeqCache::with_retention(geom(), r, fp_bpt);
        for id in 0..10 {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        // 2 sinks + 4 window fp-resident; tokens 2..=5 retired to the pool.
        assert_eq!(seq.window_tokens(), 6);
        assert_eq!(seq.pooled_tokens(), 4);
        assert_eq!(seq.retired_tokens, 4);
        assert_eq!(sh.pool.live_blocks(), 1, "4 retired tokens fit one block");
        // All three regions read back through the same API.
        for t in 0..10 {
            assert_eq!(seq.token(&sh.pool, t), codes(t as i32), "token {t}");
        }
        let mut out = vec![0u32; 10 * 4];
        seq.read_span_into(&sh.pool, 0, 10, &mut out);
        for t in 0..10usize {
            let (k, v) = codes(t as i32);
            assert_eq!(&out[t * 4..t * 4 + 2], &k[..], "span token {t}");
            assert_eq!(&out[t * 4 + 2..t * 4 + 4], &v[..], "span token {t}");
        }
        // Mixed-rate accounting: pooled at the quantized rate, pens at fp.
        assert_eq!(
            seq.logical_bytes(),
            4 * geom().bytes_per_token() + 6 * fp_bpt
        );
        // Two more appends retire two more; the sinks never move.
        for id in 10..12 {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
        }
        assert_eq!(seq.retired_tokens, 6);
        assert_eq!(seq.window_tokens(), 6);
        assert_eq!(seq.token(&sh.pool, 0), codes(0), "sink 0 still fp");
        // Draining packs only the window — sinks cannot reorder the chain.
        assert_eq!(seq.drain_window(&mut sh.pool).unwrap(), 4);
        assert_eq!(seq.window_tokens(), 2, "sinks remain penned");
        seq.release(&mut sh.pool);
        assert_eq!(sh.pool.live_blocks(), 0);
    }

    #[test]
    fn admit_retained_charges_mixed_rate_and_never_promotes() {
        let mut sh = shard(Some(8));
        let m = ServeMetrics::default();
        let r = Retention { window: 4, sinks: 2 };
        // total 12 tokens: 6 fp-resident at 4 B (= 3 blocks of 8 B) plus
        // 6 retiring tokens (= 2 quantized blocks of 4 tokens).
        let adm = sh.admit_retained(8, 4, r, 4, &m).expect("admit");
        assert_eq!(adm.reserved_blocks, 5);
        assert_eq!(adm.hit_tokens, 0, "retention skips the radix");
        let mut seq = adm.seq;
        let mut ids = Vec::new();
        for id in 0..12 {
            let (k, v) = codes(id);
            seq.append(&mut sh.pool, &k, &v).unwrap();
            ids.push(id);
        }
        assert_eq!(seq.pooled_tokens(), 6);
        let promoted = sh.finish(&mut seq, &ids, adm.reserved_blocks, &m);
        assert_eq!(promoted, 0, "retention chains never enter the radix");
        assert_eq!(m.blocks_promoted.get(), 0);
        assert!(sh.idle(), "reservation and blocks fully returned");
        assert_eq!(sh.pool.live_blocks(), 0);
    }

    #[test]
    fn admit_unstored_bytes_charges_the_policy_rate() {
        // 3 B/token tenant on an 8 B/block shard: 8 tokens → 24 B → 3 blocks,
        // not the quantized-rate 2 blocks admit_unstored would charge.
        let mut sh = PagedShard::new(geom(), BT, Some(4), false);
        let m = ServeMetrics::default();
        let adm = sh.admit_unstored_bytes(4, 4, 3, &m).unwrap();
        assert_eq!(adm.reserved_blocks, 3);
        let mut seq = adm.seq;
        for _ in 0..8 {
            seq.append_unstored().unwrap();
        }
        assert_eq!(seq.logical_bytes(), 24, "unstored bytes follow the fp rate");
        assert_eq!(sh.pool.live_blocks(), 0, "accounting only, no pages");
        assert!(
            sh.admit_unstored_bytes(4, 4, 3, &m).is_err(),
            "second tenant exceeds the budget at its own rate"
        );
        sh.finish(&mut seq, &[], adm.reserved_blocks, &m);
        assert!(sh.admit_unstored_bytes(4, 4, 3, &m).is_ok(), "budget recovered");
    }

    #[test]
    fn unstored_mode_reserves_without_storing() {
        let mut sh = PagedShard::new(geom(), BT, Some(3), false);
        let m = ServeMetrics::default();
        let adm = sh.admit_unstored(8, 4, &m).unwrap();
        assert_eq!(adm.reserved_blocks, 3);
        let mut seq = adm.seq;
        for _ in 0..12 {
            seq.append_unstored().unwrap();
        }
        assert_eq!(sh.pool.live_blocks(), 0, "fp mode allocates no pages");
        assert!(sh.admit_unstored(1, 0, &m).is_err(), "budget exhausted");
        sh.finish(&mut seq, &[], adm.reserved_blocks, &m);
        assert!(sh.admit_unstored(1, 0, &m).is_ok(), "budget recovered");
    }
}

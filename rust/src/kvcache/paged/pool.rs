//! Slab block pool: free-list allocation, ref-counting and the hard block
//! budget for one cache shard.
//!
//! All sequences of a shard draw blocks from one pool, so the pool is where
//! the byte budget is actually *enforced* (the `CacheManager` reservation is
//! the admission-time estimate; `alloc` is the ground truth).  Blocks are
//! recycled through a free list, never deallocated, so a long-running shard
//! reaches a steady-state slab and stops touching the system allocator.

use anyhow::{bail, Result};

use super::block::{Block, BlockConfig, BlockId};

/// Lifetime allocator counters for one pool (local diagnostics and test
/// invariants; serving telemetry lives in `crate::metrics::ServeMetrics`,
/// fed by `PagedShard`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Blocks handed out by `alloc` (including recycled ones).
    pub allocs: usize,
    /// Blocks whose refcount reached zero and returned to the free list.
    pub frees: usize,
}

/// Ref-counted slab of fixed-size packed-code blocks.
pub struct BlockPool {
    pub cfg: BlockConfig,
    /// Hard cap on concurrently live blocks (None = unbounded).
    pub cap_blocks: Option<usize>,
    blocks: Vec<Block>,
    free: Vec<BlockId>,
    pub stats: PoolStats,
}

impl BlockPool {
    pub fn new(cfg: BlockConfig, cap_blocks: Option<usize>) -> BlockPool {
        BlockPool { cfg, cap_blocks, blocks: Vec::new(), free: Vec::new(), stats: PoolStats::default() }
    }

    /// Live (allocated, refcount > 0) blocks.
    pub fn live_blocks(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    /// Bytes held by live blocks (every live block owns a full-size slab
    /// page whether or not it is full of tokens).
    pub fn live_bytes(&self) -> usize {
        self.live_blocks() * self.cfg.block_bytes()
    }

    /// Internal fragmentation: bytes of live pages not covered by written
    /// token records (partially-filled tail blocks).
    pub fn frag_bytes(&self) -> usize {
        let used: usize = self
            .blocks
            .iter()
            .filter(|b| b.refs > 0)
            .map(|b| b.len * self.cfg.bytes_per_token)
            .sum();
        self.live_bytes() - used
    }

    /// Allocate an empty block with refcount 1.  Fails when the cap is
    /// reached — the caller (shard admission) turns this into eviction or
    /// backpressure.
    pub fn alloc(&mut self) -> Result<BlockId> {
        if let Some(cap) = self.cap_blocks {
            if self.live_blocks() >= cap {
                bail!("block pool exhausted: {cap} blocks live");
            }
        }
        self.stats.allocs += 1;
        if let Some(id) = self.free.pop() {
            let b = &mut self.blocks[id];
            b.len = 0;
            b.refs = 1;
            return Ok(id);
        }
        self.blocks.push(Block {
            data: vec![0u8; self.cfg.block_bytes()],
            len: 0,
            refs: 1,
        });
        Ok(self.blocks.len() - 1)
    }

    /// Add a reference (sequence attach, radix insert).
    pub fn retain(&mut self, id: BlockId) {
        let b = &mut self.blocks[id];
        assert!(b.refs > 0, "retain of free block {id}");
        b.refs += 1;
    }

    /// Drop a reference; a block hitting zero returns to the free list.
    /// Returns true when the block was freed by this call.
    pub fn release(&mut self, id: BlockId) -> bool {
        let b = &mut self.blocks[id];
        assert!(b.refs > 0, "release of free block {id}");
        b.refs -= 1;
        if b.refs == 0 {
            self.free.push(id);
            self.stats.frees += 1;
            true
        } else {
            false
        }
    }

    pub fn refs(&self, id: BlockId) -> usize {
        self.blocks[id].refs
    }

    /// Token records written into `id`.
    pub fn len(&self, id: BlockId) -> usize {
        self.blocks[id].len
    }

    pub fn is_full(&self, id: BlockId) -> bool {
        self.blocks[id].is_full(&self.cfg)
    }

    /// Append one packed token record; the block must not be full.
    pub fn push_token(&mut self, id: BlockId, record: &[u8]) -> Result<()> {
        let bpt = self.cfg.bytes_per_token;
        if record.len() != bpt {
            bail!("token record is {} bytes, want {bpt}", record.len());
        }
        let b = &mut self.blocks[id];
        assert!(b.refs > 0, "write to free block {id}");
        if b.len >= self.cfg.block_tokens {
            bail!("block {id} full ({} tokens)", self.cfg.block_tokens);
        }
        let off = b.len * bpt;
        b.data[off..off + bpt].copy_from_slice(record);
        b.len += 1;
        Ok(())
    }

    /// Read token record `i` of block `id`.
    pub fn token_bytes(&self, id: BlockId, i: usize) -> &[u8] {
        let b = &self.blocks[id];
        assert!(b.refs > 0, "read of free block {id}");
        assert!(i < b.len, "token {i} beyond fill {}", b.len);
        let bpt = self.cfg.bytes_per_token;
        &b.data[i * bpt..(i + 1) * bpt]
    }

    /// All written token records of block `id` as one contiguous span
    /// (`len(id) * bytes_per_token` bytes) — the bulk-readout input: a whole
    /// block's records unpack with one kernel call instead of `len` slices.
    pub fn records_bytes(&self, id: BlockId) -> &[u8] {
        let b = &self.blocks[id];
        assert!(b.refs > 0, "read of free block {id}");
        &b.data[..b.len * self.cfg.bytes_per_token]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    fn pool(cap: Option<usize>) -> BlockPool {
        BlockPool::new(BlockConfig::new(4, 3), cap)
    }

    #[test]
    fn alloc_free_recycles_slots() {
        let mut p = pool(None);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.live_blocks(), 2);
        assert!(p.release(a), "last ref frees");
        assert_eq!(p.live_blocks(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed slot is recycled");
        assert_eq!(p.len(c), 0, "recycled block is reset");
        assert_eq!(p.stats.allocs, 3);
        assert_eq!(p.stats.frees, 1);
        let _ = b;
    }

    #[test]
    fn refcounts_delay_free_until_last_release() {
        let mut p = pool(None);
        let a = p.alloc().unwrap();
        p.retain(a);
        p.retain(a);
        assert_eq!(p.refs(a), 3);
        assert!(!p.release(a));
        assert!(!p.release(a));
        assert_eq!(p.live_blocks(), 1);
        assert!(p.release(a), "third release frees");
        assert_eq!(p.live_blocks(), 0);
    }

    #[test]
    fn cap_is_a_hard_ceiling() {
        let mut p = pool(Some(2));
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert!(p.alloc().is_err(), "cap reached");
        p.release(a);
        assert!(p.alloc().is_ok(), "freeing makes room");
        assert!(p.live_bytes() <= 2 * p.cfg.block_bytes());
    }

    #[test]
    fn token_records_roundtrip_and_fill() {
        let mut p = pool(None);
        let a = p.alloc().unwrap();
        for t in 0..4u8 {
            p.push_token(a, &[t, t + 1, t + 2]).unwrap();
        }
        assert!(p.is_full(a));
        assert!(p.push_token(a, &[0, 0, 0]).is_err(), "overfill rejected");
        let err = p.push_token(a, &[0]).unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
        assert_eq!(p.token_bytes(a, 2), &[2, 3, 4]);
        assert_eq!(p.frag_bytes(), 0, "full block has no waste");
        let b = p.alloc().unwrap();
        p.push_token(b, &[9, 9, 9]).unwrap();
        assert_eq!(p.frag_bytes(), 3 * 3, "3 unwritten records in block b");
    }

    #[test]
    fn prop_live_count_matches_alloc_release_history() {
        run_prop(20, 77, |rng| {
            let mut p = pool(Some(8));
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..200 {
                if live.is_empty() || (rng.below(2) == 0 && live.len() < 8) {
                    live.push(p.alloc().map_err(|e| e.to_string())?);
                } else {
                    let i = rng.below(live.len());
                    let id = live.swap_remove(i);
                    if !p.release(id) {
                        return Err(format!("single-ref block {id} not freed"));
                    }
                }
                if p.live_blocks() != live.len() {
                    return Err(format!(
                        "live {} != tracked {}",
                        p.live_blocks(),
                        live.len()
                    ));
                }
            }
            Ok(())
        });
    }
}

//! Radix index over token-id prefixes → chains of frozen packed-code blocks.
//!
//! Every edge covers a whole number of blocks (`block_tokens` tokens each):
//! sequences are inserted as full-block chains, and splits happen only at
//! block boundaries.  Two inserted sequences that diverge *inside* a block
//! therefore share only the floor of full blocks and keep private copies of
//! the divergent block — copy-on-write at block granularity.  Because
//! sibling edges may then share a sub-block token prefix, child lookup scans
//! all children for the longest token match instead of dispatching on the
//! first token (children counts are tiny; correctness over micro-speed).
//!
//! The index owns one pool reference per block it caches.  [`RadixIndex::
//! evict_lru`] walks cold leaves (no children, no outside references) in
//! least-recently-touched order and releases them, which is how a full shard
//! recovers budget for new admissions.

use super::block::BlockId;
use super::pool::BlockPool;

/// Result of a prefix lookup: the shared blocks covering the matched span.
/// `hit_tokens` is always a multiple of the pool's `block_tokens`.
pub struct MatchResult {
    pub blocks: Vec<BlockId>,
    pub hit_tokens: usize,
}

struct Node {
    /// Edge label; `tokens.len() == blocks.len() * block_tokens` (root: 0).
    tokens: Vec<i32>,
    blocks: Vec<BlockId>,
    children: Vec<usize>,
    parent: usize,
    last_used: u64,
}

/// Prefix index for one cache shard.
pub struct RadixIndex {
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    block_tokens: usize,
    /// Logical LRU clock (bumped per lookup/insert, no wall time).
    clock: u64,
    /// Blocks currently referenced by the tree.
    pub cached_blocks: usize,
    /// Lifetime count of blocks released by eviction.
    pub evicted_blocks: usize,
}

impl RadixIndex {
    pub fn new(block_tokens: usize) -> RadixIndex {
        assert!(block_tokens > 0);
        let root = Node {
            tokens: Vec::new(),
            blocks: Vec::new(),
            children: Vec::new(),
            parent: usize::MAX,
            last_used: 0,
        };
        RadixIndex {
            nodes: vec![Some(root)],
            free: Vec::new(),
            block_tokens,
            clock: 0,
            cached_blocks: 0,
            evicted_blocks: 0,
        }
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("live node")
    }

    fn add_node(&mut self, n: Node) -> usize {
        if let Some(i) = self.free.pop() {
            self.nodes[i] = Some(n);
            i
        } else {
            self.nodes.push(Some(n));
            self.nodes.len() - 1
        }
    }

    /// Live nodes, root included (diagnostics/tests).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Child of `node` with the longest common token prefix against `rest`.
    fn best_child(&self, node: usize, rest: &[i32]) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for &c in &self.node(node).children {
            let lab = &self.node(c).tokens;
            let lcp = lab.iter().zip(rest).take_while(|(a, b)| a == b).count();
            if lcp > 0 && best.map(|(_, l)| lcp > l).unwrap_or(true) {
                best = Some((c, lcp));
            }
        }
        best
    }

    /// Longest cached prefix of `tokens`, floored to whole blocks.  Bumps
    /// the LRU clock on every node touched.  Does **not** take references on
    /// the returned blocks — the caller must `retain` them before the next
    /// eviction opportunity (single-threaded per shard, so "immediately").
    pub fn match_prefix(&mut self, tokens: &[i32]) -> MatchResult {
        self.clock += 1;
        let clock = self.clock;
        let mut node = 0;
        let mut pos = 0usize;
        let mut blocks = Vec::new();
        loop {
            let Some((child, lcp)) = self.best_child(node, &tokens[pos..]) else {
                break;
            };
            self.node_mut(child).last_used = clock;
            let edge_len = self.node(child).tokens.len();
            if lcp == edge_len {
                blocks.extend_from_slice(&self.node(child).blocks);
                pos += lcp;
                node = child;
            } else {
                // Divergence (or query end) inside the edge: share only the
                // fully matched blocks.
                let nb = lcp / self.block_tokens;
                blocks.extend_from_slice(&self.node(child).blocks[..nb]);
                pos += nb * self.block_tokens;
                break;
            }
        }
        MatchResult { blocks, hit_tokens: pos }
    }

    /// Insert a full-block chain (`tokens.len() == blocks.len() *
    /// block_tokens`).  Spans already covered by the tree are left as-is
    /// (the tree's blocks win; the caller's duplicates die with the caller's
    /// own references).  Returns the number of blocks newly cached — the
    /// tree `retain`s exactly those.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[BlockId], pool: &mut BlockPool) -> usize {
        assert_eq!(
            tokens.len(),
            blocks.len() * self.block_tokens,
            "insert requires whole blocks"
        );
        self.clock += 1;
        let clock = self.clock;
        let mut node = 0;
        let mut pos = 0usize;
        let mut bi = 0usize; // index into `blocks`
        loop {
            if pos == tokens.len() {
                return 0; // fully covered by existing nodes
            }
            match self.best_child(node, &tokens[pos..]) {
                None => {
                    return self.finish_insert(node, &tokens[pos..], &blocks[bi..], pool);
                }
                Some((child, lcp)) => {
                    self.node_mut(child).last_used = clock;
                    let edge_len = self.node(child).tokens.len();
                    if lcp == edge_len {
                        pos += lcp;
                        bi += self.node(child).blocks.len();
                        node = child;
                        continue;
                    }
                    let nb = lcp / self.block_tokens;
                    if nb == 0 {
                        // Diverges inside the child's first block: the new
                        // chain becomes a sibling (COW: both keep their own
                        // copy of the divergent block).
                        return self.finish_insert(node, &tokens[pos..], &blocks[bi..], pool);
                    }
                    // Split the child at the block boundary, then hang the
                    // remainder (if any) off the new upper node.
                    let upper = self.split_at(child, nb);
                    pos += nb * self.block_tokens;
                    bi += nb;
                    if pos == tokens.len() {
                        return 0;
                    }
                    return self.finish_insert(upper, &tokens[pos..], &blocks[bi..], pool);
                }
            }
        }
    }

    /// Attach `tokens`/`blocks` as a new child of `parent`, retaining each
    /// block for the tree.  Empty input is a no-op.
    fn finish_insert(
        &mut self,
        parent: usize,
        tokens: &[i32],
        blocks: &[BlockId],
        pool: &mut BlockPool,
    ) -> usize {
        if blocks.is_empty() {
            return 0;
        }
        for &b in blocks {
            pool.retain(b);
        }
        let clock = self.clock;
        let n = self.add_node(Node {
            tokens: tokens.to_vec(),
            blocks: blocks.to_vec(),
            children: Vec::new(),
            parent,
            last_used: clock,
        });
        self.node_mut(parent).children.push(n);
        self.cached_blocks += blocks.len();
        blocks.len()
    }

    /// Split node `child` after its first `nb` blocks; returns the new upper
    /// node's index.  Block references move between nodes, no count changes.
    fn split_at(&mut self, child: usize, nb: usize) -> usize {
        let cut = nb * self.block_tokens;
        let parent = self.node(child).parent;
        let upper_tokens = self.node(child).tokens[..cut].to_vec();
        let upper_blocks = self.node(child).blocks[..nb].to_vec();
        let clock = self.clock;
        let upper = self.add_node(Node {
            tokens: upper_tokens,
            blocks: upper_blocks,
            children: vec![child],
            parent,
            last_used: clock,
        });
        {
            let c = self.node_mut(child);
            c.tokens.drain(..cut);
            c.blocks.drain(..nb);
            c.parent = upper;
        }
        let p = self.node_mut(parent);
        let slot = p.children.iter().position(|&x| x == child).expect("child link");
        p.children[slot] = upper;
        upper
    }

    /// A leaf is evictable when nothing hangs below it and no sequence
    /// holds its blocks (tree reference only).
    fn evictable_leaf(&self, pool: &BlockPool) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            if i == 0 || !n.children.is_empty() {
                continue;
            }
            if n.blocks.iter().any(|&b| pool.refs(b) > 1) {
                continue;
            }
            if best.map(|b| n.last_used < self.node(b).last_used).unwrap_or(true) {
                best = Some(i);
            }
        }
        best
    }

    /// Evict least-recently-used cold leaves until at least `need_blocks`
    /// blocks were released or nothing more is evictable.  Returns blocks
    /// actually freed back to the pool.
    pub fn evict_lru(&mut self, pool: &mut BlockPool, need_blocks: usize) -> usize {
        let mut freed = 0usize;
        while freed < need_blocks {
            let Some(leaf) = self.evictable_leaf(pool) else { break };
            let node = self.nodes[leaf].take().expect("live leaf");
            self.free.push(leaf);
            let p = self.node_mut(node.parent);
            p.children.retain(|&c| c != leaf);
            for &b in &node.blocks {
                pool.release(b);
            }
            freed += node.blocks.len();
            self.cached_blocks -= node.blocks.len();
            self.evicted_blocks += node.blocks.len();
        }
        freed
    }

    /// Release every cached block (shard teardown / tests).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for i in 0..self.nodes.len() {
            if i == 0 {
                continue;
            }
            if let Some(n) = self.nodes[i].take() {
                for &b in &n.blocks {
                    pool.release(b);
                }
                self.free.push(i);
            }
        }
        self.node_mut(0).children.clear();
        self.cached_blocks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::paged::block::BlockConfig;

    const BT: usize = 4; // tokens per block

    fn mk_pool() -> BlockPool {
        BlockPool::new(BlockConfig::new(BT, 2), None)
    }

    /// Token ids `start..start+n_blocks*BT` and freshly allocated blocks.
    fn chain(pool: &mut BlockPool, start: i32, n_blocks: usize) -> (Vec<i32>, Vec<BlockId>) {
        let tokens: Vec<i32> = (0..(n_blocks * BT) as i32).map(|i| start + i).collect();
        let blocks: Vec<BlockId> = (0..n_blocks).map(|_| pool.alloc().unwrap()).collect();
        (tokens, blocks)
    }

    fn release_all(pool: &mut BlockPool, blocks: &[BlockId]) {
        for &b in blocks {
            pool.release(b);
        }
    }

    #[test]
    fn insert_then_exact_and_partial_match() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (tokens, blocks) = chain(&mut pool, 0, 3);
        assert_eq!(rx.insert(&tokens, &blocks, &mut pool), 3);
        assert_eq!(rx.cached_blocks, 3);
        for &b in &blocks {
            assert_eq!(pool.refs(b), 2, "tree holds its own reference");
        }

        let m = rx.match_prefix(&tokens);
        assert_eq!(m.hit_tokens, 12);
        assert_eq!(m.blocks, blocks);

        // Query shorter than the edge: floors to whole blocks.
        let m = rx.match_prefix(&tokens[..7]);
        assert_eq!(m.hit_tokens, 4, "7 matched tokens floor to 1 block");
        assert_eq!(m.blocks, blocks[..1]);

        // Unrelated query misses entirely.
        let m = rx.match_prefix(&[500, 501]);
        assert_eq!(m.hit_tokens, 0);
        assert!(m.blocks.is_empty());
        release_all(&mut pool, &blocks);
    }

    #[test]
    fn boundary_divergence_splits_edge() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (ta, ba) = chain(&mut pool, 0, 4);
        rx.insert(&ta, &ba, &mut pool);
        // B shares A's first 2 blocks exactly, then diverges at the boundary.
        let mut tb = ta[..8].to_vec();
        tb.extend((0..2 * BT as i32).map(|i| 1000 + i));
        let bb: Vec<BlockId> = {
            let mut v = ba[..2].to_vec();
            for _ in 0..2 {
                v.push(pool.alloc().unwrap());
            }
            v
        };
        // Only the 2 divergent-suffix blocks are new to the tree.
        assert_eq!(rx.insert(&tb, &bb, &mut pool), 2);
        assert_eq!(rx.cached_blocks, 6);
        // Root -> shared(2 blocks) -> {A-suffix(2), B-suffix(2)}.
        assert_eq!(rx.node_count(), 4);
        let ma = rx.match_prefix(&ta);
        assert_eq!((ma.hit_tokens, ma.blocks.len()), (16, 4));
        assert_eq!(ma.blocks, ba);
        let mb = rx.match_prefix(&tb);
        assert_eq!((mb.hit_tokens, mb.blocks.len()), (16, 4));
        assert_eq!(mb.blocks[..2], ba[..2], "shared span uses A's storage");
        release_all(&mut pool, &ba);
        release_all(&mut pool, &bb[2..]);
    }

    #[test]
    fn mid_block_divergence_shares_only_the_floor() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (ta, ba) = chain(&mut pool, 0, 3);
        rx.insert(&ta, &ba, &mut pool);
        // B agrees for 2 blocks + 2 tokens, then diverges mid-block: B keeps
        // a private copy of block 2 (copy-on-write at block granularity).
        let mut tb = ta[..10].to_vec();
        tb.extend([900, 901]);
        let bb: Vec<BlockId> = {
            let mut v = ba[..2].to_vec();
            v.push(pool.alloc().unwrap());
            v
        };
        let m = rx.match_prefix(&tb);
        assert_eq!(m.hit_tokens, 8, "mid-block divergence floors to 2 blocks");
        assert_eq!(m.blocks, ba[..2]);
        // Inserting B adds its private third block as a sibling edge whose
        // label overlaps A's suffix for 2 tokens — longest-match scan keeps
        // both resolvable.
        assert_eq!(rx.insert(&tb, &bb, &mut pool), 1);
        let ma = rx.match_prefix(&ta);
        assert_eq!(ma.blocks, ba);
        let mb = rx.match_prefix(&tb);
        assert_eq!(mb.blocks, bb);
        release_all(&mut pool, &ba);
        release_all(&mut pool, &bb[2..]);
    }

    #[test]
    fn duplicate_insert_caches_nothing_new() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (ta, ba) = chain(&mut pool, 0, 2);
        assert_eq!(rx.insert(&ta, &ba, &mut pool), 2);
        // A second client quantized the same prompt concurrently: same
        // tokens, different (duplicate) blocks.  The tree keeps its copy.
        let dup: Vec<BlockId> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        assert_eq!(rx.insert(&ta, &dup, &mut pool), 0);
        assert_eq!(rx.cached_blocks, 2);
        for &b in &dup {
            assert_eq!(pool.refs(b), 1, "duplicates stay caller-owned");
        }
        release_all(&mut pool, &ba);
        release_all(&mut pool, &dup);
        assert_eq!(pool.live_blocks(), 2, "only the tree's copy survives");
    }

    #[test]
    fn lru_eviction_frees_cold_leaves_and_respects_live_refs() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (ta, ba) = chain(&mut pool, 0, 2);
        let (tb, bb) = chain(&mut pool, 100, 2);
        let (tc, bc) = chain(&mut pool, 200, 2);
        rx.insert(&ta, &ba, &mut pool);
        rx.insert(&tb, &bb, &mut pool);
        rx.insert(&tc, &bc, &mut pool);
        // Drop sequence refs for A and B; keep C referenced (in use).
        release_all(&mut pool, &ba);
        release_all(&mut pool, &bb);
        // Touch A so B becomes the coldest.
        rx.match_prefix(&ta);
        assert_eq!(rx.evict_lru(&mut pool, 1), 2, "evicts whole leaf (2 blocks)");
        assert_eq!(rx.cached_blocks, 4);
        assert!(rx.match_prefix(&tb).blocks.is_empty(), "B was evicted");
        assert_eq!(rx.match_prefix(&ta).hit_tokens, 8, "A survived (warmer)");
        // C is pinned by an outside reference: unlimited demand can only
        // take A.
        assert_eq!(rx.evict_lru(&mut pool, 100), 2);
        assert_eq!(rx.match_prefix(&tc).hit_tokens, 8, "pinned leaf survives");
        assert_eq!(rx.evicted_blocks, 4);
        release_all(&mut pool, &bc);
        assert_eq!(rx.evict_lru(&mut pool, 100), 2, "unpinned -> evictable");
        assert_eq!(pool.live_blocks(), 0);
        assert_eq!(rx.cached_blocks, 0);
    }

    #[test]
    fn prop_lru_eviction_never_touches_referenced_chains() {
        // Randomized insert / match / release / evict interleavings over
        // disjoint-prefix chains (each insert is one leaf, so eviction is
        // all-or-nothing per chain).  Invariants checked after every step:
        //   1. a chain whose blocks we still hold (refcount > tree's own)
        //      is never evicted — it always matches in full;
        //   2. the pool's live-block count equals the blocks of held chains
        //      plus the blocks of released-but-still-cached chains (free
        //      list == capacity - live at all times, proven at the end by
        //      allocating exactly to the cap).
        use crate::util::proptest::run_prop;
        run_prop(15, 9157, |rng| {
            let cap = 24usize;
            let mut pool = BlockPool::new(BlockConfig::new(BT, 2), Some(cap));
            let mut rx = RadixIndex::new(BT);
            // (tokens, blocks, held-by-us)
            let mut chains: Vec<(Vec<i32>, Vec<BlockId>, bool)> = Vec::new();
            let mut next_start = 0i32;
            for _step in 0..80 {
                match rng.below(4) {
                    0 => {
                        // Insert a fresh disjoint chain if the cap allows.
                        let nb = 1 + rng.below(3);
                        if pool.live_blocks() + nb <= cap {
                            let tokens: Vec<i32> =
                                (0..(nb * BT) as i32).map(|i| next_start + i).collect();
                            next_start += 10_000;
                            let blocks: Vec<BlockId> =
                                (0..nb).map(|_| pool.alloc().unwrap()).collect();
                            if rx.insert(&tokens, &blocks, &mut pool) != nb {
                                return Err("disjoint insert must cache all blocks".into());
                            }
                            chains.push((tokens, blocks, true));
                        }
                    }
                    1 => {
                        // Drop our reference on a random held chain: it
                        // becomes cold (evictable) but stays cached for now.
                        let held: Vec<usize> = (0..chains.len())
                            .filter(|&i| chains[i].2)
                            .collect();
                        if !held.is_empty() {
                            let i = held[rng.below(held.len())];
                            for &b in &chains[i].1 {
                                pool.release(b);
                            }
                            chains[i].2 = false;
                        }
                    }
                    2 => {
                        // Touch a random chain (bumps LRU recency).
                        if !chains.is_empty() {
                            let i = rng.below(chains.len());
                            let _ = rx.match_prefix(&chains[i].0);
                        }
                    }
                    _ => {
                        let _ = rx.evict_lru(&mut pool, 1 + rng.below(4));
                    }
                }
                // Invariant 1: held chains always fully matchable.
                for (tokens, _, held) in &chains {
                    if *held && rx.match_prefix(tokens).hit_tokens != tokens.len() {
                        return Err("eviction took a refcounted chain".into());
                    }
                }
                // Invariant 2: live blocks = held + released-but-cached.
                let mut expect_live = 0usize;
                for (tokens, blocks, held) in &chains {
                    if *held || rx.match_prefix(tokens).hit_tokens == tokens.len() {
                        expect_live += blocks.len();
                    }
                }
                if pool.live_blocks() != expect_live {
                    return Err(format!(
                        "live {} != expected {expect_live}",
                        pool.live_blocks()
                    ));
                }
                if rx.cached_blocks > pool.live_blocks() {
                    return Err("index caches more blocks than are live".into());
                }
            }
            // Drain: release everything and evict to empty.
            for (_, blocks, held) in &mut chains {
                if *held {
                    for &b in blocks.iter() {
                        pool.release(b);
                    }
                    *held = false;
                }
            }
            rx.evict_lru(&mut pool, cap + 1);
            if pool.live_blocks() != 0 || rx.cached_blocks != 0 {
                return Err(format!(
                    "drain leaked: {} live, {} cached",
                    pool.live_blocks(),
                    rx.cached_blocks
                ));
            }
            // Free-list accounting: exactly `cap` allocations fit, the next
            // fails — free count equaled capacity minus live throughout.
            let all: Vec<BlockId> = (0..cap).map(|_| pool.alloc().unwrap()).collect();
            if pool.alloc().is_ok() {
                return Err("pool allocated beyond its cap".into());
            }
            for b in all {
                pool.release(b);
            }
            Ok(())
        });
    }

    #[test]
    fn clear_releases_everything() {
        let mut pool = mk_pool();
        let mut rx = RadixIndex::new(BT);
        let (ta, ba) = chain(&mut pool, 0, 3);
        rx.insert(&ta, &ba, &mut pool);
        release_all(&mut pool, &ba);
        rx.clear(&mut pool);
        assert_eq!(pool.live_blocks(), 0);
        assert_eq!(rx.node_count(), 1, "root remains");
        assert_eq!(rx.match_prefix(&ta).hit_tokens, 0);
    }
}

//! Fixed-size packed-code blocks: the allocation unit of the paged cache.
//!
//! A block holds up to `block_tokens` fixed-width token records of
//! `bytes_per_token` packed-code bytes each (the CQ bit-stream record the
//! flat cache used to append to one big `Vec<u8>`).  Blocks are ref-counted
//! by the [`super::pool::BlockPool`]: one reference per sequence chain that
//! includes the block plus one for the radix index while the block backs a
//! cached prefix.

/// Index of a block inside its pool's slab.
pub type BlockId = usize;

/// Shape of every block in one pool.
#[derive(Clone, Copy, Debug)]
pub struct BlockConfig {
    /// Tokens per block (the paging granularity).
    pub block_tokens: usize,
    /// Packed bytes per token record (`CacheGeom::bytes_per_token()`).
    pub bytes_per_token: usize,
}

impl BlockConfig {
    pub fn new(block_tokens: usize, bytes_per_token: usize) -> BlockConfig {
        assert!(block_tokens > 0, "block must hold at least one token");
        assert!(bytes_per_token > 0, "token record cannot be empty");
        BlockConfig { block_tokens, bytes_per_token }
    }

    /// Full-block footprint in bytes.
    pub fn block_bytes(&self) -> usize {
        self.block_tokens * self.bytes_per_token
    }

    /// Blocks needed to hold `tokens` token records.
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }
}

/// One slab entry: storage + token fill level + reference count.
#[derive(Default)]
pub(crate) struct Block {
    pub(crate) data: Vec<u8>,
    /// Token records currently written.
    pub(crate) len: usize,
    /// 0 = on the free list.
    pub(crate) refs: usize,
}

impl Block {
    pub(crate) fn is_full(&self, cfg: &BlockConfig) -> bool {
        self.len >= cfg.block_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_geometry() {
        let cfg = BlockConfig::new(16, 12);
        assert_eq!(cfg.block_bytes(), 192);
        assert_eq!(cfg.blocks_for_tokens(0), 0);
        assert_eq!(cfg.blocks_for_tokens(1), 1);
        assert_eq!(cfg.blocks_for_tokens(16), 1);
        assert_eq!(cfg.blocks_for_tokens(17), 2);
    }
}

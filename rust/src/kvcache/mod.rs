//! Quantized KV-cache: paged block-pool storage + staging tensors.
//!
//! Two representations coexist (DESIGN.md §3.3):
//!
//! * **Paged packed blocks** ([`paged`]) — the durable store: codes at
//!   their true bit width (1 bit/FPN for CQ-8c8b) in fixed-size ref-counted
//!   blocks drawn from a per-shard slab [`BlockPool`].  A [`RadixIndex`]
//!   maps token-id prefixes to frozen block chains, so requests sharing a
//!   system prompt attach to already-quantized blocks and skip the
//!   quantize+store pass for the matched span (the prefill artifact still
//!   runs over the whole prompt — skipping its compute for hit spans is an
//!   open follow-up); cold cached prefixes are evicted LRU when admission
//!   would otherwise exceed the block budget.  Each sequence is a
//!   [`PagedSeqCache`]: shared prefix blocks + private tail.
//! * **Staging tensors** ([`BatchStage`]) — the `i32` code tensors the PJRT
//!   decode artifact consumes, one slot per batch lane, updated in place so
//!   the hot loop never re-packs.
//!
//! [`CacheManager`] accounts the shard budget in **blocks** (reservations
//! by active sequences + blocks cached by the radix index); the pool's
//! allocator enforces the same cap as a hard ceiling.  The serve-throughput
//! bench and the von-Neumann traffic model read this accounting.
//!
//! Batch-kernel dataflow (PR 3 — the hot path end to end):
//!
//! ```text
//! prefill acts ──CqCodebooks::encode_span_parallel──▶ token-major codes
//!   (per-layer threads, book-major centroid scan,     [span, L*H*G] × 2
//!    ‖c‖² precomputed once per codebook)
//!           ──PagedSeqCache::append_span──▶ packed records (word-level
//!                                           pack_into, reused scratch)
//!           ──BlockPool blocks──▶ durable store
//! reload:   ──PagedSeqCache::read_span_into──▶ whole-block bulk unpack
//!           ──BatchStage::load_sequence──▶ staging tensors via
//!                                          precomputed (l,h) strides
//! ```

use anyhow::{bail, Result};

use crate::quant::pack::packed_len;
use crate::tensor::TensorI;

pub mod paged;

pub use paged::{
    Admission, BlockConfig, BlockId, BlockPool, PagedSeqCache, PagedShard, RadixIndex,
    DEFAULT_BLOCK_TOKENS,
};

/// Geometry of one model's quantized cache.
#[derive(Clone, Copy, Debug)]
pub struct CacheGeom {
    pub n_layers: usize,
    pub n_heads: usize,
    pub groups: usize,
    pub bits: u32,
    pub tmax: usize,
}

impl CacheGeom {
    /// Codes per token (both K and V, all layers/heads).
    pub fn codes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.groups
    }

    /// Packed bytes per token.
    pub fn bytes_per_token(&self) -> usize {
        packed_len(self.codes_per_token(), self.bits)
    }

    /// FP16 bytes per token for the same geometry (the paper's baseline).
    pub fn fp16_bytes_per_token(&self, head_dim: usize) -> usize {
        2 * self.n_layers * self.n_heads * head_dim * 2
    }
}

/// Staging tensors for one decode batch: `[L, B, H, Tmax, G]` i32 for keys
/// and values, plus per-slot positions.  Lanes map 1:1 to sequences.
pub struct BatchStage {
    pub geom: CacheGeom,
    pub batch: usize,
    pub k_codes: TensorI,
    pub v_codes: TensorI,
    pub pos: Vec<i32>,
    pub occupied: Vec<bool>,
    /// Reusable bulk-readout buffer: sequence reloads unpack into this, so
    /// a warm stage admits without touching the allocator.
    scratch: Vec<u32>,
}

impl BatchStage {
    pub fn new(geom: CacheGeom, batch: usize) -> BatchStage {
        let shape = [geom.n_layers, batch, geom.n_heads, geom.tmax, geom.groups];
        BatchStage {
            geom,
            batch,
            k_codes: TensorI::zeros(&shape),
            v_codes: TensorI::zeros(&shape),
            pos: vec![0; batch],
            occupied: vec![false; batch],
            scratch: Vec::new(),
        }
    }

    fn off(&self, l: usize, slot: usize, h: usize, t: usize) -> usize {
        (((l * self.batch + slot) * self.geom.n_heads + h) * self.geom.tmax + t)
            * self.geom.groups
    }

    /// Write one token's codes (`[L,H,G]` per side) at position `t` of `slot`.
    pub fn write_token(&mut self, slot: usize, t: usize, k: &[u32], v: &[u32]) {
        let g = self.geom.groups;
        let mut i = 0;
        for l in 0..self.geom.n_layers {
            for h in 0..self.geom.n_heads {
                let off = self.off(l, slot, h, t);
                for gi in 0..g {
                    self.k_codes.data[off + gi] = k[i] as i32;
                    self.v_codes.data[off + gi] = v[i] as i32;
                    i += 1;
                }
            }
        }
    }

    /// Load a whole paged sequence into `slot` (prefill admission): shared
    /// prefix blocks and private tail alike are read through the pool, a
    /// whole block of records per unpack call
    /// ([`PagedSeqCache::read_span_into`]), then scattered into the staging
    /// tensors with per-(layer, head) strides computed once — not re-derived
    /// per (l, h, t) as the old per-token path did.  `pos` is left at the
    /// sequence length — the next write position the decode step appends at.
    pub fn load_sequence(&mut self, slot: usize, seq: &PagedSeqCache, pool: &BlockPool) {
        assert!(seq.len <= self.geom.tmax);
        let g = self.geom.groups;
        let (l_n, h_n, tmax) = (self.geom.n_layers, self.geom.n_heads, self.geom.tmax);
        let per_side = l_n * h_n * g;
        let cpt = 2 * per_side;
        let n = seq.len;
        if n > 0 {
            if self.scratch.len() < n * cpt {
                self.scratch.resize(n * cpt, 0);
            }
            seq.read_span_into(pool, 0, n, &mut self.scratch[..n * cpt]);
            for l in 0..l_n {
                for h in 0..h_n {
                    // Stage offset of (l, slot, h, t=0, g=0); tokens advance
                    // by `g`, record source by `cpt`.
                    let base = (((l * self.batch + slot) * h_n + h) * tmax) * g;
                    let src_lh = (l * h_n + h) * g;
                    for t in 0..n {
                        let rec = t * cpt + src_lh;
                        let dst = base + t * g;
                        for gi in 0..g {
                            self.k_codes.data[dst + gi] = self.scratch[rec + gi] as i32;
                            self.v_codes.data[dst + gi] =
                                self.scratch[rec + per_side + gi] as i32;
                        }
                    }
                }
            }
        }
        self.pos[slot] = seq.len as i32;
        self.occupied[slot] = true;
    }

    /// Occupy `slot` for a sequence whose codes are not pool-backed (an
    /// fp16-policy tenant on the sim backend): position and occupancy only,
    /// no staged codes.
    pub fn mark_occupied(&mut self, slot: usize, len: usize) {
        assert!(len <= self.geom.tmax);
        self.pos[slot] = len as i32;
        self.occupied[slot] = true;
    }

    /// Release a slot (sequence finished).
    pub fn release(&mut self, slot: usize) {
        self.occupied[slot] = false;
        self.pos[slot] = 0;
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.occupied.iter().position(|&o| !o)
    }
}

/// Shard cache accounting, in **blocks** (the pool's allocation unit).
///
/// Two components add up against the budget: `blocks_in_use` — admission
/// reservations held by active sequences — and `cached_blocks` — blocks the
/// radix index keeps resident for prefix reuse.  Cached blocks are the
/// reclaimable part: when a reservation would overflow, the shard evicts
/// cold prefixes (`RadixIndex::evict_lru`) to cover the
/// [`CacheManager::shortfall`].
#[derive(Default)]
pub struct CacheManager {
    /// Reservations held by active sequences.
    pub blocks_in_use: usize,
    /// Blocks resident for prefix reuse (radix index references).
    pub cached_blocks: usize,
    pub budget_blocks: Option<usize>,
    pub peak_blocks: usize,
}

impl CacheManager {
    pub fn with_budget(budget_blocks: usize) -> CacheManager {
        CacheManager { budget_blocks: Some(budget_blocks), ..Default::default() }
    }

    /// Everything counted against the budget.
    pub fn total_blocks(&self) -> usize {
        self.blocks_in_use + self.cached_blocks
    }

    /// Reserve blocks for a sequence; fails when over budget (the router
    /// turns this into backpressure, the shard into eviction).
    pub fn reserve(&mut self, blocks: usize) -> Result<()> {
        if let Some(b) = self.budget_blocks {
            if self.total_blocks() + blocks > b {
                bail!(
                    "cache budget exceeded: {} in use + {} cached + {blocks} > {b} blocks",
                    self.blocks_in_use,
                    self.cached_blocks
                );
            }
        }
        self.blocks_in_use += blocks;
        self.peak_blocks = self.peak_blocks.max(self.total_blocks());
        Ok(())
    }

    pub fn release(&mut self, blocks: usize) {
        self.blocks_in_use = self.blocks_in_use.saturating_sub(blocks);
    }

    /// Blocks that must be evicted for `reserve(blocks)` to succeed.
    pub fn shortfall(&self, blocks: usize) -> usize {
        match self.budget_blocks {
            Some(b) => (self.total_blocks() + blocks).saturating_sub(b),
            None => 0,
        }
    }

    /// A completed sequence promoted `blocks` into the radix index: they
    /// stay resident, accounted as reclaimable cache.
    pub fn note_cached(&mut self, blocks: usize) {
        self.cached_blocks += blocks;
        self.peak_blocks = self.peak_blocks.max(self.total_blocks());
    }

    /// Eviction returned `blocks` to the free pool.
    pub fn note_evicted(&mut self, blocks: usize) {
        self.cached_blocks = self.cached_blocks.saturating_sub(blocks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    fn geom() -> CacheGeom {
        CacheGeom { n_layers: 2, n_heads: 2, groups: 4, bits: 3, tmax: 8 }
    }

    fn mk_pool(g: &CacheGeom) -> BlockPool {
        BlockPool::new(BlockConfig::new(4, g.bytes_per_token()), None)
    }

    #[test]
    fn bytes_per_token_is_exact() {
        let g = geom();
        // 2*2*2*4 = 32 codes * 3 bits = 96 bits = 12 bytes.
        assert_eq!(g.codes_per_token(), 32);
        assert_eq!(g.bytes_per_token(), 12);
        // 1-bit CQ-8c8b example from the paper: hd=64 -> G=8, bits=8:
        let g1 = CacheGeom { n_layers: 4, n_heads: 4, groups: 8, bits: 8, tmax: 512 };
        let fp16 = g1.fp16_bytes_per_token(64);
        assert_eq!(fp16 / g1.bytes_per_token(), 16, "16x compression at 1 bit/FPN");
    }

    #[test]
    fn stage_roundtrips_through_sequence_load() {
        let g = geom();
        let mut pool = mk_pool(&g);
        let mut seq = PagedSeqCache::new(g);
        let per = 16;
        for t in 0..4 {
            let k: Vec<u32> = (0..per).map(|i| ((7 * t + i) % 8) as u32).collect();
            seq.append(&mut pool, &k, &k).unwrap();
        }
        let mut stage = BatchStage::new(g, 2);
        stage.load_sequence(1, &seq, &pool);
        assert_eq!(stage.pos[1], 4, "pos = next write position");
        assert!(stage.occupied[1]);
        // Spot-check a code: token 2, layer 1, head 0, group 3.
        let (k2, _) = seq.token(&pool, 2);
        let idx = stage.off(1, 1, 0, 2) + 3;
        assert_eq!(stage.k_codes.data[idx], k2[11] as i32); // [l=1,h=0,g=3]
        stage.release(1);
        assert_eq!(stage.free_slot(), Some(0));
        seq.release(&mut pool);
    }

    #[test]
    fn prop_bulk_load_matches_per_token_staging() {
        // load_sequence (bulk span readout + strided scatter) must leave the
        // staging tensors exactly as the old per-token token()+write_token
        // loop did, across random geometries and block sizes.
        run_prop(15, 83, |rng| {
            let g = CacheGeom {
                n_layers: 1 + rng.below(3),
                n_heads: 1 + rng.below(3),
                groups: 1 + rng.below(6),
                bits: 1 + rng.below(10) as u32,
                tmax: 24,
            };
            let block_tokens = 1 + rng.below(5);
            let mut pool =
                BlockPool::new(BlockConfig::new(block_tokens, g.bytes_per_token()), None);
            let per = g.n_layers * g.n_heads * g.groups;
            let maxc = 1usize << g.bits;
            let mut seq = PagedSeqCache::new(g);
            let n_tok = 1 + rng.below(g.tmax);
            for _ in 0..n_tok {
                let k: Vec<u32> = (0..per).map(|_| rng.below(maxc) as u32).collect();
                let v: Vec<u32> = (0..per).map(|_| rng.below(maxc) as u32).collect();
                seq.append(&mut pool, &k, &v).map_err(|e| e.to_string())?;
            }
            let batch = 1 + rng.below(3);
            let slot = rng.below(batch);
            let mut bulk = BatchStage::new(g, batch);
            bulk.load_sequence(slot, &seq, &pool);
            let mut reference = BatchStage::new(g, batch);
            for t in 0..seq.len {
                let (k, v) = seq.token(&pool, t);
                reference.write_token(slot, t, &k, &v);
            }
            if bulk.k_codes.data != reference.k_codes.data {
                return Err("k staging diverged from per-token path".into());
            }
            if bulk.v_codes.data != reference.v_codes.data {
                return Err("v staging diverged from per-token path".into());
            }
            if bulk.pos[slot] != seq.len as i32 || !bulk.occupied[slot] {
                return Err("pos/occupied not set".into());
            }
            seq.release(&mut pool);
            Ok(())
        });
    }

    #[test]
    fn manager_budget_backpressure() {
        let mut m = CacheManager::with_budget(10);
        m.reserve(6).unwrap();
        assert!(m.reserve(5).is_err());
        m.release(3);
        m.reserve(5).unwrap();
        assert_eq!(m.blocks_in_use, 8);
        assert_eq!(m.peak_blocks, 8);
    }

    #[test]
    fn manager_counts_cached_blocks_against_budget() {
        let mut m = CacheManager::with_budget(10);
        m.reserve(4).unwrap();
        // Sequence completes: 3 of its blocks stay cached in the index.
        m.release(4);
        m.note_cached(3);
        assert_eq!(m.total_blocks(), 3);
        m.reserve(7).unwrap();
        let err = m.reserve(1).unwrap_err();
        assert!(err.to_string().contains("cached"), "{err}");
        assert_eq!(m.shortfall(1), 1, "one eviction covers it");
        m.note_evicted(2);
        m.reserve(1).unwrap();
        assert_eq!(m.total_blocks(), 9);
        assert_eq!(m.peak_blocks, 10);
    }

    #[test]
    fn budget_exhaustion_error_path_and_recovery() {
        let mut m = CacheManager::with_budget(100);
        m.reserve(60).unwrap();
        m.reserve(40).unwrap();
        // Exactly full: the next block must be refused with a budget error.
        let err = m.reserve(1).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        // A failed reserve must not corrupt accounting.
        assert_eq!(m.blocks_in_use, 100);
        assert_eq!(m.peak_blocks, 100);
        // Releasing makes room again; peak is sticky.
        m.release(50);
        m.reserve(30).unwrap();
        assert_eq!(m.blocks_in_use, 80);
        assert_eq!(m.peak_blocks, 100);
        // Unbudgeted manager never refuses.
        let mut free = CacheManager::default();
        free.reserve(usize::MAX / 2).unwrap();
        assert_eq!(free.shortfall(usize::MAX / 4), 0);
    }

    #[test]
    fn compression_ratio_table_matches_paper() {
        // hd=64 head: CQ-<c>c8b has G = 64/c groups at 8 bits each, so
        // bits/FPN = 8/c and the fp16 ratio is 2c. Paper headline: 8c8b
        // (1 bit per channel) compresses 16x.
        for (groups, want) in [(8usize, 16usize), (16, 8), (32, 4)] {
            let g = CacheGeom { n_layers: 4, n_heads: 4, groups, bits: 8, tmax: 512 };
            assert_eq!(
                g.fp16_bytes_per_token(64) / g.bytes_per_token(),
                want,
                "G={groups}"
            );
        }
        // fp16 geometry (1 channel per group, 16 bits) is the identity.
        let fp = CacheGeom { n_layers: 4, n_heads: 4, groups: 64, bits: 16, tmax: 512 };
        assert_eq!(fp.fp16_bytes_per_token(64), fp.bytes_per_token());
    }

    #[test]
    fn unstored_fp_cache_accounts_without_storing() {
        let g = geom();
        let mut c = PagedSeqCache::new_unstored(g);
        for _ in 0..g.tmax {
            c.append_unstored().unwrap();
        }
        assert!(c.append_unstored().is_err(), "tmax enforced in fp mode too");
        assert_eq!(c.logical_bytes(), g.tmax * g.bytes_per_token());
    }

    #[test]
    fn prop_paged_roundtrip_random_geometry() {
        run_prop(20, 21, |rng| {
            let g = CacheGeom {
                n_layers: 1 + rng.below(3),
                n_heads: 1 + rng.below(3),
                groups: 1 + rng.below(8),
                bits: 1 + rng.below(10) as u32,
                tmax: 16,
            };
            let block_tokens = 1 + rng.below(5);
            let mut pool =
                BlockPool::new(BlockConfig::new(block_tokens, g.bytes_per_token()), None);
            let per = g.n_layers * g.n_heads * g.groups;
            let maxc = 1u32 << g.bits;
            let mut c = PagedSeqCache::new(g);
            let mut expect = Vec::new();
            let n_tok = 3 + rng.below(10);
            for _ in 0..n_tok {
                let k: Vec<u32> = (0..per).map(|_| rng.below(maxc as usize) as u32).collect();
                let v: Vec<u32> = (0..per).map(|_| rng.below(maxc as usize) as u32).collect();
                c.append(&mut pool, &k, &v).map_err(|e| e.to_string())?;
                expect.push((k, v));
            }
            if pool.live_blocks() != n_tok.div_ceil(block_tokens) {
                return Err(format!(
                    "{} blocks for {n_tok} tokens at {block_tokens}/block",
                    pool.live_blocks()
                ));
            }
            for (t, (k, v)) in expect.iter().enumerate() {
                let (k2, v2) = c.token(&pool, t);
                if &k2 != k || &v2 != v {
                    return Err(format!("token {t} mismatch"));
                }
            }
            c.release(&mut pool);
            if pool.live_blocks() != 0 {
                return Err("release leaked blocks".into());
            }
            Ok(())
        });
    }
}

//! Quantized KV-cache manager.
//!
//! Two representations coexist (DESIGN.md §3.3):
//!
//! * **Packed pages** ([`PackedSeqCache`]) — the durable, per-sequence store:
//!   codes at their true bit width (1 bit/FPN for CQ-8c8b), allocated in
//!   fixed-size pages.  This is the unit of memory accounting and the thing
//!   the paper shrinks 16×.
//! * **Staging tensors** ([`BatchStage`]) — the `i32` code tensors the PJRT
//!   decode artifact consumes, one slot per batch lane, updated in place so
//!   the hot loop never re-packs.
//!
//! `CacheManager` tracks a global byte budget and exposes the accounting
//! used by the serve-throughput bench and the von-Neumann traffic model.

use anyhow::{bail, Result};

use crate::quant::pack::{pack_codes, packed_len, unpack_codes};
use crate::tensor::{TensorF, TensorI};

/// Geometry of one model's quantized cache.
#[derive(Clone, Copy, Debug)]
pub struct CacheGeom {
    pub n_layers: usize,
    pub n_heads: usize,
    pub groups: usize,
    pub bits: u32,
    pub tmax: usize,
}

impl CacheGeom {
    /// Codes per token (both K and V, all layers/heads).
    pub fn codes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.groups
    }

    /// Packed bytes per token.
    pub fn bytes_per_token(&self) -> usize {
        packed_len(self.codes_per_token(), self.bits)
    }

    /// FP16 bytes per token for the same geometry (the paper's baseline).
    pub fn fp16_bytes_per_token(&self, head_dim: usize) -> usize {
        2 * self.n_layers * self.n_heads * head_dim * 2
    }
}

/// Packed per-sequence cache: one bit-stream page list per (layer, kv, head).
/// Codes are appended token-at-a-time in [k, v] × layer × head order.
pub struct PackedSeqCache {
    pub geom: CacheGeom,
    pub len: usize,
    /// Packed code stream; tokens are appended as fixed-width records of
    /// `codes_per_token` codes, so random access by token index is O(1).
    data: Vec<u8>,
    scratch: Vec<u32>,
    /// `false` for fp-cache sequences: length/byte accounting only, the
    /// actual floats live in the serve loop's staging tensors.
    stored: bool,
    /// fp-mode only: prefill K/V (`[L,1,H,T,hd]`) held until the sequence is
    /// admitted into a staging lane, then dropped.
    pub fp_seed: Option<(TensorF, TensorF)>,
}

impl PackedSeqCache {
    pub fn new(geom: CacheGeom) -> PackedSeqCache {
        PackedSeqCache { geom, len: 0, data: Vec::new(), scratch: Vec::new(), stored: true, fp_seed: None }
    }

    /// Accounting-only cache (fp16 serving baseline): tracks length and
    /// logical bytes without storing codes.
    pub fn new_unstored(geom: CacheGeom) -> PackedSeqCache {
        PackedSeqCache { geom, len: 0, data: Vec::new(), scratch: Vec::new(), stored: false, fp_seed: None }
    }

    /// Bump the token count without storing codes (unstored mode).
    pub fn append_unstored(&mut self) -> Result<()> {
        if self.len >= self.geom.tmax {
            bail!("cache full ({} tokens)", self.geom.tmax);
        }
        self.len += 1;
        Ok(())
    }

    /// Logical footprint: what this sequence occupies at the configured bit
    /// width, independent of storage mode (fp16 geometry uses bits=16).
    pub fn logical_bytes(&self) -> usize {
        self.len * self.geom.bytes_per_token()
    }

    /// Append one token's codes: `k_codes`/`v_codes` laid out `[L, H, G]`.
    pub fn append(&mut self, k_codes: &[u32], v_codes: &[u32]) -> Result<()> {
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        if k_codes.len() != per_side || v_codes.len() != per_side {
            bail!(
                "append: want {per_side} codes per side, got {}/{}",
                k_codes.len(),
                v_codes.len()
            );
        }
        if self.len >= self.geom.tmax {
            bail!("cache full ({} tokens)", self.geom.tmax);
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(k_codes);
        self.scratch.extend_from_slice(v_codes);
        self.data.extend_from_slice(&pack_codes(&self.scratch, self.geom.bits));
        self.len += 1;
        Ok(())
    }

    /// Read one token's codes back as (k `[L,H,G]`, v `[L,H,G]`).
    pub fn token(&self, t: usize) -> (Vec<u32>, Vec<u32>) {
        assert!(self.stored, "unstored (fp) cache holds no codes");
        assert!(t < self.len);
        let per_tok = self.geom.bytes_per_token();
        let per_side = self.geom.n_layers * self.geom.n_heads * self.geom.groups;
        let rec = &self.data[t * per_tok..(t + 1) * per_tok];
        let all = unpack_codes(rec, self.geom.bits, 2 * per_side);
        (all[..per_side].to_vec(), all[per_side..].to_vec())
    }

    /// Exact packed footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Staging tensors for one decode batch: `[L, B, H, Tmax, G]` i32 for keys
/// and values, plus per-slot positions.  Lanes map 1:1 to sequences.
pub struct BatchStage {
    pub geom: CacheGeom,
    pub batch: usize,
    pub k_codes: TensorI,
    pub v_codes: TensorI,
    pub pos: Vec<i32>,
    pub occupied: Vec<bool>,
}

impl BatchStage {
    pub fn new(geom: CacheGeom, batch: usize) -> BatchStage {
        let shape = [geom.n_layers, batch, geom.n_heads, geom.tmax, geom.groups];
        BatchStage {
            geom,
            batch,
            k_codes: TensorI::zeros(&shape),
            v_codes: TensorI::zeros(&shape),
            pos: vec![0; batch],
            occupied: vec![false; batch],
        }
    }

    fn off(&self, l: usize, slot: usize, h: usize, t: usize) -> usize {
        (((l * self.batch + slot) * self.geom.n_heads + h) * self.geom.tmax + t)
            * self.geom.groups
    }

    /// Write one token's codes (`[L,H,G]` per side) at position `t` of `slot`.
    pub fn write_token(&mut self, slot: usize, t: usize, k: &[u32], v: &[u32]) {
        let g = self.geom.groups;
        let mut i = 0;
        for l in 0..self.geom.n_layers {
            for h in 0..self.geom.n_heads {
                let off = self.off(l, slot, h, t);
                for gi in 0..g {
                    self.k_codes.data[off + gi] = k[i] as i32;
                    self.v_codes.data[off + gi] = v[i] as i32;
                    i += 1;
                }
            }
        }
    }

    /// Load a whole packed sequence into `slot` (prefill admission).
    pub fn load_sequence(&mut self, slot: usize, seq: &PackedSeqCache) {
        assert!(seq.len <= self.geom.tmax);
        for t in 0..seq.len {
            let (k, v) = seq.token(t);
            self.write_token(slot, t, &k, &v);
        }
        self.pos[slot] = seq.len.saturating_sub(1) as i32;
        self.occupied[slot] = true;
    }

    /// Release a slot (sequence finished).
    pub fn release(&mut self, slot: usize) {
        self.occupied[slot] = false;
        self.pos[slot] = 0;
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.occupied.iter().position(|&o| !o)
    }
}

/// Global cache accounting across sequences.
#[derive(Default)]
pub struct CacheManager {
    pub bytes_in_use: usize,
    pub budget: Option<usize>,
    pub peak: usize,
}

impl CacheManager {
    pub fn with_budget(budget: usize) -> CacheManager {
        CacheManager { budget: Some(budget), ..Default::default() }
    }

    /// Reserve bytes for a sequence; fails when over budget (the router
    /// turns this into backpressure).
    pub fn reserve(&mut self, bytes: usize) -> Result<()> {
        if let Some(b) = self.budget {
            if self.bytes_in_use + bytes > b {
                bail!(
                    "cache budget exceeded: {} + {bytes} > {b}",
                    self.bytes_in_use
                );
            }
        }
        self.bytes_in_use += bytes;
        self.peak = self.peak.max(self.bytes_in_use);
        Ok(())
    }

    pub fn release(&mut self, bytes: usize) {
        self.bytes_in_use = self.bytes_in_use.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    fn geom() -> CacheGeom {
        CacheGeom { n_layers: 2, n_heads: 2, groups: 4, bits: 3, tmax: 8 }
    }

    #[test]
    fn bytes_per_token_is_exact() {
        let g = geom();
        // 2*2*2*4 = 32 codes * 3 bits = 96 bits = 12 bytes.
        assert_eq!(g.codes_per_token(), 32);
        assert_eq!(g.bytes_per_token(), 12);
        // 1-bit CQ-8c8b example from the paper: hd=64 -> G=8, bits=8:
        let g1 = CacheGeom { n_layers: 4, n_heads: 4, groups: 8, bits: 8, tmax: 512 };
        let fp16 = g1.fp16_bytes_per_token(64);
        assert_eq!(fp16 / g1.bytes_per_token(), 16, "16x compression at 1 bit/FPN");
    }

    #[test]
    fn append_and_read_roundtrip() {
        let mut c = PackedSeqCache::new(geom());
        let per = 2 * 2 * 4;
        for t in 0..5 {
            let k: Vec<u32> = (0..per).map(|i| ((t + i) % 8) as u32).collect();
            let v: Vec<u32> = (0..per).map(|i| ((t * 3 + i) % 8) as u32).collect();
            c.append(&k, &v).unwrap();
        }
        assert_eq!(c.len, 5);
        let (k2, v2) = c.token(3);
        assert_eq!(k2, (0..per).map(|i| ((3 + i) % 8) as u32).collect::<Vec<_>>());
        assert_eq!(v2, (0..per).map(|i| ((9 + i) % 8) as u32).collect::<Vec<_>>());
        assert_eq!(c.bytes(), 5 * c.geom.bytes_per_token());
    }

    #[test]
    fn cache_capacity_enforced() {
        let mut c = PackedSeqCache::new(geom());
        let per = 16;
        for _ in 0..8 {
            c.append(&vec![0; per], &vec![0; per]).unwrap();
        }
        assert!(c.append(&vec![0; per], &vec![0; per]).is_err());
    }

    #[test]
    fn stage_roundtrips_through_sequence_load() {
        let g = geom();
        let mut seq = PackedSeqCache::new(g);
        let per = 16;
        for t in 0..4 {
            let k: Vec<u32> = (0..per).map(|i| ((7 * t + i) % 8) as u32).collect();
            seq.append(&k, &k).unwrap();
        }
        let mut stage = BatchStage::new(g, 2);
        stage.load_sequence(1, &seq);
        assert_eq!(stage.pos[1], 3);
        assert!(stage.occupied[1]);
        // Spot-check a code: token 2, layer 1, head 0, group 3.
        let (k2, _) = seq.token(2);
        let idx = stage.off(1, 1, 0, 2) + 3;
        assert_eq!(stage.k_codes.data[idx], k2[(1 * 2 + 0) * 4 + 3] as i32);
        stage.release(1);
        assert_eq!(stage.free_slot(), Some(0));
    }

    #[test]
    fn manager_budget_backpressure() {
        let mut m = CacheManager::with_budget(100);
        m.reserve(60).unwrap();
        assert!(m.reserve(50).is_err());
        m.release(30);
        m.reserve(50).unwrap();
        assert_eq!(m.bytes_in_use, 80);
        assert_eq!(m.peak, 80);
    }

    #[test]
    fn compression_ratio_table_matches_paper() {
        // hd=64 head: CQ-<c>c8b has G = 64/c groups at 8 bits each, so
        // bits/FPN = 8/c and the fp16 ratio is 2c. Paper headline: 8c8b
        // (1 bit per channel) compresses 16x.
        for (groups, want) in [(8usize, 16usize), (16, 8), (32, 4)] {
            let g = CacheGeom { n_layers: 4, n_heads: 4, groups, bits: 8, tmax: 512 };
            assert_eq!(
                g.fp16_bytes_per_token(64) / g.bytes_per_token(),
                want,
                "G={groups}"
            );
        }
        // fp16 geometry (1 channel per group, 16 bits) is the identity.
        let fp = CacheGeom { n_layers: 4, n_heads: 4, groups: 64, bits: 16, tmax: 512 };
        assert_eq!(fp.fp16_bytes_per_token(64), fp.bytes_per_token());
    }

    #[test]
    fn token_random_access_is_fixed_stride() {
        // Appends are O(1) amortized and token(t) reads a fixed-width record
        // at t * bytes_per_token, independent of cache length: storage must
        // grow exactly linearly and out-of-order reads must roundtrip.
        let g = geom();
        let per = g.n_layers * g.n_heads * g.groups;
        let mut c = PackedSeqCache::new(g);
        let tok = |t: usize| -> Vec<u32> {
            (0..per).map(|i| ((5 * t + 3 * i) % 8) as u32).collect()
        };
        for t in 0..8 {
            let before = c.bytes();
            c.append(&tok(t), &tok(t + 1)).unwrap();
            assert_eq!(c.bytes() - before, g.bytes_per_token(), "linear growth");
        }
        for t in [7usize, 0, 4, 2, 6, 1, 5, 3] {
            let (k, v) = c.token(t);
            assert_eq!(k, tok(t), "token {t} keys");
            assert_eq!(v, tok(t + 1), "token {t} values");
        }
        assert_eq!(c.logical_bytes(), 8 * g.bytes_per_token());
    }

    #[test]
    fn budget_exhaustion_error_path_and_recovery() {
        let mut m = CacheManager::with_budget(1000);
        m.reserve(600).unwrap();
        m.reserve(400).unwrap();
        // Exactly full: the next byte must be refused with a budget error.
        let err = m.reserve(1).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        // A failed reserve must not corrupt accounting.
        assert_eq!(m.bytes_in_use, 1000);
        assert_eq!(m.peak, 1000);
        // Releasing makes room again; peak is sticky.
        m.release(500);
        m.reserve(300).unwrap();
        assert_eq!(m.bytes_in_use, 800);
        assert_eq!(m.peak, 1000);
        // Unbudgeted manager never refuses.
        let mut free = CacheManager::default();
        free.reserve(usize::MAX / 2).unwrap();
    }

    #[test]
    fn unstored_fp_cache_accounts_without_storing() {
        let g = geom();
        let mut c = PackedSeqCache::new_unstored(g);
        for _ in 0..g.tmax {
            c.append_unstored().unwrap();
        }
        assert!(c.append_unstored().is_err(), "tmax enforced in fp mode too");
        assert_eq!(c.bytes(), 0, "fp mode stores no codes");
        assert_eq!(c.logical_bytes(), g.tmax * g.bytes_per_token());
    }

    #[test]
    fn prop_packed_roundtrip_random_geometry() {
        run_prop(20, 21, |rng| {
            let g = CacheGeom {
                n_layers: 1 + rng.below(3),
                n_heads: 1 + rng.below(3),
                groups: 1 + rng.below(8),
                bits: 1 + rng.below(10) as u32,
                tmax: 6,
            };
            let per = g.n_layers * g.n_heads * g.groups;
            let maxc = 1u32 << g.bits;
            let mut c = PackedSeqCache::new(g);
            let mut expect = Vec::new();
            for _ in 0..5 {
                let k: Vec<u32> = (0..per).map(|_| rng.below(maxc as usize) as u32).collect();
                let v: Vec<u32> = (0..per).map(|_| rng.below(maxc as usize) as u32).collect();
                c.append(&k, &v).map_err(|e| e.to_string())?;
                expect.push((k, v));
            }
            for (t, (k, v)) in expect.iter().enumerate() {
                let (k2, v2) = c.token(t);
                if &k2 != k || &v2 != v {
                    return Err(format!("token {t} mismatch"));
                }
            }
            Ok(())
        });
    }
}

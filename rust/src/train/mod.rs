//! Rust-driven training loop.
//!
//! The optimizer math lives in the AOT `train_step` artifact (Adam, fused by
//! XLA); this module owns the schedule, data feeding, logging and
//! checkpointing.  The loss curve it logs is the end-to-end evidence in
//! EXPERIMENTS.md that all three layers compose.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::{train_batch, Dataset};
use crate::runtime::{Engine, Value};
use crate::tensor::TensorF;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Training hyperparameters (the in-graph Adam betas/eps are fixed at
/// lowering time; these are the host-controlled knobs).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr_max: f64,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { steps: 400, lr_max: 3e-3, warmup: 40, seed: 7, log_every: 20 }
    }
}

/// Linear warmup then cosine decay to 10 % of peak.
pub fn lr_at(cfg: &TrainCfg, step: usize) -> f64 {
    if step < cfg.warmup {
        cfg.lr_max * (step + 1) as f64 / cfg.warmup as f64
    } else {
        let t = (step - cfg.warmup) as f64 / (cfg.steps - cfg.warmup).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        cfg.lr_max * (0.1 + 0.9 * cos)
    }
}

/// Result of a training run.
pub struct TrainResult {
    pub params: TensorF,
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub secs: f64,
}

/// Train `model` on `ds`, starting from `params0`.
pub fn train(
    engine: &Engine,
    model: &str,
    params0: TensorF,
    ds: &Dataset,
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    let art = format!("{model}.train_step");
    let spec = engine.manifest.artifact(&art)?.clone();
    let batch = spec.meta.num_or("batch", 8.0) as usize;
    let ctx = spec.meta.num_or("ctx", 65.0) as usize;
    let n = params0.numel();

    let mut params = params0;
    let mut m = TensorF::zeros(&[n]);
    let mut v = TensorF::zeros(&[n]);
    let mut rng = Pcg64::seed(cfg.seed);
    let mut losses = Vec::new();
    let t0 = std::time::Instant::now();
    let mut final_loss = f64::NAN;

    for step in 0..cfg.steps {
        let tokens = train_batch(ds, batch, ctx, &mut rng);
        let lr = lr_at(cfg, step);
        let out = engine.run(
            &art,
            &[
                Value::F(params),
                Value::F(m),
                Value::F(v),
                Value::scalar_f((step + 1) as f32),
                Value::scalar_f(lr as f32),
                Value::I(tokens),
            ],
        )?;
        let mut it = out.into_iter();
        params = it.next().context("params out")?.into_f()?;
        m = it.next().context("m out")?.into_f()?;
        v = it.next().context("v out")?.into_f()?;
        let loss = it.next().context("loss out")?.into_f()?.data[0] as f64;
        final_loss = loss;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log::info!("step {step:>5}  lr {lr:.2e}  loss {loss:.4}");
            println!("step {step:>5}  lr {lr:.2e}  loss {loss:.4}");
            losses.push((step, loss));
        }
    }

    Ok(TrainResult { params, losses, final_loss, secs: t0.elapsed().as_secs_f64() })
}

/// Save a checkpoint: `<dir>/params.bin` + `<dir>/ckpt.json`.
pub fn save_checkpoint(
    dir: &Path,
    model: &str,
    params: &TensorF,
    losses: &[(usize, f64)],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    params.write_f32_file(&dir.join("params.bin"))?;
    let meta = Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("param_count", Json::Num(params.numel() as f64)),
        (
            "loss_curve",
            Json::Arr(
                losses
                    .iter()
                    .map(|(s, l)| Json::Arr(vec![Json::Num(*s as f64), Json::Num(*l)]))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(dir.join("ckpt.json"), meta.dump())?;
    Ok(())
}

/// Load `<dir>/params.bin` for a model known to the manifest.
pub fn load_checkpoint(engine: &Engine, model: &str, dir: &Path) -> Result<TensorF> {
    let mm = engine.manifest.model(model)?;
    TensorF::read_f32_file(&dir.join("params.bin"), &[mm.param_count])
        .with_context(|| format!("checkpoint in {} (run `cq-serve train` first)", dir.display()))
}

/// Default checkpoint directory for a model.
pub fn ckpt_dir(model: &str) -> PathBuf {
    let mut d = crate::artifacts_dir();
    d.pop();
    d.join("runs").join(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainCfg { steps: 100, lr_max: 1.0, warmup: 10, ..Default::default() };
        assert!(lr_at(&cfg, 0) < 0.2);
        assert!((lr_at(&cfg, 9) - 1.0).abs() < 1e-9);
        assert!(lr_at(&cfg, 50) < 1.0);
        assert!(lr_at(&cfg, 99) >= 0.1 * 0.99);
        // Monotone decay after warmup.
        assert!(lr_at(&cfg, 30) > lr_at(&cfg, 60));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("cq_ckpt_test");
        let params = TensorF::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        save_checkpoint(&dir, "toy", &params, &[(0, 5.5), (10, 3.2)]).unwrap();
        let re = TensorF::read_f32_file(&dir.join("params.bin"), &[4]).unwrap();
        assert_eq!(re, params);
        let meta = std::fs::read_to_string(dir.join("ckpt.json")).unwrap();
        assert!(meta.contains("loss_curve"));
    }
}

//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the Rust hot path.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`.
//! * [`engine`]   — PJRT CPU client, executable registry (compile-on-first-
//!                  use, cached), literal marshalling for `TensorF`/`TensorI`.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Exe, Value};
pub use manifest::{ArgSpec, ArtifactSpec, DType, Manifest, ModelMeta};

//! PJRT execution engine.
//!
//! Loads HLO-text artifacts, compiles them on the PJRT CPU client
//! (compile-on-first-use, cached for the process lifetime) and executes them
//! with host tensors.  Inputs are validated against the manifest so a
//! shape/dtype mismatch fails loudly at the boundary instead of inside XLA.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};
use crate::tensor::{TensorF, TensorI};

/// A host value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F(TensorF),
    I(TensorI),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F(t) => &t.shape,
            Value::I(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F(_) => DType::F32,
            Value::I(_) => DType::I32,
        }
    }

    pub fn as_f(&self) -> Result<&TensorF> {
        match self {
            Value::F(t) => Ok(t),
            Value::I(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i(&self) -> Result<&TensorI> {
        match self {
            Value::I(t) => Ok(t),
            Value::F(_) => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f(self) -> Result<TensorF> {
        match self {
            Value::F(t) => Ok(t),
            Value::I(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_i(self) -> Result<TensorI> {
        match self {
            Value::I(t) => Ok(t),
            Value::F(_) => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Scalar f32 convenience constructor.
    pub fn scalar_f(x: f32) -> Value {
        Value::F(TensorF { shape: vec![], data: vec![x] })
    }

    /// Upload directly host->device without an intermediate literal copy.
    fn to_device(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        Ok(match self {
            Value::F(t) => client.buffer_from_host_buffer(&t.data, &t.shape, None)?,
            Value::I(t) => client.buffer_from_host_buffer(&t.data, &t.shape, None)?,
        })
    }

    fn from_literal(lit: &xla::Literal, spec: &ArgSpec2) -> Result<Value> {
        match spec.dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(Value::F(TensorF::from_vec(&spec.shape, data)?))
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I(TensorI::from_vec(&spec.shape, data)?))
            }
        }
    }
}

// Local alias to avoid pulling ArgSpec's name field through.
struct ArgSpec2 {
    dtype: DType,
    shape: Vec<usize>,
}

/// A compiled executable plus its manifest spec.
pub struct Exe {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// An argument to [`Exe::run_mixed`]: a host tensor (uploaded per call) or a
/// resident device buffer (uploaded once via [`Engine::upload`]) — the hot
/// path keeps the 13 MB parameter vector and the centroid tables resident.
pub enum Arg<'a> {
    V(&'a Value),
    B(&'a DevBuf),
}

/// A device-resident input (wraps a PJRT buffer plus its spec for
/// validation).
pub struct DevBuf {
    buf: xla::PjRtBuffer,
    dtype: DType,
    shape: Vec<usize>,
}

impl Exe {
    /// Execute with host values; validates shapes/dtypes against the spec.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let args: Vec<Arg> = inputs.iter().map(Arg::V).collect();
        self.run_mixed(&args)
    }

    /// Execute with a mix of host values and resident device buffers.
    pub fn run_mixed(&self, inputs: &[Arg]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, s) in inputs.iter().zip(&self.spec.inputs) {
            let (dt, shape): (DType, &[usize]) = match v {
                Arg::V(v) => (v.dtype(), v.shape()),
                Arg::B(b) => (b.dtype, &b.shape),
            };
            if dt != s.dtype || shape != s.shape.as_slice() {
                bail!(
                    "{}: input '{}' wants {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    dt,
                    shape
                );
            }
        }
        // Upload host args; borrow resident ones.
        let client = self.exe.client().clone();
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        for a in inputs {
            if let Arg::V(v) = a {
                uploaded.push(v.to_device(&client)?);
            }
        }
        let mut it = uploaded.iter();
        let bufs_in: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .map(|a| match a {
                Arg::V(_) => it.next().unwrap(),
                Arg::B(b) => &b.buf,
            })
            .collect();
        let bufs = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs_in)?;
        let out_lit = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is an N-tuple.
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, s)| {
                Value::from_literal(
                    lit,
                    &ArgSpec2 { dtype: s.dtype, shape: s.shape.clone() },
                )
            })
            .collect()
    }
}

/// The PJRT engine: client + manifest + executable cache.
///
/// PJRT handles are not `Send`/`Sync`; the engine lives on one thread (the
/// coordinator's engine loop) and other threads talk to it via channels.
pub struct Engine {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Engine {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(dir: PathBuf) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: {} ({} devices), {} artifacts",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(crate::artifacts_dir())
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Rc<Exe>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Rc::new(Exe { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Convenience: run an artifact by name.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.executable(name)?.run(inputs)
    }

    /// Upload a host tensor once; reuse across calls via [`Arg::B`].
    pub fn upload(&self, v: &Value) -> Result<DevBuf> {
        Ok(DevBuf {
            buf: v.to_device(&self.client)?,
            dtype: v.dtype(),
            shape: v.shape().to_vec(),
        })
    }

    /// Read the initial parameter vector for a model.
    pub fn init_params(&self, model: &str) -> Result<TensorF> {
        let mm = self.manifest.model(model)?;
        TensorF::read_f32_file(&self.dir.join(&mm.init_file), &[mm.param_count])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let f = Value::F(TensorF::zeros(&[2, 2]));
        let i = Value::I(TensorI::zeros(&[3]));
        assert_eq!(f.dtype(), DType::F32);
        assert_eq!(i.shape(), &[3]);
        assert!(f.as_f().is_ok());
        assert!(f.as_i().is_err());
        assert!(i.as_i().is_ok());
        let s = Value::scalar_f(2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    // Engine execution is covered by rust/tests/runtime_smoke.rs, which
    // requires built artifacts.
}

//! Typed view of `artifacts/manifest.json` (written by the AOT pipeline).
//!
//! The manifest is the single source of truth for artifact shapes: the Rust
//! side never hard-codes model dimensions, so recompiling the Python layer
//! with a different configuration requires no Rust changes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// One input or output of an artifact.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<ArgSpec> {
        Ok(ArgSpec {
            name: j.str_or("name", "?"),
            dtype: DType::parse(j.req("dtype")?.as_str().context("dtype")?)?,
            shape: j
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub meta: Json,
}

/// A serve-path CQ configuration listed in the manifest.
#[derive(Clone, Copy, Debug)]
pub struct ServeCq {
    pub channels: usize,
    pub bits: usize,
}

/// Model metadata block.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub train_ctx: usize,
    pub eval_ctx: usize,
    pub serve_ctx: usize,
    pub init_file: String,
    pub serve_cq: Vec<ServeCq>,
    pub decode_batches: Vec<usize>,
}

/// The parsed manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest JSON")?;
        let mut m = Manifest::default();
        for a in j.req("artifacts")?.as_arr().context("artifacts")? {
            let spec = ArtifactSpec {
                name: a.req("name")?.as_str().context("name")?.to_string(),
                inputs: a
                    .req("inputs")?
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<_>>()?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
            };
            m.artifacts.insert(spec.name.clone(), spec);
        }
        if let Some(Json::Obj(models)) = j.get("models") {
            for (name, mm) in models {
                let serve_cq = mm
                    .get("serve_cq")
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .map(|c| ServeCq {
                                channels: c.num_or("channels", 1.0) as usize,
                                bits: c.num_or("bits", 8.0) as usize,
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                let decode_batches = mm
                    .get("decode_batches")
                    .and_then(Json::as_arr)
                    .map(|arr| arr.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                m.models.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        param_count: mm.num_or("param_count", 0.0) as usize,
                        vocab: mm.num_or("vocab", 256.0) as usize,
                        d_model: mm.num_or("d_model", 0.0) as usize,
                        n_layers: mm.num_or("n_layers", 0.0) as usize,
                        n_heads: mm.num_or("n_heads", 0.0) as usize,
                        head_dim: mm.num_or("head_dim", 0.0) as usize,
                        d_ffn: mm.num_or("d_ffn", 0.0) as usize,
                        train_ctx: mm.num_or("train_ctx", 0.0) as usize,
                        eval_ctx: mm.num_or("eval_ctx", 0.0) as usize,
                        serve_ctx: mm.num_or("serve_ctx", 0.0) as usize,
                        init_file: mm.str_or("init_file", ""),
                        serve_cq,
                        decode_batches,
                    },
                );
            }
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {"small": {"param_count": 100, "vocab": 256, "d_model": 8,
        "n_layers": 2, "n_heads": 2, "head_dim": 4, "d_ffn": 16,
        "train_ctx": 8, "eval_ctx": 16, "serve_ctx": 32,
        "init_file": "init_small.bin",
        "serve_cq": [{"channels": 2, "bits": 8, "tag": "2c8b"}],
        "decode_batches": [1, 8]}},
      "artifacts": [{"name": "small.eval_kv",
        "inputs": [{"name": "params", "dtype": "f32", "shape": [100]},
                   {"name": "tokens", "dtype": "i32", "shape": [4, 16]}],
        "outputs": [{"name": "nll", "dtype": "f32", "shape": [4, 15]}],
        "meta": {"batch": 4, "ctx": 16}}]
    }"#;

    #[test]
    fn parses_models_and_artifacts() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mm = m.model("small").unwrap();
        assert_eq!(mm.param_count, 100);
        assert_eq!(mm.serve_cq.len(), 1);
        assert_eq!(mm.serve_cq[0].channels, 2);
        assert_eq!(mm.decode_batches, vec![1, 8]);
        let a = m.artifact("small.eval_kv").unwrap();
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[1].shape, vec![4, 16]);
        assert_eq!(a.outputs[0].numel(), 60);
        assert_eq!(a.meta.num_or("batch", 0.0), 4.0);
    }

    #[test]
    fn missing_entries_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("huge").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("small.eval_kv"));
            assert!(m.models.contains_key("small"));
            let mm = m.model("small").unwrap();
            assert_eq!(mm.head_dim, 64);
        }
    }
}

#![cfg_attr(feature = "simd", feature(portable_simd))]
//! `cq` — Coupled Quantization KV-cache serving stack.
//!
//! Reproduction of *"KV Cache is 1 Bit Per Channel: Efficient Large Language
//! Model Inference with Coupled Quantization"* (NeurIPS 2024) as a
//! three-layer Rust + JAX + Pallas system.  This crate is Layer 3: the
//! coordinator that owns the event loop, the quantized KV cache, request
//! routing/batching, training/calibration drivers and every experiment
//! harness.  Layers 1–2 (Pallas kernels + JAX model) are AOT-compiled to
//! `artifacts/*.hlo.txt` by `python/compile/aot.py` and executed through the
//! PJRT CPU client (`runtime`); Python never runs on the request path.
//!
//! Module map (see DESIGN.md §1 for the paper-system inventory):
//!
//! * [`util`]        — substrates the offline image lacks crates for:
//!                     JSON, RNG, CLI, bench harness, property testing.
//! * [`tensor`]      — minimal shaped f32/i32 host tensors.
//! * [`runtime`]     — PJRT engine: manifest, executable registry, literals.
//! * [`quant`]       — the paper's contribution + baselines: CQ codec,
//!                     k-means(++/weighted), INT/NF/KVQuant codecs,
//!                     bit-packing, entropy & correlation estimators.
//!                     Hot paths are batched: book-major dot-product-
//!                     expansion centroid assignment (`‖c‖²` precomputed per
//!                     codebook, per-layer threads in prefill) and
//!                     word-level pack/unpack into caller-owned scratch.
//! * [`data`]        — synthetic corpora, byte tokenizer, batch assembly.
//! * [`train`]       — Rust-driven AOT training loop + checkpoints.
//! * [`calib`]       — Fisher calibration (activations + gradients).
//! * [`eval`]        — perplexity + zero-shot suites under any codec.
//! * [`kvcache`]     — paged quantized cache: slab block pool + radix-tree
//!                     prefix sharing with LRU eviction (`kvcache::paged`),
//!                     staging buffers, per-shard block-budget accounting.
//!                     Encode span → pack records → block store → bulk
//!                     whole-block unpack → batch stage, all through reused
//!                     scratch (see the `kvcache` module doc for the full
//!                     batch-kernel dataflow).
//! * [`coordinator`] — sharded serve pool: least-loaded router (owner-
//!                     pinned routing for multi-turn sessions) with
//!                     pool-wide admission control over N engine workers,
//!                     continuous batcher, decode scheduler.  Requests are
//!                     event streams (`Started`/`Token`/`Done`/`Failed`)
//!                     with mid-decode cancellation that frees the lane and
//!                     cache blocks immediately; `submit`/`submit_async`
//!                     are drain-to-`Response` wrappers (one shared drain
//!                     thread).  Fault-tolerant: a supervisor retires dead
//!                     workers and re-dispatches their queued requests,
//!                     `EventSink`s guarantee every stream terminates,
//!                     session tables are bounded (LRU + TTL) with
//!                     `session_evicted`/`resend_history` signals, and
//!                     `coordinator::fault` scripts deterministic failures
//!                     against an engine-free sim backend (tests/chaos.rs).
//! * [`server`]      — TCP wire protocol v2: v1 single-line requests plus
//!                     `"stream": true` NDJSON event frames with a
//!                     `ttft_ms`/`queue_ms`-bearing terminal frame; failed
//!                     frames carry `retryable` + session resend signals;
//!                     client disconnect cancels mid-decode.  Blocking
//!                     accept + condvar `StopSignal` shutdown.
//! * [`metrics`]     — latency/throughput/memory-traffic telemetry (incl.
//!                     TTFT histograms and cancellation counts), merged
//!                     per-worker into pool-level aggregates.

pub mod bench_support;
pub mod calib;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kvcache;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// True when the AOT artifact bundle exists (`artifacts/manifest.json`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// True when artifacts exist *and* a PJRT engine can actually be built
/// (false when compiled against the vendored `xla` stub).  Integration
/// tests and benches that execute artifacts gate on this and skip
/// gracefully instead of failing on build-only hosts.
pub fn runtime_available() -> bool {
    artifacts_available() && runtime::Engine::load_default().is_ok()
}

/// Root of the artifact directory; overridable via `CQ_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Some(p) = std::env::var_os("CQ_ARTIFACTS") {
        return p.into();
    }
    // Walk up from CWD until a directory containing `artifacts/manifest.json`
    // is found (tests and benches run from target subdirectories).
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = d.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !d.pop() {
            return "artifacts".into();
        }
    }
}

//! Bounded per-worker session table: LRU capacity + idle TTL eviction.
//!
//! PR 4's session table mapped `session_id -> conversation token ids` in a
//! plain `HashMap` that grew without bound — a worker serving millions of
//! one-shot "sessions" would eventually hold every dead conversation's
//! history forever.  [`SessionTable`] bounds it two ways:
//!
//! * **LRU capacity** (`cap`): recording a turn for a new session beyond the
//!   cap evicts the least-recently-used session;
//! * **idle TTL** (`ttl`): a session untouched for longer than the TTL is
//!   evicted on the next table access (lazy sweep — no timer thread).
//!
//! Eviction is *visible*, not silent: the evicted id moves to a tombstone
//! set, and the next turn that references it gets
//! [`SessionLookup::Evicted`] — the serve loop turns that into a terminal
//! `Failed` event whose reason carries the `session_evicted` signal, telling
//! the client to resend its history instead of being silently answered from
//! partial context.  The failed lookup consumes the tombstone, so the
//! client's resent-history turn recreates the session cleanly.  Tombstones
//! are 8 bytes each and only accumulate for sessions that never return; the
//! histories themselves (the unbounded part PR 4 left open) are freed at
//! eviction time.
//!
//! The table also publishes each live session's total token count into
//! `ServeMetrics::session_tokens` so the pool router can estimate a
//! follow-up turn's true reservation (history + new text), not just the new
//! turn's text.

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crate::metrics::ServeMetrics;

struct Entry {
    ids: Vec<i32>,
    last_used: Instant,
    /// Logical recency (monotonic per table): LRU order without relying on
    /// `Instant` resolution for same-instant touches.
    touch: u64,
}

/// Outcome of a session lookup at turn admission.
pub enum SessionLookup<'a> {
    /// The conversation's token ids so far (prompt ++ generated of every
    /// prior turn), borrowed from the table — the admission path reads them
    /// once into the effective prompt without copying the history twice.
    Hit(&'a [i32]),
    /// The session existed but was evicted (LRU or TTL): the turn must fail
    /// with a `session_evicted` signal so the client resends history.
    Evicted,
    /// Never seen: this is the session's first turn.
    New,
}

/// Bounded session table for one serve worker.
pub struct SessionTable {
    cap: usize,
    ttl: Option<Duration>,
    clock: u64,
    entries: HashMap<u64, Entry>,
    evicted: HashSet<u64>,
}

impl SessionTable {
    pub fn new(cap: usize, ttl: Option<Duration>) -> SessionTable {
        SessionTable {
            cap: cap.max(1),
            ttl,
            clock: 0,
            entries: HashMap::new(),
            evicted: HashSet::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resolve a session at turn admission.  Sweeps TTL-expired sessions
    /// first, so an idle-too-long session answers `Evicted` even if nothing
    /// else touched the table since it expired.
    pub fn lookup(&mut self, sid: u64, metrics: &ServeMetrics) -> SessionLookup<'_> {
        self.sweep_expired(metrics);
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&sid) {
            e.last_used = Instant::now();
            e.touch = clock;
            return SessionLookup::Hit(&e.ids);
        }
        if self.evicted.remove(&sid) {
            return SessionLookup::Evicted;
        }
        SessionLookup::New
    }

    /// Record a finished turn's full conversation, publishing its token
    /// count and LRU-evicting over-cap sessions.
    pub fn record(&mut self, sid: u64, ids: Vec<i32>, metrics: &ServeMetrics) {
        self.clock += 1;
        metrics.session_tokens.publish(sid, ids.len() as u64);
        self.entries
            .insert(sid, Entry { ids, last_used: Instant::now(), touch: self.clock });
        while self.entries.len() > self.cap {
            let coldest = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.touch)
                .map(|(&k, _)| k)
                .expect("non-empty over-cap table");
            self.evict(coldest, metrics);
        }
    }

    /// Tombstone bound: sessions that never return would otherwise grow the
    /// evicted set by 8 bytes each, forever.  When the set overflows (far
    /// beyond any live working set) it is cleared wholesale — the cleared
    /// sessions lose their explicit `session_evicted` signal and simply
    /// start fresh on their next turn, trading a rare soft reset for a hard
    /// memory bound.
    fn tombstone_cap(&self) -> usize {
        (8 * self.cap).max(1024)
    }

    fn evict(&mut self, sid: u64, metrics: &ServeMetrics) {
        if self.entries.remove(&sid).is_some() {
            if self.evicted.len() >= self.tombstone_cap() {
                self.evicted.clear();
            }
            self.evicted.insert(sid);
            metrics.sessions_evicted.add(1);
            metrics.session_tokens.forget(sid);
        }
    }

    fn sweep_expired(&mut self, metrics: &ServeMetrics) {
        let Some(ttl) = self.ttl else { return };
        let expired: Vec<u64> = self
            .entries
            .iter()
            .filter(|(_, e)| e.last_used.elapsed() > ttl)
            .map(|(&k, _)| k)
            .collect();
        for sid in expired {
            self.evict(sid, metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hit_ids(l: SessionLookup<'_>) -> Vec<i32> {
        match l {
            SessionLookup::Hit(ids) => ids.to_vec(),
            SessionLookup::Evicted => panic!("unexpected Evicted"),
            SessionLookup::New => panic!("unexpected New"),
        }
    }

    #[test]
    fn record_then_lookup_roundtrips_and_publishes_length() {
        let m = ServeMetrics::default();
        let mut t = SessionTable::new(8, None);
        assert!(matches!(t.lookup(1, &m), SessionLookup::New));
        t.record(1, vec![10, 11, 12], &m);
        assert_eq!(hit_ids(t.lookup(1, &m)), vec![10, 11, 12]);
        assert_eq!(m.session_tokens.get(1), Some(3));
        t.record(1, vec![10, 11, 12, 13, 14], &m);
        assert_eq!(hit_ids(t.lookup(1, &m)).len(), 5);
        assert_eq!(m.session_tokens.get(1), Some(5));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn lru_cap_evicts_coldest_and_surfaces_evicted_once() {
        let m = ServeMetrics::default();
        let mut t = SessionTable::new(2, None);
        t.record(1, vec![1], &m);
        t.record(2, vec![2], &m);
        // Touch 1 so 2 becomes coldest.
        let _ = t.lookup(1, &m);
        t.record(3, vec![3], &m);
        assert_eq!(t.len(), 2);
        assert_eq!(m.sessions_evicted.get(), 1);
        assert_eq!(m.session_tokens.get(2), None, "evicted length forgotten");
        assert!(matches!(t.lookup(2, &m), SessionLookup::Evicted));
        // The failed turn consumed the tombstone: the resent-history turn
        // starts the session fresh.
        assert!(matches!(t.lookup(2, &m), SessionLookup::New));
        assert!(matches!(t.lookup(1, &m), SessionLookup::Hit(_)));
        assert!(matches!(t.lookup(3, &m), SessionLookup::Hit(_)));
    }

    #[test]
    fn ttl_expiry_evicts_on_next_access() {
        let m = ServeMetrics::default();
        let mut t = SessionTable::new(8, Some(Duration::from_millis(1)));
        t.record(5, vec![9, 9], &m);
        std::thread::sleep(Duration::from_millis(10));
        assert!(matches!(t.lookup(5, &m), SessionLookup::Evicted));
        assert_eq!(m.sessions_evicted.get(), 1);
        assert!(t.is_empty());
        // With a generous TTL the same access pattern stays live.
        let mut t2 = SessionTable::new(8, Some(Duration::from_secs(600)));
        t2.record(5, vec![1], &m);
        assert!(matches!(t2.lookup(5, &m), SessionLookup::Hit(_)));
    }
}

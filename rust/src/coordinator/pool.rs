//! Sharded serve pool: N replica workers, each owning its own non-`Send`
//! PJRT [`crate::runtime::Engine`], `Batcher`, `BatchStage` and paged cache
//! shard (`kvcache::PagedShard`: block pool + radix prefix index +
//! accounting) on a dedicated thread, fronted by a router that dispatches
//! requests over per-worker mpsc channels.
//!
//! Routing is **least-loaded**: the router tracks per-worker in-flight
//! requests ([`WorkerLoad`]) and picks the worker with the shallowest
//! virtual queue, breaking ties by most free lanes and then round-robin
//! (a rotating scan start).  Requests carrying a session id route to the
//! worker **owning that session's history** — derived from the per-worker
//! published session-token directories (no router-side session table, so
//! router session state is bounded by the worker tables), with the
//! deterministic affinity hash placing sessions that have no history yet —
//! so every turn of a conversation lands on the shard holding its
//! radix-cached blocks.  In-flight accounting is crash-safe: every
//! dispatched request carries a [`LoadToken`] that decrements the counter
//! on drop, whatever path the request dies on.
//!
//! **Fault tolerance (PR 5).**  A dedicated *supervisor thread* owns
//! worker-lifecycle recovery:
//!
//! * every worker thread carries a death notice that reports its exit
//!   (clean shutdown vs crash) — crashed workers are retired from rotation
//!   (`PoolMetrics::workers_dead`) without waiting for the next failed send;
//! * every dispatched request rides in a [`super::EventSink`]; when a worker
//!   dies, sinks still *queued* in its channel re-route through the
//!   supervisor and are **speculatively re-dispatched** to a live worker
//!   (`PoolMetrics::requests_redispatched`) — the client just sees its
//!   stream start a little late.  Requests already mid-decode get a terminal
//!   `Failed { retryable: true }` instead, because re-running them would
//!   duplicate already-streamed token events;
//! * a follow-up session turn whose owning worker died is failed with a
//!   `resend_history` reason (retryable: false) — its history died with the
//!   shard, and serving only the new text would be silently wrong.  The
//!   dead worker's directory entry is forgotten so the client's
//!   resent-history turn places fresh on a live worker.  A session first
//!   turn that dies queued (no history recorded anywhere) is simply
//!   re-dispatched like any other request.
//!
//! The router's pool-wide admission estimate counts a session's **full
//! published token count** (history + new text, from
//! `ServeMetrics::session_tokens`), closing the PR 4 follow-up where session
//! turns were gated only on their new text.
//!
//! The streaming lifecycle API is [`ServePool::submit_stream`]; `submit` /
//! `submit_async` are drain-to-[`Response`] wrappers.  `submit_async` is
//! served by one shared multiplexing drain thread (not one thread per
//! request): it polls every active stream and resolves each terminal event
//! into the legacy `Receiver<Response>` contract.
//!
//! The global cache byte budget becomes a **per-shard budget**
//! (`ceil(total / n_workers)`); per-shard accounting is re-aggregated by
//! [`crate::metrics::PoolMetrics`].  [`ServeHandle`] survives as the
//! `n_workers = 1` special case so single-stream callers keep a simple API.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::metrics::{PoolMetrics, ServeMetrics};
use crate::quant::policy::{PolicyDescriptor, PolicyTable};

use super::serve_loop::{build_policy_table, serve_loop, ServeConfig};
use super::{Event, EventSink, Inbound, Priority, Request, Response, SupervisorMsg};

/// Shared load snapshot for one worker: how many requests have been
/// dispatched to it and not yet completed/rejected.
pub struct WorkerLoad {
    batch: usize,
    inflight: AtomicUsize,
}

impl WorkerLoad {
    pub fn new(batch: usize) -> WorkerLoad {
        WorkerLoad { batch: batch.max(1), inflight: AtomicUsize::new(0) }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Decode lanes not yet claimed by an in-flight request.
    pub fn free_lanes(&self) -> usize {
        self.batch.saturating_sub(self.inflight())
    }

    /// Requests beyond lane capacity (the worker's virtual queue depth).
    pub fn queue_depth(&self) -> usize {
        self.inflight().saturating_sub(self.batch)
    }
}

/// RAII in-flight marker: created at dispatch, rides inside the request
/// through the worker, and decrements the worker's in-flight count when the
/// request reaches *any* terminal state (its `SeqRun`/message is dropped).
pub struct LoadToken(Arc<WorkerLoad>);

impl LoadToken {
    pub fn acquire(load: &Arc<WorkerLoad>) -> LoadToken {
        load.inflight.fetch_add(1, Ordering::Relaxed);
        LoadToken(load.clone())
    }
}

impl Drop for LoadToken {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Client end of one request's event stream: iterate (or `recv`) the
/// [`Event`]s as the worker produces them, and/or cancel mid-decode.
/// Dropping the handle without draining also cancels implicitly — the
/// worker treats a dead event receiver as a disconnected client and
/// reclaims the lane on its next token.
pub struct StreamHandle {
    id: u64,
    rx: Receiver<Event>,
    /// Clone of the owning worker's inbound sender (None when the request
    /// was terminated at the router and never reached a worker).
    cancel_tx: Option<Sender<Inbound>>,
    /// Worker index the request was dispatched to (None when terminated at
    /// the router).  Chaos scenarios use it as per-request ground truth; a
    /// supervisor re-dispatch may later move the request elsewhere.
    worker: Option<usize>,
}

/// Detached cancel trigger for a stream (cheap to clone out of a
/// [`StreamHandle`] before iterating it away).
pub struct CancelHandle {
    id: u64,
    tx: Option<Sender<Inbound>>,
}

impl CancelHandle {
    /// Ask the worker to cancel this request.  Safe at any time: unknown or
    /// already-completed ids are ignored worker-side.
    pub fn cancel(&self) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Inbound::Cancel(self.id));
        }
    }
}

impl StreamHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Worker this request was originally dispatched to (`None` when the
    /// router terminated it without dispatching).
    pub fn worker(&self) -> Option<usize> {
        self.worker
    }

    /// A detached cancel trigger (usable while this handle is being
    /// iterated or after it was consumed by [`Self::drain`]).
    pub fn canceller(&self) -> CancelHandle {
        CancelHandle { id: self.id, tx: self.cancel_tx.clone() }
    }

    /// Ask the worker to cancel this request mid-decode: its lane frees,
    /// reserved blocks return to the shard budget, and the stream ends with
    /// a `Failed` event.
    pub fn cancel(&self) {
        self.canceller().cancel();
    }

    /// Block for the next event.  Errors only when the worker dropped the
    /// stream without a terminal event (worker death).
    pub fn recv(&self) -> Result<Event> {
        match self.rx.recv() {
            Ok(ev) => Ok(ev),
            Err(_) => bail!("serve worker dropped event stream"),
        }
    }

    /// Non-blocking poll for the next event.
    pub fn try_recv(&self) -> Option<Event> {
        self.rx.try_recv().ok()
    }

    /// Block up to `timeout` for the next event; `None` on timeout or a
    /// dropped stream.  The chaos suite drives every stream through this so
    /// a hang is an assertion failure, never a stuck test.
    pub fn recv_deadline(&self, timeout: Duration) -> Option<Event> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking poll that distinguishes "nothing yet" (`Ok(None)`) from
    /// a dropped stream (`Err`) — the shared drain thread needs the
    /// difference to retire dead streams instead of polling them forever.
    pub fn try_event(&self) -> Result<Option<Event>> {
        match self.rx.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("serve worker dropped event stream"),
        }
    }

    /// Consume the stream to its terminal event and fold it into the legacy
    /// [`Response`]: `Done` passes through, `Failed` becomes
    /// [`Response::failure`] (preserving the v1 rejection/error texts).
    pub fn drain(self) -> Result<Response> {
        loop {
            match self.rx.recv() {
                Ok(Event::Done(resp)) => return Ok(resp),
                Ok(Event::Failed { id, reason, .. }) => return Ok(Response::failure(id, reason)),
                Ok(_) => {}
                Err(_) => bail!("serve worker dropped response"),
            }
        }
    }
}

impl Iterator for StreamHandle {
    type Item = Event;

    /// Yields events until the worker drops its sender (which happens right
    /// after the terminal event).
    fn next(&mut self) -> Option<Event> {
        self.rx.recv().ok()
    }
}

/// Pick the least-loaded worker from `(queue_depth, free_lanes)` snapshots:
/// min queue depth, then max free lanes, scanning from `start` so equally
/// loaded workers are chosen round-robin.
pub(crate) fn select_least_loaded(loads: &[(usize, usize)], start: usize) -> usize {
    assert!(!loads.is_empty());
    let n = loads.len();
    let mut best = start % n;
    for k in 1..n {
        let i = (start + k) % n;
        let (bq, bf) = loads[best];
        let (iq, if_) = loads[i];
        if iq < bq || (iq == bq && if_ > bf) {
            best = i;
        }
    }
    best
}

/// Per-shard cache budget: split the global budget evenly (rounded up so
/// `n` shards never sum below the requested total).
pub(crate) fn shard_budget(total: Option<usize>, n_workers: usize) -> Option<usize> {
    total.map(|b| b.div_ceil(n_workers.max(1)))
}

/// Pool-wide admission check: would a request needing
/// `(prompt_tokens + max_new) * bytes_per_token` bytes overflow what is
/// left of the *total* pool budget?  `bytes_in_use` should already exclude
/// radix-cached bytes (shards evict those on demand, so they count as
/// available).  `bytes_per_token == 0` means no worker has published its
/// geometry yet — admit and let the shard decide.  Conservative on purpose:
/// prefix hits and per-shard context trimming can only shrink the real
/// reservation.
pub(crate) fn pool_admission_rejects(
    total_budget: Option<usize>,
    bytes_per_token: u64,
    bytes_in_use: u64,
    prompt_tokens: usize,
    max_new: usize,
) -> bool {
    let Some(budget) = total_budget else { return false };
    if bytes_per_token == 0 {
        return false;
    }
    let est = (prompt_tokens + max_new) as u64 * bytes_per_token;
    est > (budget as u64).saturating_sub(bytes_in_use)
}

/// Policy-aware variant of the pool admission gate: the request's byte
/// estimate comes from ITS policy descriptor
/// ([`PolicyDescriptor::reserve_bytes`] over the pool's published quantized
/// and fp16 rates), so an fp16 tenant is gated on fp16 math and a windowed
/// tenant on its mixed rate — not the pool-wide quantized constant.  A zero
/// estimate means no worker has published the relevant rate yet: admit and
/// let the shard decide, matching the legacy gate's semantics.
pub(crate) fn pool_admission_rejects_policy(
    total_budget: Option<usize>,
    policy: &PolicyDescriptor,
    q_bpt: u64,
    fp_bpt: u64,
    bytes_in_use: u64,
    prompt_tokens: usize,
    max_new: usize,
) -> bool {
    let Some(budget) = total_budget else { return false };
    let est = policy.reserve_bytes(prompt_tokens + max_new, q_bpt, fp_bpt);
    if est == 0 {
        return false;
    }
    est > (budget as u64).saturating_sub(bytes_in_use)
}

/// Estimated time-to-first-token for a new request on a worker that already
/// has `backlog_tokens` of prefill pending, in prefill chunks: the worker
/// advances one chunk per loop iteration, and the new prompt queues behind
/// the backlog.  Conservative for interactive requests (they preempt batch
/// chunks), exact for a FIFO same-class queue.
pub(crate) fn estimate_ttft_chunks(
    backlog_tokens: u64,
    prompt_tokens: usize,
    prefill_chunk: usize,
) -> u64 {
    (backlog_tokens + prompt_tokens as u64).div_ceil(prefill_chunk.max(1) as u64)
}

/// Effective prompt-token count for the router's pool-wide estimate: the
/// session's published history (0 for non-session / first turns) plus the
/// new turn's text, clamped to the published prefill ceiling (`max_ctx ==
/// 0` means no worker has published one yet).  Session turns are thereby
/// gated on the reservation the shard will actually take — not just the new
/// text (the PR 4 follow-up).
pub(crate) fn estimate_prompt_tokens(
    history_tokens: usize,
    new_text_len: usize,
    max_ctx: usize,
) -> usize {
    let t = history_tokens + new_text_len;
    if max_ctx > 0 {
        t.min(max_ctx)
    } else {
        t
    }
}

struct PoolWorker {
    tx: Sender<Inbound>,
    load: Arc<WorkerLoad>,
    /// Cleared when the worker's loop exits (supervisor death notice or a
    /// failed send); dead workers are excluded from routing — otherwise a
    /// crashed worker's empty load would make it a magnet for all
    /// subsequent traffic.
    alive: AtomicBool,
}

/// Router state shared between the pool handle and the supervisor thread.
struct RouterState {
    workers: Vec<PoolWorker>,
    rr: AtomicUsize,
    /// Total cache budget across all shards (admission-control ceiling).
    total_budget: Option<usize>,
    /// Workers' prefill yield granularity (denominator of the TTFT
    /// admission estimate).
    prefill_chunk: usize,
    /// Interactive TTFT admission bound in chunks (`None` = gate off): an
    /// interactive request whose best-case estimate across live workers
    /// exceeds this is rejected retryably at the router.
    ttft_slo_chunks: Option<u64>,
    /// The pool's per-tenant policy table (same specs every worker
    /// validated): the router prices policy-carrying requests with it and
    /// fast-fails unknown names without a worker round-trip.
    policies: PolicyTable,
    metrics: Arc<PoolMetrics>,
}

/// Outcome of one routing attempt.
enum Dispatched {
    /// Handed to this worker's queue.
    Sent(usize),
    /// Terminated at the router; a terminal `Failed` event is already on
    /// the stream (budget rejection, resend-history, retries exhausted).
    Terminal,
    /// No live worker and nothing sent: the caller surfaces an error.
    NoWorkers,
}

impl RouterState {
    fn alive(&self, w: usize) -> bool {
        self.workers[w].alive.load(Ordering::Relaxed)
    }

    /// Take a worker out of rotation; `count` distinguishes a crash (counts
    /// toward `workers_dead`) from a clean shutdown.
    fn retire(&self, w: usize, count: bool) {
        if self.workers[w].alive.swap(false, Ordering::Relaxed) && count {
            self.metrics.workers_dead.add(1);
            // Flight-recorder post-mortem: every request still live on the
            // dead worker gets a terminal trace (failed if mid-flight,
            // redispatched if still pre-first-token) in the crash-dump
            // store, which outlives the worker for `{"op":"trace"}` reads.
            let dumped = self
                .metrics
                .worker(w)
                .trace
                .dump_crashed(&format!("worker {w} crashed"));
            log::warn!(
                "serve worker {w} is gone; retired from rotation \
                 ({dumped} in-flight traces dumped)"
            );
        }
    }

    /// Least-loaded live worker, or `None` when every worker is dead.  The
    /// candidate list is rotated by a round-robin counter before the
    /// least-loaded scan so ties rotate across the pool.
    fn pick_worker(&self) -> Option<usize> {
        let n = self.workers.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let live: Vec<usize> = (0..n)
            .map(|k| (start + k) % n)
            .filter(|&i| self.alive(i))
            .collect();
        if live.is_empty() {
            return None;
        }
        let loads: Vec<(usize, usize)> = live
            .iter()
            .map(|&i| {
                let w = &self.workers[i];
                (w.load.queue_depth(), w.load.free_lanes())
            })
            .collect();
        Some(live[select_least_loaded(&loads, 0)])
    }

    /// First-turn session placement: deterministic hash of the session id
    /// onto the worker ring, scanning forward past dead workers.  The
    /// placement is stable (the alive set only shrinks), so a session's
    /// turns keep landing on the same shard without any router-side table.
    fn pick_session_worker(&self, session_id: u64) -> Option<usize> {
        let n = self.workers.len();
        let start = (session_id % n as u64) as usize;
        (0..n).map(|k| (start + k) % n).find(|&i| self.alive(i))
    }

    /// The worker holding this session's history, if any — derived from the
    /// per-worker published session-token directory, so the router carries
    /// **no unbounded session state** of its own (the directories are
    /// bounded by each worker's `SessionTable` cap).  `None` until the
    /// session's first turn completes somewhere.
    fn session_owner(&self, sid: u64) -> Option<usize> {
        (0..self.workers.len())
            .find(|&w| self.metrics.worker(w).session_tokens.get(sid).is_some())
    }

    /// Send to worker `w` inside a fresh supervised [`EventSink`]; on
    /// failure retire the worker and hand the request back for an inline
    /// retry elsewhere.
    fn try_send(
        &self,
        w: usize,
        req: Request,
        tx: &Sender<Event>,
        sup: &Sender<SupervisorMsg>,
        attempts: usize,
    ) -> std::result::Result<(), Request> {
        let token = LoadToken::acquire(&self.workers[w].load);
        let sink = EventSink::supervised(req, tx.clone(), sup.clone(), attempts);
        match self.workers[w].tx.send(Inbound::Submit(sink, Some(token))) {
            Ok(()) => Ok(()),
            Err(std::sync::mpsc::SendError(msg)) => {
                self.retire(w, true);
                match msg {
                    Inbound::Submit(sink, _token) => {
                        Err(sink.recover().expect("undispatched sink holds its request"))
                    }
                    _ => unreachable!("submit send bounced a different message"),
                }
            }
        }
    }

    /// Route + dispatch one request.  All router-terminal outcomes push a
    /// terminal `Failed` event onto `tx` before returning, so a dispatched
    /// or `Terminal` stream can never hang.
    fn dispatch(
        &self,
        mut req: Request,
        tx: &Sender<Event>,
        sup: &Sender<SupervisorMsg>,
        attempts: usize,
    ) -> Dispatched {
        let id = req.id;
        // Re-dispatch bound: a request that keeps landing on dying workers
        // must not ping-pong forever.  Bounded by the LIVE worker count
        // (floor 1), not the historical pool size — in a pool where most
        // workers have been retired, each extra attempt can only land on
        // the same survivors again.
        let live = (0..self.workers.len())
            .filter(|&w| self.alive(w))
            .count()
            .max(1);
        if attempts > live {
            let _ = tx.send(Event::Failed {
                id,
                reason: "[error: serve worker died; re-dispatch retries exhausted]".into(),
                retryable: true,
            });
            return Dispatched::Terminal;
        }
        // --- Session affinity: resolve the owning worker first ----------
        // "Owner" = the worker that published history for this session.  A
        // session with no published history anywhere (first turn, or a
        // first turn recovered from a crashed worker before it ever ran)
        // has lost nothing and is placed fresh by the affinity hash.
        let mut session_target = None;
        let mut history_tokens = 0usize;
        let mut has_history = false;
        if let Some(sid) = req.session_id {
            match self.session_owner(sid) {
                Some(w) if self.alive(w) => {
                    history_tokens =
                        self.metrics.worker(w).session_tokens.get(sid).unwrap_or(0) as usize;
                    has_history = true;
                    session_target = Some(w);
                }
                Some(w) => {
                    // The shard holding this session's history is dead;
                    // generating from only the new turn's text would be
                    // wrong, silently.  Scrub EVERY directory (matching the
                    // supervisor's `SessionLost` path) — a stale replica
                    // entry on another worker would otherwise capture the
                    // resent-history turn and serve it from partial
                    // context.
                    for wm in self.metrics.workers() {
                        wm.session_tokens.forget(sid);
                    }
                    let _ = tx.send(Event::Failed {
                        id,
                        reason: format!(
                            "[resend_history: session {sid} lost with worker {w}; \
                             resend full history]"
                        ),
                        retryable: false,
                    });
                    return Dispatched::Terminal;
                }
                None => match self.pick_session_worker(sid) {
                    Some(w) => session_target = Some(w),
                    None => return Dispatched::NoWorkers,
                },
            }
        }
        // --- Per-tenant policy resolution --------------------------------
        // An unknown policy name is a client error: fail it here,
        // non-retryably, without burning a worker round-trip.
        let policy = match req.policy.as_deref() {
            None => None,
            Some(name) => match self.policies.get(name) {
                Some(d) => Some(d),
                None => {
                    self.metrics.router_rejected.add(1);
                    let _ = tx.send(Event::Failed {
                        id,
                        reason: format!(
                            "[rejected: unknown policy '{name}' (serving: {:?})]",
                            self.policies.names()
                        ),
                        retryable: false,
                    });
                    return Dispatched::Terminal;
                }
            },
        };
        // --- Pool-wide admission estimate -------------------------------
        let hard_in_use = self
            .metrics
            .cache_bytes_in_use()
            .saturating_sub(self.metrics.cache_cached_bytes());
        let prompt_tokens = estimate_prompt_tokens(
            history_tokens,
            req.prompt.len(),
            self.metrics.max_prompt_tokens() as usize,
        );
        let over_budget = match policy {
            None => pool_admission_rejects(
                self.total_budget,
                self.metrics.bytes_per_token(),
                hard_in_use,
                prompt_tokens,
                req.max_new,
            ),
            Some(d) => pool_admission_rejects_policy(
                self.total_budget,
                d,
                self.metrics.bytes_per_token(),
                self.metrics.fp16_bytes_per_token(),
                hard_in_use,
                prompt_tokens,
                req.max_new,
            ),
        };
        if over_budget {
            self.metrics.router_rejected.add(1);
            let _ = tx.send(Event::Failed {
                id,
                reason: String::from("[rejected: pool budget]"),
                retryable: true,
            });
            return Dispatched::Terminal;
        }
        // --- Interactive TTFT admission (chunk-backlog estimate) ---------
        // Admitting an interactive request the pool cannot serve inside the
        // SLO just converts a fast retryable rejection into a slow one; the
        // estimate uses the best (minimum) published prefill backlog among
        // live workers.  Batch requests are exempt — they queue.
        if let Some(slo) = self.ttft_slo_chunks {
            if req.priority == Priority::Interactive {
                let backlog = (0..self.workers.len())
                    .filter(|&w| self.alive(w))
                    .map(|w| self.metrics.worker(w).prefill_backlog_tokens.get())
                    .min();
                if let Some(backlog) = backlog {
                    if estimate_ttft_chunks(backlog, prompt_tokens, self.prefill_chunk) > slo {
                        self.metrics.router_rejected.add(1);
                        let _ = tx.send(Event::Failed {
                            id,
                            reason: String::from("[rejected: ttft slo]"),
                            retryable: true,
                        });
                        return Dispatched::Terminal;
                    }
                }
            }
        }
        // --- Hand off ----------------------------------------------------
        if let Some(w0) = session_target {
            let sid = req.session_id.expect("session target implies session id");
            let mut w = w0;
            loop {
                match self.try_send(w, req, tx, sup, attempts) {
                    Ok(()) => return Dispatched::Sent(w),
                    Err(back) => {
                        req = back;
                        if has_history {
                            // The owner died between the aliveness check and
                            // the send: same resend-history outcome, same
                            // scrub-all (no stale replica may survive).
                            for wm in self.metrics.workers() {
                                wm.session_tokens.forget(sid);
                            }
                            let _ = tx.send(Event::Failed {
                                id,
                                reason: format!(
                                    "[resend_history: session {sid} lost with worker {w}; \
                                     resend full history]"
                                ),
                                retryable: false,
                            });
                            return Dispatched::Terminal;
                        }
                        match self.pick_session_worker(sid) {
                            Some(n) => w = n,
                            None => return Dispatched::NoWorkers,
                        }
                    }
                }
            }
        }
        for _ in 0..self.workers.len() {
            let Some(w) = self.pick_worker() else { break };
            match self.try_send(w, req, tx, sup, attempts) {
                Ok(()) => return Dispatched::Sent(w),
                Err(back) => req = back,
            }
        }
        Dispatched::NoWorkers
    }
}

/// Reports a worker thread's exit to the supervisor on every path out of
/// the thread closure — normal return, startup error, or panic unwind.
struct DeathNotice {
    worker: usize,
    clean: bool,
    tx: Sender<SupervisorMsg>,
}

impl Drop for DeathNotice {
    fn drop(&mut self) {
        let _ = self
            .tx
            .send(SupervisorMsg::WorkerDied { worker: self.worker, clean: self.clean });
    }
}

/// Supervisor: retires dead workers and re-dispatches recovered requests.
/// Exits on [`SupervisorMsg::Stop`] (pool shutdown/drop).
fn supervisor_loop(
    state: Arc<RouterState>,
    rx: Receiver<SupervisorMsg>,
    sup_tx: Sender<SupervisorMsg>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            SupervisorMsg::Stop => break,
            SupervisorMsg::WorkerDied { worker, clean } => {
                state.retire(worker, !clean);
                if !clean {
                    log::warn!("serve worker {worker} died; recovering its queued requests");
                }
            }
            SupervisorMsg::SessionLost(sid) => {
                // The session's mid-flight turn died with its worker: scrub
                // every directory so the resent-history turn places fresh
                // instead of bouncing off the dead owner a second time.
                for w in state.metrics.workers() {
                    w.session_tokens.forget(sid);
                }
            }
            SupervisorMsg::Redispatch { req, events, attempts } => {
                let id = req.id;
                match state.dispatch(req, &events, &sup_tx, attempts) {
                    Dispatched::Sent(w) => {
                        state.metrics.requests_redispatched.add(1);
                        log::info!("request {id} re-dispatched to worker {w}");
                    }
                    Dispatched::Terminal => {}
                    Dispatched::NoWorkers => {
                        let _ = events.send(Event::Failed {
                            id,
                            reason: String::from("[error: no live serve workers]"),
                            retryable: true,
                        });
                    }
                }
            }
        }
    }
}

/// Shared `submit_async` drain: multiplexes every active stream through one
/// thread, resolving each terminal event into its `Receiver<Response>`.
/// Parks on the control channel while nothing is in flight; while streams
/// are active it polls with an exponential idle backoff (100 µs → 5 ms), so
/// a long-running generation costs at most a few hundred wakeups/second
/// instead of a busy spin, and responses surface within one backoff step.
fn drain_loop(ctl: Receiver<(StreamHandle, Sender<Response>)>) {
    const BACKOFF_MIN: Duration = Duration::from_micros(100);
    const BACKOFF_MAX: Duration = Duration::from_millis(5);
    let mut active: Vec<(StreamHandle, Sender<Response>)> = Vec::new();
    let mut open = true;
    let mut backoff = BACKOFF_MIN;
    loop {
        if active.is_empty() {
            if !open {
                return;
            }
            match ctl.recv() {
                Ok(pair) => active.push(pair),
                Err(_) => return,
            }
        }
        loop {
            match ctl.try_recv() {
                Ok(pair) => active.push(pair),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        let mut progressed = false;
        active.retain_mut(|(stream, out)| loop {
            match stream.try_event() {
                Ok(Some(Event::Done(resp))) => {
                    progressed = true;
                    let _ = out.send(resp);
                    return false;
                }
                Ok(Some(Event::Failed { id, reason, .. })) => {
                    progressed = true;
                    let _ = out.send(Response::failure(id, reason));
                    return false;
                }
                Ok(Some(_)) => progressed = true,
                Ok(None) => return true,
                // Stream dropped without a terminal event: dropping `out`
                // unsent surfaces the legacy disconnected-receiver error.
                Err(_) => {
                    progressed = true;
                    return false;
                }
            }
        });
        if progressed {
            backoff = BACKOFF_MIN;
        } else {
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_MAX);
        }
    }
}

/// Handle to a sharded pool of serve-loop workers.
///
/// `Sync`: submissions from many threads (TCP connection handlers, bench
/// clients) go through `&self`; each picks a worker and sends on its
/// channel.  Workers own all non-`Send` PJRT state.
pub struct ServePool {
    state: Arc<RouterState>,
    joins: Vec<Option<std::thread::JoinHandle<Result<()>>>>,
    sup_tx: Sender<SupervisorMsg>,
    sup_join: Option<std::thread::JoinHandle<()>>,
    drain_tx: Option<Sender<(StreamHandle, Sender<Response>)>>,
    drain_join: Option<std::thread::JoinHandle<()>>,
    /// Pool + per-worker telemetry (shared with the supervisor thread).
    pub metrics: Arc<PoolMetrics>,
}

impl ServePool {
    /// Spawn `n_workers` replica serve loops (each compiles its own
    /// executables and owns a cache shard of `cache_budget / n_workers`),
    /// plus the supervisor and shared drain threads.
    pub fn start(cfg: ServeConfig, n_workers: usize) -> ServePool {
        let n = n_workers.max(1);
        let per_shard = shard_budget(cfg.cache_budget, n);
        let (sup_tx, sup_rx) = channel();
        let mut workers = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        let mut worker_metrics = Vec::with_capacity(n);
        for w in 0..n {
            let mut wcfg = cfg.clone();
            wcfg.cache_budget = per_shard;
            wcfg.worker_index = w;
            let (tx, rx) = channel();
            let metrics = Arc::new(ServeMetrics::default());
            let m2 = metrics.clone();
            let sup = sup_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("cq-serve-worker-{w}"))
                .spawn(move || {
                    // The notice fires on every exit path: a normal return
                    // reports clean, a startup/loop error or panic unwind
                    // reports a crash.  The loop's receiver drops first, so
                    // queued sinks re-dispatch before the death notice lands.
                    let mut notice = DeathNotice { worker: w, clean: false, tx: sup };
                    let res = serve_loop(wcfg, rx, m2);
                    notice.clean = res.is_ok();
                    res
                })
                .expect("spawn serve worker");
            workers.push(PoolWorker {
                tx,
                load: Arc::new(WorkerLoad::new(cfg.batch)),
                alive: AtomicBool::new(true),
            });
            joins.push(Some(join));
            worker_metrics.push(metrics);
        }
        let metrics = Arc::new(PoolMetrics::new(worker_metrics));
        // The router shares the workers' validated policy table.  Invalid
        // specs leave it empty here — the workers themselves fail startup
        // with the descriptive error, and policy-carrying requests then
        // fast-fail at the router as unknown names.
        let policies = build_policy_table(&cfg).unwrap_or_default();
        let state = Arc::new(RouterState {
            workers,
            rr: AtomicUsize::new(0),
            total_budget: cfg.cache_budget,
            prefill_chunk: cfg.prefill_chunk,
            ttft_slo_chunks: cfg.ttft_slo_chunks,
            policies,
            metrics: metrics.clone(),
        });
        let sup_state = state.clone();
        let sup_tx2 = sup_tx.clone();
        let sup_join = std::thread::Builder::new()
            .name("cq-serve-supervisor".into())
            .spawn(move || supervisor_loop(sup_state, sup_rx, sup_tx2))
            .expect("spawn pool supervisor");
        let (drain_tx, drain_rx) = channel();
        let drain_join = std::thread::Builder::new()
            .name("cq-stream-drain".into())
            .spawn(move || drain_loop(drain_rx))
            .expect("spawn shared stream drain");
        ServePool {
            state,
            joins,
            sup_tx,
            sup_join: Some(sup_join),
            drain_tx: Some(drain_tx),
            drain_join: Some(drain_join),
            metrics,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.state.workers.len()
    }

    /// Current `(queue_depth, free_lanes)` per worker (router's view).
    pub fn loads(&self) -> Vec<(usize, usize)> {
        self.state
            .workers
            .iter()
            .map(|w| (w.load.queue_depth(), w.load.free_lanes()))
            .collect()
    }

    /// Whether worker `w` is still accepting traffic (`{"op":"health"}`).
    pub fn worker_alive(&self, w: usize) -> bool {
        self.state.alive(w)
    }

    /// Workers still accepting traffic.
    pub fn live_workers(&self) -> usize {
        (0..self.state.workers.len())
            .filter(|&i| self.state.alive(i))
            .count()
    }

    /// Dispatch a request as an event stream.  Requests that cannot
    /// possibly fit the pool's remaining cache budget — counting a
    /// session's full published history — are terminated here with a
    /// `Failed` event, before any worker sees them; so are follow-up
    /// session turns whose owning worker died (`resend_history`).  A failed
    /// send retires that worker and reroutes to the next live one.
    pub fn submit_stream(&self, mut req: Request) -> Result<StreamHandle> {
        // Workers always serve at least one token (the decode loop appends
        // before consulting must_stop), so clamp max_new ONCE — up front —
        // and dispatch the clamped request.  The pool-wide byte estimate
        // and the shard's own reservation then gate the same value; a
        // max_new = 0 request can no longer slip past the router with a
        // smaller reservation than the shard actually takes.
        req.max_new = req.max_new.max(1);
        let id = req.id;
        let (tx, rx) = channel();
        match self.state.dispatch(req, &tx, &self.sup_tx, 0) {
            Dispatched::Sent(w) => Ok(StreamHandle {
                id,
                rx,
                cancel_tx: Some(self.state.workers[w].tx.clone()),
                worker: Some(w),
            }),
            Dispatched::Terminal => Ok(StreamHandle { id, rx, cancel_tx: None, worker: None }),
            Dispatched::NoWorkers => {
                // Same contract as every other router-terminal outcome: a
                // stream that already holds its terminal event.  The
                // supervisor's re-dispatch path resolves NoWorkers this way
                // too, so first dispatch and re-dispatch now agree.
                let _ = tx.send(Event::Failed {
                    id,
                    reason: String::from("[error: no live serve workers]"),
                    retryable: true,
                });
                Ok(StreamHandle { id, rx, cancel_tx: None, worker: None })
            }
        }
    }

    /// Dispatch a request onto a caller-owned event channel instead of a
    /// fresh per-request one.  Events are id-tagged, so one sender can
    /// multiplex every in-flight request — this is the event-driven
    /// frontend's queue-push path: the reactor hands its single shared
    /// sender here and one pump thread drains all streams, instead of one
    /// blocked drain thread per connection.  Same dispatch contract as
    /// [`Self::submit_stream`]: every router-terminal outcome has pushed a
    /// terminal `Failed` event onto `events` before this returns, so a
    /// stream can never hang.  The returned [`CancelHandle`] is inert
    /// (`cancel` is a no-op) when the request terminated at the router.
    pub fn submit_stream_with(&self, mut req: Request, events: &Sender<Event>) -> CancelHandle {
        // Clamp once, up front, for the same reason submit_stream does.
        req.max_new = req.max_new.max(1);
        let id = req.id;
        match self.state.dispatch(req, events, &self.sup_tx, 0) {
            Dispatched::Sent(w) => {
                CancelHandle { id, tx: Some(self.state.workers[w].tx.clone()) }
            }
            Dispatched::Terminal => CancelHandle { id, tx: None },
            Dispatched::NoWorkers => {
                let _ = events.send(Event::Failed {
                    id,
                    reason: String::from("[error: no live serve workers]"),
                    retryable: true,
                });
                CancelHandle { id, tx: None }
            }
        }
    }

    /// Dispatch without waiting; returns the legacy response receiver.  The
    /// shared drain thread folds the event stream into its terminal
    /// [`Response`]; worker death without a terminal event surfaces as a
    /// dropped receiver, exactly as before the streaming redesign.
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Response>> {
        let stream = self.submit_stream(req)?;
        let (tx, rx) = channel();
        self.drain_tx
            .as_ref()
            .expect("drain thread runs for the pool's lifetime")
            .send((stream, tx))
            .map_err(|_| anyhow!("stream drain thread exited"))?;
        Ok(rx)
    }

    /// Dispatch and block for the terminal response.
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.submit_stream(req)?.drain()
    }

    /// Drain all workers and join them; the first worker error propagates.
    pub fn shutdown(mut self) -> Result<()> {
        for w in &self.state.workers {
            let _ = w.tx.send(Inbound::Shutdown);
        }
        let mut first_err: Option<anyhow::Error> = None;
        for j in &mut self.joins {
            if let Some(j) = j.take() {
                let res = match j.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow!("serve worker panicked")),
                };
                if let Err(e) = res {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        // Workers are joined: every death notice and recovered request is
        // already queued ahead of this Stop, so the supervisor settles them
        // before exiting.
        let _ = self.sup_tx.send(SupervisorMsg::Stop);
        if let Some(j) = self.sup_join.take() {
            let _ = j.join();
        }
        // Closing the control channel lets the drain thread exit once its
        // in-flight streams (all terminal by now) are resolved.
        self.drain_tx.take();
        if let Some(j) = self.drain_join.take() {
            let _ = j.join();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ServePool {
    fn drop(&mut self) {
        // Covers pools dropped without an explicit `shutdown` (tests, early
        // returns): without this the supervisor would park on its queue
        // forever, since it holds its own re-dispatch sender.
        let _ = self.sup_tx.send(SupervisorMsg::Stop);
        self.drain_tx.take();
    }
}

/// In-process handle for the single-worker case: spawns a 1-worker
/// [`ServePool`] and forwards to it.  Kept because single-stream callers
/// (the `generate` CLI, quickstart) don't care about sharding.
pub struct ServeHandle {
    pool: ServePool,
}

impl ServeHandle {
    pub fn start(cfg: ServeConfig) -> ServeHandle {
        ServeHandle { pool: ServePool::start(cfg, 1) }
    }

    /// The underlying 1-worker pool (e.g. for `server::serve_tcp`).
    pub fn pool(&self) -> &ServePool {
        &self.pool
    }

    /// Metrics of the single worker.
    pub fn metrics(&self) -> &ServeMetrics {
        self.pool.metrics.worker(0)
    }

    /// Submit a request and block for its response.
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.pool.submit(req)
    }

    /// Submit without waiting; returns the response receiver.
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Response>> {
        self.pool.submit_async(req)
    }

    /// Submit as an event stream (token events + cancellation).
    pub fn submit_stream(&self, req: Request) -> Result<StreamHandle> {
        self.pool.submit_stream(req)
    }

    /// Drain and stop the loop.
    pub fn shutdown(self) -> Result<()> {
        self.pool.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_prefers_shallow_queue_then_free_lanes() {
        // Worker 1 has the shallowest queue.
        assert_eq!(select_least_loaded(&[(2, 0), (0, 0), (1, 0)], 0), 1);
        // Equal queues: worker with more free lanes wins.
        assert_eq!(select_least_loaded(&[(0, 1), (0, 3), (0, 2)], 0), 1);
        // Queue depth dominates free lanes.
        assert_eq!(select_least_loaded(&[(1, 8), (0, 1)], 0), 1);
    }

    #[test]
    fn ties_break_round_robin_via_scan_start() {
        let even = [(0usize, 4usize), (0, 4), (0, 4)];
        assert_eq!(select_least_loaded(&even, 0), 0);
        assert_eq!(select_least_loaded(&even, 1), 1);
        assert_eq!(select_least_loaded(&even, 2), 2);
        assert_eq!(select_least_loaded(&even, 3), 0);
    }

    #[test]
    fn load_tokens_track_inflight_free_lanes_and_queue_depth() {
        let load = Arc::new(WorkerLoad::new(2));
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 2));
        let t1 = LoadToken::acquire(&load);
        let t2 = LoadToken::acquire(&load);
        let t3 = LoadToken::acquire(&load);
        assert_eq!(load.inflight(), 3);
        assert_eq!(load.free_lanes(), 0);
        assert_eq!(load.queue_depth(), 1, "one request beyond lane capacity");
        drop(t2);
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 0));
        drop(t1);
        drop(t3);
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 2));
    }

    #[test]
    fn budget_splits_across_shards_rounding_up() {
        assert_eq!(shard_budget(None, 4), None);
        assert_eq!(shard_budget(Some(100), 1), Some(100));
        assert_eq!(shard_budget(Some(100), 4), Some(25));
        assert_eq!(shard_budget(Some(101), 4), Some(26), "never under-provision");
    }

    fn dead_worker_cfg(cache_budget: Option<usize>) -> ServeConfig {
        ServeConfig {
            model: "small".into(),
            cq: None,
            batch: 1,
            cache_budget,
            codebook_path: None,
            params_path: "/nonexistent/params.bin".into(),
            kernel: ServeConfig::default_kernel(),
            block_tokens: ServeConfig::default_block_tokens(),
            prefix_sharing: true,
            sim: None,
            faults: None,
            worker_index: 0,
            session_cap: ServeConfig::default_session_cap(),
            session_ttl: None,
            prefill_chunk: ServeConfig::default_prefill_chunk(),
            ttft_slo_chunks: None,
            trace_ring: ServeConfig::default_trace_ring(),
            encode_threads: ServeConfig::default_encode_threads(),
            codec: None,
            policies: Vec::new(),
        }
    }

    /// Dead-worker submissions race the supervisor: the send either fails
    /// inline (`Err`) or lands in a dying channel and comes back as a
    /// terminal `[error: ...]` failure event.  Both are fail-fast.
    fn failed_fast(r: Result<Response>) -> bool {
        match r {
            Err(_) => true,
            Ok(resp) => resp.gen_tokens == 0 && resp.text.starts_with("[error"),
        }
    }

    #[test]
    fn pool_with_missing_assets_errors_instead_of_hanging() {
        // No artifacts / params anywhere: every worker must fail fast and
        // submissions must surface an error, never block forever.
        let pool = ServePool::start(dead_worker_cfg(None), 2);
        assert_eq!(pool.n_workers(), 2);
        assert!(failed_fast(pool.submit(Request::greedy(1, "x", 4))));
        assert!(pool.shutdown().is_err(), "worker startup error propagates");
    }

    #[test]
    fn pool_admission_estimate_gates_on_total_remaining_budget() {
        // No budget or unpublished geometry: always admit.
        assert!(!pool_admission_rejects(None, 8, 0, 1_000_000, 1_000));
        assert!(!pool_admission_rejects(Some(100), 0, 0, 1_000_000, 1_000));
        // (prompt + max_new) * bpt vs remaining budget.
        assert!(!pool_admission_rejects(Some(100), 4, 0, 20, 5), "100 == 100 fits");
        assert!(pool_admission_rejects(Some(100), 4, 0, 20, 6), "104 > 100");
        // In-use bytes shrink the remaining budget.
        assert!(pool_admission_rejects(Some(100), 4, 60, 5, 5));
        assert!(!pool_admission_rejects(Some(100), 4, 60, 5, 4));
        // Saturation: over-reserved pool admits nothing with a cost.
        assert!(pool_admission_rejects(Some(100), 4, 200, 1, 0));
    }

    #[test]
    fn session_history_counts_toward_the_prompt_estimate() {
        // The PR 4 follow-up, pinned: a follow-up session turn is estimated
        // against history + new text, not just the new text.
        assert_eq!(estimate_prompt_tokens(0, 12, 0), 12, "non-session unchanged");
        assert_eq!(estimate_prompt_tokens(1000, 5, 0), 1005);
        assert_eq!(estimate_prompt_tokens(1000, 5, 64), 64, "prefill ceiling clamps");
        assert_eq!(estimate_prompt_tokens(0, 5, 64), 5);
        // Combined with the byte gate: 40-token history + 5 new + 30 decode
        // at 2 B/token = 150 B can never fit a 128 B pool, while the old
        // new-text-only estimate (70 B) would have slipped through.
        let est_new = estimate_prompt_tokens(40, 5, 0);
        let est_old = estimate_prompt_tokens(0, 5, 0);
        assert!(pool_admission_rejects(Some(128), 2, 0, est_new, 30));
        assert!(!pool_admission_rejects(Some(128), 2, 0, est_old, 30));
    }

    #[test]
    fn max_new_zero_is_clamped_before_the_pool_estimate() {
        // The shard always reserves for >= 1 decode token; the router's
        // byte estimate must gate the same clamped value, not the raw
        // request.  16-token prompt at 4 B/token: (16 + 1) * 4 = 68 B can
        // never fit a 64 B pool, even though the raw max_new = 0 estimate
        // (64 B) would have slipped through.
        let pool = ServePool::start(dead_worker_cfg(Some(64)), 1);
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        let resp = pool
            .submit(Request::greedy(1, &"x".repeat(16), 0))
            .expect("router replies directly");
        assert!(resp.text.contains("pool budget"), "{}", resp.text);
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        // One token smaller and the clamped estimate fits exactly — the
        // request passes the gate (and then dies on the dead worker).
        assert!(failed_fast(pool.submit(Request::greedy(2, &"x".repeat(15), 0))));
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn router_rejects_oversized_requests_before_any_worker() {
        let pool = ServePool::start(dead_worker_cfg(Some(1024)), 2);
        // Simulate one worker having published its cache geometry.
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        // (2000 + 16) * 4 bytes can never fit a 1024-byte pool: rejected at
        // the router even though every worker is dead.
        let big = Request::greedy(1, &"x".repeat(2000), 16);
        let resp = pool.submit(big).expect("router replies directly");
        assert!(resp.text.contains("pool budget"), "{}", resp.text);
        assert_eq!(resp.gen_tokens, 0);
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert_eq!(pool.metrics.requests_rejected(), 1);
        // A small request passes the gate and then surfaces the dead-worker
        // error instead.
        assert!(failed_fast(pool.submit(Request::greedy(2, "hi", 1))));
        // Once a worker publishes its prefill ceiling, the estimate clamps
        // to it: the same huge prompt trims to (64 + 16) * 4 = 320 B, fits
        // the 1024 B pool, and reaches the (dead) workers instead of being
        // router-rejected.
        pool.metrics.worker(0).max_prompt_tokens.observe_max(64);
        assert!(failed_fast(pool.submit(Request::greedy(3, &"x".repeat(2000), 16))));
        assert_eq!(
            pool.metrics.router_rejected.get(),
            1,
            "trimmed estimate must not be rejected again"
        );
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn router_rejection_is_a_failed_event_on_the_stream() {
        let pool = ServePool::start(dead_worker_cfg(Some(64)), 1);
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        let h = pool
            .submit_stream(Request::greedy(7, &"x".repeat(100), 4))
            .expect("router replies directly");
        assert_eq!(h.id(), 7);
        assert_eq!(h.worker(), None, "router-terminated: no worker");
        match h.recv().expect("one terminal event") {
            Event::Failed { id, reason, retryable } => {
                assert_eq!(id, 7);
                assert!(reason.contains("pool budget"), "{reason}");
                assert!(retryable, "capacity rejection is retryable");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // cancel on a router-terminated stream is a harmless no-op.
        h.cancel();
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn session_requests_route_by_affinity_hash() {
        let pool = ServePool::start(dead_worker_cfg(None), 3);
        let state = &pool.state;
        // Deterministic ring position, independent of load.
        assert_eq!(state.pick_session_worker(0), Some(0));
        assert_eq!(state.pick_session_worker(4), Some(1));
        assert_eq!(state.pick_session_worker(5), Some(2));
        assert_eq!(
            state.pick_session_worker(3),
            state.pick_session_worker(3),
            "same session id always maps to the same worker"
        );
        // Dead workers are skipped by scanning forward on the ring.
        state.workers[1].alive.store(false, Ordering::Relaxed);
        assert_eq!(state.pick_session_worker(4), Some(2));
        state.workers[2].alive.store(false, Ordering::Relaxed);
        assert_eq!(state.pick_session_worker(4), Some(0));
        state.workers[0].alive.store(false, Ordering::Relaxed);
        assert_eq!(state.pick_session_worker(4), None, "all dead");
        // With every worker dead the submission fails fast with a terminal
        // retryable event instead of erroring or hanging.
        let h = pool
            .submit_stream(Request::greedy(1, "x", 2).in_session(4))
            .expect("NoWorkers yields a terminal stream");
        match h.recv().expect("terminal event") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("no live serve workers"), "{reason}");
                assert!(retryable);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn redispatch_retry_bound_tracks_live_workers() {
        let pool = ServePool::start(dead_worker_cfg(None), 4);
        for w in 0..3 {
            pool.state.workers[w].alive.store(false, Ordering::Relaxed);
        }
        // 2 attempts already: more than the single live worker, so the
        // request terminates instead of ping-ponging up to the historical
        // pool size (the old `attempts > workers.len()` bound would have
        // allowed 4 attempts against 1 survivor).
        let (tx, rx) = channel();
        let (sup_tx, _sup_rx) = channel();
        let out = pool
            .state
            .dispatch(Request::greedy(1, "x", 2), &tx, &sup_tx, 2);
        assert!(matches!(out, Dispatched::Terminal));
        match rx.try_recv().expect("terminal event already on the stream") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("retries exhausted"), "{reason}");
                assert!(retryable);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn dead_owner_failure_scrubs_every_session_directory() {
        let pool = ServePool::start(dead_worker_cfg(None), 3);
        // Session 9's history lives on worker 0; a stale replica of the
        // directory entry survives on worker 2 (e.g. published by an
        // earlier turn before the session moved).
        pool.metrics.worker(0).session_tokens.publish(9, 40);
        pool.metrics.worker(2).session_tokens.publish(9, 12);
        pool.state.workers[0].alive.store(false, Ordering::Relaxed);
        let h = pool
            .submit_stream(Request::greedy(2, "next turn", 4).in_session(9))
            .expect("router replies directly");
        match h.recv().expect("terminal event") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("resend_history"), "{reason}");
                assert!(!retryable);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // EVERY directory is scrubbed — not just the dead owner's — so the
        // resent-history turn cannot route to the stale replica and be
        // served from partial context.
        for w in 0..3 {
            assert_eq!(
                pool.metrics.worker(w).session_tokens.get(9),
                None,
                "worker {w} directory must be scrubbed"
            );
        }
        assert_eq!(pool.state.session_owner(9), None);
        assert_eq!(pool.state.pick_session_worker(9), Some(1), "places fresh on a live worker");
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn submit_on_all_dead_pool_fails_fast_with_terminal_event() {
        let pool = ServePool::start(dead_worker_cfg(None), 2);
        for w in 0..2 {
            pool.state.workers[w].alive.store(false, Ordering::Relaxed);
        }
        // First dispatch against an all-dead pool: a stream that already
        // holds its terminal retryable Failed — never an Err, never a
        // stream that hangs.
        let h = pool
            .submit_stream(Request::greedy(5, "x", 2))
            .expect("NoWorkers yields a terminal stream");
        assert_eq!(h.worker(), None, "router-terminated: no worker");
        match h.recv().expect("terminal event, never a hung stream") {
            Event::Failed { id, reason, retryable } => {
                assert_eq!(id, 5);
                assert!(reason.contains("no live serve workers"), "{reason}");
                assert!(retryable);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn policy_requests_price_admission_at_their_own_rate() {
        let mut cfg = dead_worker_cfg(Some(1024));
        cfg.policies = vec!["fp16".into()];
        let pool = ServePool::start(cfg, 1);
        pool.metrics.worker(0).bytes_per_token.observe_max(2);
        pool.metrics.worker(0).fp16_bytes_per_token.observe_max(64);
        // 20 tokens total: 40 B under the pool-wide quantized rate — passes
        // the gate (then dies on the dead worker).
        assert!(failed_fast(pool.submit(Request::greedy(1, &"x".repeat(16), 4))));
        assert_eq!(pool.metrics.router_rejected.get(), 0);
        // The SAME shape as an fp16 tenant prices at 20 * 64 = 1280 B and
        // is rejected by the router before any worker sees it.
        let resp = pool
            .submit(Request::greedy(2, &"x".repeat(16), 4).with_policy("fp16"))
            .expect("router replies directly");
        assert!(resp.text.contains("pool budget"), "{}", resp.text);
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        // Unknown policy names fast-fail non-retryably at the router.
        let h = pool
            .submit_stream(Request::greedy(3, "x", 2).with_policy("nope"))
            .expect("router replies directly");
        match h.recv().expect("terminal event") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("unknown policy 'nope'"), "{reason}");
                assert!(!retryable, "client must fix the name, not retry");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(pool.metrics.router_rejected.get(), 2);
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn policy_gate_admits_until_rates_are_published() {
        let fp = PolicyDescriptor::parse("fp16").unwrap();
        // No published fp16 rate: estimate is zero, admit (shard decides).
        assert!(!pool_admission_rejects_policy(Some(100), &fp, 4, 0, 0, 50, 0));
        assert!(pool_admission_rejects_policy(Some(100), &fp, 4, 64, 0, 50, 0));
        // Windowed policy mixes both rates: 8 fp-resident + 42 quantized.
        let w = PolicyDescriptor::parse("cq-8c8b-w6-s2").unwrap();
        assert_eq!(w.reserve_bytes(50, 4, 64), 42 * 4 + 8 * 64);
        assert!(pool_admission_rejects_policy(Some(500), &w, 4, 64, 0, 50, 0));
        assert!(!pool_admission_rejects_policy(Some(1000), &w, 4, 64, 0, 50, 0));
        // No budget: never rejects.
        assert!(!pool_admission_rejects_policy(None, &fp, 4, 64, 0, 1 << 20, 0));
    }

    #[test]
    fn ttft_estimate_counts_backlog_plus_own_prompt_in_chunks() {
        assert_eq!(estimate_ttft_chunks(0, 512, 512), 1);
        assert_eq!(estimate_ttft_chunks(0, 513, 512), 2);
        assert_eq!(estimate_ttft_chunks(1024, 1, 512), 3);
        assert_eq!(estimate_ttft_chunks(0, 0, 512), 0, "nothing pending, nothing to wait for");
        // Degenerate chunk size never divides by zero.
        assert_eq!(estimate_ttft_chunks(3, 1, 0), 4);
    }

    #[test]
    fn ttft_slo_gate_rejects_interactive_behind_a_deep_backlog() {
        use crate::coordinator::fault::{FaultPlan, SimSpec};
        let plan = FaultPlan::new();
        plan.hold_worker(0);
        let mut cfg = dead_worker_cfg(None);
        cfg.sim = Some(SimSpec::tiny());
        cfg.faults = Some(plan.clone());
        cfg.prefill_chunk = 4;
        cfg.ttft_slo_chunks = Some(2);
        let pool = ServePool::start(cfg, 1);
        // The worker is parked at its loop-top gate, so the backlog level
        // we plant here is exactly what the router reads.
        plan.await_paused(0);
        pool.metrics.worker(0).prefill_backlog_tokens.set(64);
        let h = pool
            .submit_stream(Request::greedy(1, "hi", 2))
            .expect("router replies directly");
        match h.recv().expect("terminal event") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("ttft slo"), "{reason}");
                assert!(retryable, "the client can retry once the backlog drains");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        // Batch-priority requests are exempt from the gate: they dispatch
        // and queue behind the backlog.
        let batch = pool
            .submit_stream(Request::greedy(2, "hi", 2).batch_priority())
            .expect("batch dispatches");
        assert_eq!(batch.worker(), Some(0), "gate does not apply to batch priority");
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        plan.release_worker(0);
        let resp = batch.drain().expect("batch request completes");
        assert!(resp.gen_tokens >= 1);
        pool.shutdown().expect("clean shutdown");
    }

    #[test]
    fn follow_up_turn_on_dead_session_worker_gets_resend_history() {
        let pool = ServePool::start(dead_worker_cfg(None), 2);
        // Simulate a session whose owning worker published history (turn 1
        // completed there) and then died.
        pool.metrics.worker(0).session_tokens.publish(9, 40);
        pool.state.workers[0].alive.store(false, Ordering::Relaxed);
        assert_eq!(pool.state.session_owner(9), Some(0));
        let h = pool
            .submit_stream(Request::greedy(2, "next turn", 4).in_session(9))
            .expect("router replies directly");
        match h.recv().expect("terminal event") {
            Event::Failed { reason, retryable, .. } => {
                assert!(reason.contains("resend_history"), "{reason}");
                assert!(!retryable, "blind retry would reuse the lost history");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // The dead worker's directory entry is forgotten: the resent-history
        // turn sees no owner and places fresh (on the live worker 1).
        assert_eq!(pool.state.session_owner(9), None);
        assert_eq!(pool.state.pick_session_worker(9), Some(1));
        // A session with NO published history anywhere is never failed with
        // resend_history — nothing was lost, it routes like a first turn
        // (and here dies on the dead-worker pool like any other request).
        assert!(failed_fast(pool.submit(Request::greedy(3, "x", 2).in_session(11))));
        assert!(pool.shutdown().is_err());
    }
}

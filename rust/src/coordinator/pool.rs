//! Sharded serve pool: N replica workers, each owning its own non-`Send`
//! PJRT [`crate::runtime::Engine`], `Batcher`, `BatchStage` and paged cache
//! shard (`kvcache::PagedShard`: block pool + radix prefix index +
//! accounting) on a dedicated thread, fronted by a router that dispatches
//! requests over per-worker mpsc channels.
//!
//! Routing is **least-loaded**: the router tracks per-worker in-flight
//! requests ([`WorkerLoad`]) and picks the worker with the shallowest
//! virtual queue, breaking ties by most free lanes and then round-robin
//! (a rotating scan start).  Requests carrying a session id instead route
//! by **affinity hash** (`session_id % n_workers`, skipping dead workers)
//! so every turn of a conversation lands on the shard holding its
//! radix-cached blocks.  In-flight accounting is crash-safe: every
//! dispatched request carries a [`LoadToken`] that decrements the counter
//! on drop, whatever path the request dies on (completion, budget
//! rejection, prefill failure, cancellation, shutdown drain).  A worker
//! whose loop has exited is marked dead on the first failed send and
//! excluded from routing; the submission reroutes to the next live worker.
//!
//! The streaming lifecycle API is [`ServePool::submit_stream`]: it returns
//! a [`StreamHandle`] — an iterator of [`Event`]s plus `cancel()` — and the
//! legacy `submit` / `submit_async` are thin drain-to-[`Response`] wrappers
//! over it, so one code path serves every caller.
//!
//! The global cache byte budget becomes a **per-shard budget**
//! (`ceil(total / n_workers)`); per-shard accounting is re-aggregated by
//! [`crate::metrics::PoolMetrics`].  On top of the per-shard enforcement the
//! router runs **pool-wide admission control**: once any worker has
//! published its cache geometry, a request whose prefill+decode reservation
//! estimate exceeds the *total* remaining pool budget is rejected up front
//! — instead of being dispatched to a shard that is guaranteed to refuse it
//! after prefill work was already queued.  [`ServeHandle`] survives as the
//! `n_workers = 1` special case so single-stream callers keep a simple API.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::metrics::{PoolMetrics, ServeMetrics};

use super::serve_loop::{serve_loop, ServeConfig};
use super::{Event, Inbound, Request, Response};

/// Shared load snapshot for one worker: how many requests have been
/// dispatched to it and not yet completed/rejected.
pub struct WorkerLoad {
    batch: usize,
    inflight: AtomicUsize,
}

impl WorkerLoad {
    pub fn new(batch: usize) -> WorkerLoad {
        WorkerLoad { batch: batch.max(1), inflight: AtomicUsize::new(0) }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Decode lanes not yet claimed by an in-flight request.
    pub fn free_lanes(&self) -> usize {
        self.batch.saturating_sub(self.inflight())
    }

    /// Requests beyond lane capacity (the worker's virtual queue depth).
    pub fn queue_depth(&self) -> usize {
        self.inflight().saturating_sub(self.batch)
    }
}

/// RAII in-flight marker: created at dispatch, rides inside the request
/// through the worker, and decrements the worker's in-flight count when the
/// request reaches *any* terminal state (its `SeqRun`/message is dropped).
pub struct LoadToken(Arc<WorkerLoad>);

impl LoadToken {
    pub fn acquire(load: &Arc<WorkerLoad>) -> LoadToken {
        load.inflight.fetch_add(1, Ordering::Relaxed);
        LoadToken(load.clone())
    }
}

impl Drop for LoadToken {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Client end of one request's event stream: iterate (or `recv`) the
/// [`Event`]s as the worker produces them, and/or cancel mid-decode.
/// Dropping the handle without draining also cancels implicitly — the
/// worker treats a dead event receiver as a disconnected client and
/// reclaims the lane on its next token.
pub struct StreamHandle {
    id: u64,
    rx: Receiver<Event>,
    /// Clone of the owning worker's inbound sender (None when the request
    /// was terminated at the router and never reached a worker).
    cancel_tx: Option<Sender<Inbound>>,
}

/// Detached cancel trigger for a stream (cheap to clone out of a
/// [`StreamHandle`] before iterating it away).
pub struct CancelHandle {
    id: u64,
    tx: Option<Sender<Inbound>>,
}

impl CancelHandle {
    /// Ask the worker to cancel this request.  Safe at any time: unknown or
    /// already-completed ids are ignored worker-side.
    pub fn cancel(&self) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Inbound::Cancel(self.id));
        }
    }
}

impl StreamHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// A detached cancel trigger (usable while this handle is being
    /// iterated or after it was consumed by [`Self::drain`]).
    pub fn canceller(&self) -> CancelHandle {
        CancelHandle { id: self.id, tx: self.cancel_tx.clone() }
    }

    /// Ask the worker to cancel this request mid-decode: its lane frees,
    /// reserved blocks return to the shard budget, and the stream ends with
    /// a `Failed` event.
    pub fn cancel(&self) {
        self.canceller().cancel();
    }

    /// Block for the next event.  Errors only when the worker dropped the
    /// stream without a terminal event (worker death).
    pub fn recv(&self) -> Result<Event> {
        match self.rx.recv() {
            Ok(ev) => Ok(ev),
            Err(_) => bail!("serve worker dropped event stream"),
        }
    }

    /// Non-blocking poll for the next event.
    pub fn try_recv(&self) -> Option<Event> {
        self.rx.try_recv().ok()
    }

    /// Consume the stream to its terminal event and fold it into the legacy
    /// [`Response`]: `Done` passes through, `Failed` becomes
    /// [`Response::failure`] (preserving the v1 rejection/error texts).
    pub fn drain(self) -> Result<Response> {
        loop {
            match self.rx.recv() {
                Ok(Event::Done(resp)) => return Ok(resp),
                Ok(Event::Failed { id, reason }) => return Ok(Response::failure(id, reason)),
                Ok(_) => {}
                Err(_) => bail!("serve worker dropped response"),
            }
        }
    }
}

impl Iterator for StreamHandle {
    type Item = Event;

    /// Yields events until the worker drops its sender (which happens right
    /// after the terminal event).
    fn next(&mut self) -> Option<Event> {
        self.rx.recv().ok()
    }
}

/// Pick the least-loaded worker from `(queue_depth, free_lanes)` snapshots:
/// min queue depth, then max free lanes, scanning from `start` so equally
/// loaded workers are chosen round-robin.
pub(crate) fn select_least_loaded(loads: &[(usize, usize)], start: usize) -> usize {
    assert!(!loads.is_empty());
    let n = loads.len();
    let mut best = start % n;
    for k in 1..n {
        let i = (start + k) % n;
        let (bq, bf) = loads[best];
        let (iq, if_) = loads[i];
        if iq < bq || (iq == bq && if_ > bf) {
            best = i;
        }
    }
    best
}

/// Per-shard cache budget: split the global budget evenly (rounded up so
/// `n` shards never sum below the requested total).
pub(crate) fn shard_budget(total: Option<usize>, n_workers: usize) -> Option<usize> {
    total.map(|b| b.div_ceil(n_workers.max(1)))
}

/// Pool-wide admission check: would a request needing
/// `(prompt_tokens + max_new) * bytes_per_token` bytes overflow what is
/// left of the *total* pool budget?  `bytes_in_use` should already exclude
/// radix-cached bytes (shards evict those on demand, so they count as
/// available).  `bytes_per_token == 0` means no worker has published its
/// geometry yet — admit and let the shard decide.  Conservative on purpose:
/// prefix hits and per-shard context trimming can only shrink the real
/// reservation.
pub(crate) fn pool_admission_rejects(
    total_budget: Option<usize>,
    bytes_per_token: u64,
    bytes_in_use: u64,
    prompt_tokens: usize,
    max_new: usize,
) -> bool {
    let Some(budget) = total_budget else { return false };
    if bytes_per_token == 0 {
        return false;
    }
    let est = (prompt_tokens + max_new) as u64 * bytes_per_token;
    est > (budget as u64).saturating_sub(bytes_in_use)
}

struct PoolWorker {
    tx: Sender<Inbound>,
    load: Arc<WorkerLoad>,
    /// Cleared when a send to this worker fails (its loop exited); dead
    /// workers are excluded from routing — otherwise a crashed worker's
    /// empty load would make it a magnet for all subsequent traffic.
    alive: AtomicBool,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

/// Handle to a sharded pool of serve-loop workers.
///
/// `Sync`: submissions from many threads (TCP connection handlers, bench
/// clients) go through `&self`; each picks a worker and sends on its
/// channel.  Workers own all non-`Send` PJRT state.
pub struct ServePool {
    workers: Vec<PoolWorker>,
    rr: AtomicUsize,
    /// Total cache budget across all shards (admission-control ceiling).
    total_budget: Option<usize>,
    pub metrics: PoolMetrics,
}

impl ServePool {
    /// Spawn `n_workers` replica serve loops (each compiles its own
    /// executables and owns a cache shard of `cache_budget / n_workers`).
    pub fn start(cfg: ServeConfig, n_workers: usize) -> ServePool {
        let n = n_workers.max(1);
        let per_shard = shard_budget(cfg.cache_budget, n);
        let mut workers = Vec::with_capacity(n);
        let mut worker_metrics = Vec::with_capacity(n);
        for w in 0..n {
            let mut wcfg = cfg.clone();
            wcfg.cache_budget = per_shard;
            let (tx, rx) = channel();
            let metrics = Arc::new(ServeMetrics::default());
            let m2 = metrics.clone();
            let join = std::thread::Builder::new()
                .name(format!("cq-serve-worker-{w}"))
                .spawn(move || serve_loop(wcfg, rx, m2))
                .expect("spawn serve worker");
            workers.push(PoolWorker {
                tx,
                load: Arc::new(WorkerLoad::new(cfg.batch)),
                alive: AtomicBool::new(true),
                join: Some(join),
            });
            worker_metrics.push(metrics);
        }
        ServePool {
            workers,
            rr: AtomicUsize::new(0),
            total_budget: cfg.cache_budget,
            metrics: PoolMetrics::new(worker_metrics),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Current `(queue_depth, free_lanes)` per worker (router's view).
    pub fn loads(&self) -> Vec<(usize, usize)> {
        self.workers
            .iter()
            .map(|w| (w.load.queue_depth(), w.load.free_lanes()))
            .collect()
    }

    /// Workers still accepting traffic.
    pub fn live_workers(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| w.alive.load(Ordering::Relaxed))
            .count()
    }

    /// Least-loaded live worker, or `None` when every worker is dead.  The
    /// candidate list is rotated by a round-robin counter before the
    /// least-loaded scan so ties rotate across the pool.
    fn pick_worker(&self) -> Option<usize> {
        let n = self.workers.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let live: Vec<usize> = (0..n)
            .map(|k| (start + k) % n)
            .filter(|&i| self.workers[i].alive.load(Ordering::Relaxed))
            .collect();
        if live.is_empty() {
            return None;
        }
        let loads: Vec<(usize, usize)> = live
            .iter()
            .map(|&i| {
                let w = &self.workers[i];
                (w.load.queue_depth(), w.load.free_lanes())
            })
            .collect();
        Some(live[select_least_loaded(&loads, 0)])
    }

    /// Session-affinity pick: deterministic hash of the session id onto the
    /// worker ring, scanning forward past dead workers.  Every turn of a
    /// session lands on the shard whose radix index holds its blocks (the
    /// ROADMAP "prefix-affinity" follow-up), trading a little load balance
    /// for prefix locality.
    fn pick_session_worker(&self, session_id: u64) -> Option<usize> {
        let n = self.workers.len();
        let start = (session_id % n as u64) as usize;
        (0..n)
            .map(|k| (start + k) % n)
            .find(|&i| self.workers[i].alive.load(Ordering::Relaxed))
    }

    /// Dispatch a request as an event stream.  Requests that cannot
    /// possibly fit the pool's remaining cache budget are terminated here
    /// with a `Failed` event, before any worker sees them.  A failed send
    /// marks that worker dead and reroutes to the next live one.  Session
    /// requests route by affinity hash instead of least-loaded (the byte
    /// estimate sees only the new turn's text — conservative in the wrong
    /// direction, but the shard's own reservation still gates the true
    /// length).
    pub fn submit_stream(&self, mut req: Request) -> Result<StreamHandle> {
        // Workers always serve at least one token (the decode loop appends
        // before consulting must_stop), so clamp max_new ONCE — up front —
        // and dispatch the clamped request.  The pool-wide byte estimate
        // below and the shard's own reservation then gate the same value; a
        // max_new = 0 request can no longer slip past the router with a
        // smaller reservation than the shard actually takes.
        req.max_new = req.max_new.max(1);
        let hard_in_use = self
            .metrics
            .cache_bytes_in_use()
            .saturating_sub(self.metrics.cache_cached_bytes());
        // Workers trim prompts to their prefill ceiling before reserving, so
        // the estimate must too (once a worker has published that ceiling).
        let max_ctx = self.metrics.max_prompt_tokens() as usize;
        let prompt_tokens = if max_ctx > 0 {
            req.prompt.len().min(max_ctx)
        } else {
            req.prompt.len()
        };
        if pool_admission_rejects(
            self.total_budget,
            self.metrics.bytes_per_token(),
            hard_in_use,
            prompt_tokens,
            req.max_new,
        ) {
            self.metrics.router_rejected.add(1);
            let (tx, rx) = channel();
            let _ = tx.send(Event::Failed {
                id: req.id,
                reason: String::from("[rejected: pool budget]"),
            });
            return Ok(StreamHandle { id: req.id, rx, cancel_tx: None });
        }
        let id = req.id;
        for _ in 0..self.workers.len() {
            let picked = match req.session_id {
                Some(sid) => self.pick_session_worker(sid),
                None => self.pick_worker(),
            };
            let Some(wi) = picked else { break };
            let w = &self.workers[wi];
            let token = LoadToken::acquire(&w.load);
            let (tx, rx) = channel();
            match w.tx.send(Inbound::Submit(req.clone(), tx, Some(token))) {
                Ok(()) => {
                    return Ok(StreamHandle { id, rx, cancel_tx: Some(w.tx.clone()) })
                }
                Err(_) => {
                    // Worker loop exited: exclude it and retry elsewhere.
                    w.alive.store(false, Ordering::Relaxed);
                    log::warn!("serve worker {wi} is gone; rerouting");
                }
            }
        }
        Err(anyhow!("no live serve workers"))
    }

    /// Dispatch without waiting; returns the legacy response receiver.  A
    /// small drain thread folds the event stream into its terminal
    /// [`Response`]; worker death surfaces as a dropped receiver, exactly
    /// as before the streaming redesign.
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Response>> {
        let stream = self.submit_stream(req)?;
        let (tx, rx) = channel();
        std::thread::Builder::new()
            .name("cq-stream-drain".into())
            .spawn(move || {
                if let Ok(resp) = stream.drain() {
                    let _ = tx.send(resp);
                }
                // Drain error: tx drops unsent -> the receiver observes a
                // disconnect, matching the old dropped-response behavior.
            })
            .expect("spawn stream drain thread");
        Ok(rx)
    }

    /// Dispatch and block for the terminal response.
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.submit_stream(req)?.drain()
    }

    /// Drain all workers and join them; the first worker error propagates.
    pub fn shutdown(mut self) -> Result<()> {
        for w in &self.workers {
            let _ = w.tx.send(Inbound::Shutdown);
        }
        let mut first_err: Option<anyhow::Error> = None;
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let res = match j.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow!("serve worker panicked")),
                };
                if let Err(e) = res {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// In-process handle for the single-worker case: spawns a 1-worker
/// [`ServePool`] and forwards to it.  Kept because single-stream callers
/// (the `generate` CLI, quickstart) don't care about sharding.
pub struct ServeHandle {
    pool: ServePool,
}

impl ServeHandle {
    pub fn start(cfg: ServeConfig) -> ServeHandle {
        ServeHandle { pool: ServePool::start(cfg, 1) }
    }

    /// The underlying 1-worker pool (e.g. for `server::serve_tcp`).
    pub fn pool(&self) -> &ServePool {
        &self.pool
    }

    /// Metrics of the single worker.
    pub fn metrics(&self) -> &ServeMetrics {
        self.pool.metrics.worker(0)
    }

    /// Submit a request and block for its response.
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.pool.submit(req)
    }

    /// Submit without waiting; returns the response receiver.
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Response>> {
        self.pool.submit_async(req)
    }

    /// Submit as an event stream (token events + cancellation).
    pub fn submit_stream(&self, req: Request) -> Result<StreamHandle> {
        self.pool.submit_stream(req)
    }

    /// Drain and stop the loop.
    pub fn shutdown(self) -> Result<()> {
        self.pool.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_prefers_shallow_queue_then_free_lanes() {
        // Worker 1 has the shallowest queue.
        assert_eq!(select_least_loaded(&[(2, 0), (0, 0), (1, 0)], 0), 1);
        // Equal queues: worker with more free lanes wins.
        assert_eq!(select_least_loaded(&[(0, 1), (0, 3), (0, 2)], 0), 1);
        // Queue depth dominates free lanes.
        assert_eq!(select_least_loaded(&[(1, 8), (0, 1)], 0), 1);
    }

    #[test]
    fn ties_break_round_robin_via_scan_start() {
        let even = [(0usize, 4usize), (0, 4), (0, 4)];
        assert_eq!(select_least_loaded(&even, 0), 0);
        assert_eq!(select_least_loaded(&even, 1), 1);
        assert_eq!(select_least_loaded(&even, 2), 2);
        assert_eq!(select_least_loaded(&even, 3), 0);
    }

    #[test]
    fn load_tokens_track_inflight_free_lanes_and_queue_depth() {
        let load = Arc::new(WorkerLoad::new(2));
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 2));
        let t1 = LoadToken::acquire(&load);
        let t2 = LoadToken::acquire(&load);
        let t3 = LoadToken::acquire(&load);
        assert_eq!(load.inflight(), 3);
        assert_eq!(load.free_lanes(), 0);
        assert_eq!(load.queue_depth(), 1, "one request beyond lane capacity");
        drop(t2);
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 0));
        drop(t1);
        drop(t3);
        assert_eq!((load.queue_depth(), load.free_lanes()), (0, 2));
    }

    #[test]
    fn budget_splits_across_shards_rounding_up() {
        assert_eq!(shard_budget(None, 4), None);
        assert_eq!(shard_budget(Some(100), 1), Some(100));
        assert_eq!(shard_budget(Some(100), 4), Some(25));
        assert_eq!(shard_budget(Some(101), 4), Some(26), "never under-provision");
    }

    fn dead_worker_cfg(cache_budget: Option<usize>) -> ServeConfig {
        ServeConfig {
            model: "small".into(),
            cq: None,
            batch: 1,
            cache_budget,
            codebook_path: None,
            params_path: "/nonexistent/params.bin".into(),
            kernel: ServeConfig::default_kernel(),
            block_tokens: ServeConfig::default_block_tokens(),
            prefix_sharing: true,
        }
    }

    #[test]
    fn pool_with_missing_assets_errors_instead_of_hanging() {
        // No artifacts / params anywhere: every worker must fail fast and
        // submissions must surface an error, never block forever.
        let pool = ServePool::start(dead_worker_cfg(None), 2);
        assert_eq!(pool.n_workers(), 2);
        assert!(pool.submit(Request::greedy(1, "x", 4)).is_err());
        assert!(pool.shutdown().is_err(), "worker startup error propagates");
    }

    #[test]
    fn pool_admission_estimate_gates_on_total_remaining_budget() {
        // No budget or unpublished geometry: always admit.
        assert!(!pool_admission_rejects(None, 8, 0, 1_000_000, 1_000));
        assert!(!pool_admission_rejects(Some(100), 0, 0, 1_000_000, 1_000));
        // (prompt + max_new) * bpt vs remaining budget.
        assert!(!pool_admission_rejects(Some(100), 4, 0, 20, 5), "100 == 100 fits");
        assert!(pool_admission_rejects(Some(100), 4, 0, 20, 6), "104 > 100");
        // In-use bytes shrink the remaining budget.
        assert!(pool_admission_rejects(Some(100), 4, 60, 5, 5));
        assert!(!pool_admission_rejects(Some(100), 4, 60, 5, 4));
        // Saturation: over-reserved pool admits nothing with a cost.
        assert!(pool_admission_rejects(Some(100), 4, 200, 1, 0));
    }

    #[test]
    fn max_new_zero_is_clamped_before_the_pool_estimate() {
        // The shard always reserves for >= 1 decode token; the router's
        // byte estimate must gate the same clamped value, not the raw
        // request.  16-token prompt at 4 B/token: (16 + 1) * 4 = 68 B can
        // never fit a 64 B pool, even though the raw max_new = 0 estimate
        // (64 B) would have slipped through.
        let pool = ServePool::start(dead_worker_cfg(Some(64)), 1);
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        let resp = pool
            .submit(Request::greedy(1, &"x".repeat(16), 0))
            .expect("router replies directly");
        assert!(resp.text.contains("pool budget"), "{}", resp.text);
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        // One token smaller and the clamped estimate fits exactly — the
        // request passes the gate (and then dies on the dead worker).
        assert!(pool.submit(Request::greedy(2, &"x".repeat(15), 0)).is_err());
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn router_rejects_oversized_requests_before_any_worker() {
        let pool = ServePool::start(dead_worker_cfg(Some(1024)), 2);
        // Simulate one worker having published its cache geometry.
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        // (2000 + 16) * 4 bytes can never fit a 1024-byte pool: rejected at
        // the router even though every worker is dead.
        let big = Request::greedy(1, &"x".repeat(2000), 16);
        let resp = pool.submit(big).expect("router replies directly");
        assert!(resp.text.contains("pool budget"), "{}", resp.text);
        assert_eq!(resp.gen_tokens, 0);
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert_eq!(pool.metrics.requests_rejected(), 1);
        // A small request passes the gate and then surfaces the dead-worker
        // error instead.
        assert!(pool.submit(Request::greedy(2, "hi", 1)).is_err());
        // Once a worker publishes its prefill ceiling, the estimate clamps
        // to it: the same huge prompt trims to (64 + 16) * 4 = 320 B, fits
        // the 1024 B pool, and reaches the (dead) workers instead of being
        // router-rejected.
        pool.metrics.worker(0).max_prompt_tokens.observe_max(64);
        assert!(pool.submit(Request::greedy(3, &"x".repeat(2000), 16)).is_err());
        assert_eq!(
            pool.metrics.router_rejected.get(),
            1,
            "trimmed estimate must not be rejected again"
        );
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn router_rejection_is_a_failed_event_on_the_stream() {
        let pool = ServePool::start(dead_worker_cfg(Some(64)), 1);
        pool.metrics.worker(0).bytes_per_token.observe_max(4);
        let h = pool
            .submit_stream(Request::greedy(7, &"x".repeat(100), 4))
            .expect("router replies directly");
        assert_eq!(h.id(), 7);
        match h.recv().expect("one terminal event") {
            Event::Failed { id, reason } => {
                assert_eq!(id, 7);
                assert!(reason.contains("pool budget"), "{reason}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // cancel on a router-terminated stream is a harmless no-op.
        h.cancel();
        assert_eq!(pool.metrics.router_rejected.get(), 1);
        assert!(pool.shutdown().is_err());
    }

    #[test]
    fn session_requests_route_by_affinity_hash() {
        let pool = ServePool::start(dead_worker_cfg(None), 3);
        // Deterministic ring position, independent of load.
        assert_eq!(pool.pick_session_worker(0), Some(0));
        assert_eq!(pool.pick_session_worker(4), Some(1));
        assert_eq!(pool.pick_session_worker(5), Some(2));
        assert_eq!(
            pool.pick_session_worker(3),
            pool.pick_session_worker(3),
            "same session id always maps to the same worker"
        );
        // Dead workers are skipped by scanning forward on the ring.
        pool.workers[1].alive.store(false, Ordering::Relaxed);
        assert_eq!(pool.pick_session_worker(4), Some(2));
        pool.workers[2].alive.store(false, Ordering::Relaxed);
        assert_eq!(pool.pick_session_worker(4), Some(0));
        pool.workers[0].alive.store(false, Ordering::Relaxed);
        assert_eq!(pool.pick_session_worker(4), None, "all dead");
        // With every worker dead the submission errors instead of hanging.
        assert!(pool
            .submit_stream(Request::greedy(1, "x", 2).in_session(4))
            .is_err());
        assert!(pool.shutdown().is_err());
    }
}

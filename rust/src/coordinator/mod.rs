//! Serving coordinator: request routing, continuous batching and the decode
//! scheduler — the Layer-3 system that turns the paper's quantized cache
//! into a serving win (vLLM-router-style architecture, DESIGN.md §3.3).
//!
//! Threading model: PJRT handles are not `Send`, so the [`serve_loop`] owns
//! the [`crate::runtime::Engine`] on a dedicated thread; the TCP frontend
//! (`server`) and in-process clients talk to it over an mpsc channel.

pub mod batcher;
pub mod sampler;
pub mod serve_loop;

pub use batcher::{Batcher, SeqRun};
pub use sampler::{sample, SampleCfg};
pub use serve_loop::{serve_loop, ServeConfig, ServeHandle};

use std::sync::mpsc::Sender;

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl Request {
    pub fn greedy(id: u64, prompt: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            temperature: 0.0,
            top_k: 0,
            seed: id,
        }
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub cache_bytes: usize,
}

/// Messages into the serve loop.
pub enum Inbound {
    Submit(Request, Sender<Response>),
    /// Drain in-flight work and exit.
    Shutdown,
}

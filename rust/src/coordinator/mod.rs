//! Serving coordinator: request routing, continuous batching and the decode
//! scheduler — the Layer-3 system that turns the paper's quantized cache
//! into a serving win (vLLM-router-style architecture, DESIGN.md §3.3).
//!
//! Threading model: PJRT handles are not `Send`, so each serve-loop worker
//! owns its [`crate::runtime::Engine`] on a dedicated thread.  The sharded
//! [`pool::ServePool`] fronts N such workers with a least-loaded router;
//! the TCP frontend (`server`) and in-process clients talk to the pool over
//! per-worker mpsc channels.  [`pool::ServeHandle`] is the 1-worker case.
//!
//! Request lifecycle (v2): every request is an **event stream**.  The worker
//! pushes [`Event`]s — `Started` at admission, one `Token` per generated
//! token (the first arrives at end of prefill: that emission *is* the TTFT
//! mark), then a terminal `Done(Response)` or `Failed` — into the per-request
//! channel carried by [`Inbound::Submit`].  [`pool::StreamHandle`] is the
//! client end; `ServePool::submit`/`submit_async` survive as thin
//! drain-to-[`Response`] wrappers.  [`Inbound::Cancel`] (sent by
//! `StreamHandle::cancel`, or implied by a dropped event receiver) aborts a
//! request mid-decode: the batch lane frees immediately, the shard releases
//! its reserved blocks (completed full blocks still promote into the radix
//! index so the interrupted prefix stays warm) and the router's in-flight
//! token drops.
//!
//! Multi-turn continuation: a [`Request::session_id`] keys a per-worker
//! session table mapping the conversation so far (prompt ++ generated token
//! ids) to the radix key a follow-up turn resumes from — the client sends
//! only the new turn's text, the worker prepends the stored history, and the
//! paged cache serves the shared span from already-quantized blocks.  The
//! pool registers each session's owning worker on its first turn and pins
//! every follow-up to it.  The table is bounded ([`session::SessionTable`]):
//! LRU capacity + idle TTL, with evictions surfaced as `session_evicted`
//! failures so the client resends history instead of being silently served
//! from partial context.
//!
//! Fault tolerance (PR 5): every dispatched request travels inside an
//! [`EventSink`] whose drop hook guarantees stream termination.  If a worker
//! dies (panic or loop error) before *processing* a request, the sink
//! re-routes it through the pool supervisor to a live worker
//! (`requests_redispatched`); if the worker dies mid-flight, the sink emits
//! a terminal `Failed { retryable: true }` so the client can retry — no
//! stream ever hangs.  A per-worker death notice retires crashed workers
//! from rotation (`workers_dead`), and [`fault::FaultPlan`] scripts
//! deterministic failures (kills, holds, delays, prefill poison) for the
//! chaos suite in `rust/tests/chaos.rs`, using the engine-free
//! [`fault::SimSpec`] backend.
//!
//! Chunked, preemptible prefill (PR 6): prefill runs in fixed-token chunks
//! (`--prefill-chunk`) interleaved with decode steps, so a long prompt never
//! monopolizes its worker.  Every chunk boundary is a yield point — cancels,
//! chaos kill/hold gates and worker-death redispatch all take effect there,
//! and a request is only *begun* (in the [`EventSink`] sense) once its
//! prefill completes, so a mid-prefill worker death re-dispatches the whole
//! request to a live worker.  [`Priority`] splits traffic into `Interactive`
//! (latency-sensitive, prefill-first) and `Batch` (throughput, chunks
//! deferred while interactive prefill is pending); the router can reject
//! interactive requests whose estimated TTFT against the current chunk
//! backlog exceeds `--ttft-slo-chunks`.

pub mod batcher;
pub mod fault;
pub mod pool;
pub mod sampler;
pub mod serve_loop;
pub mod session;

pub use batcher::{Batcher, SeqRun};
pub use fault::{FaultPlan, SimSpec};
pub use pool::{CancelHandle, LoadToken, ServeHandle, ServePool, StreamHandle, WorkerLoad};
pub use sampler::{sample, SampleCfg};
pub use serve_loop::{serve_loop, ServeConfig};
pub use session::{SessionLookup, SessionTable};

use std::sync::mpsc::Sender;

/// Scheduling class of a request.  `Interactive` requests are
/// latency-sensitive: their prefill chunks run before any `Batch` prefill
/// work on the same worker, and the router may hold them to a TTFT SLO.
/// `Batch` requests are throughput traffic whose prefill chunks are
/// deferred while interactive work is pending.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    Batch,
}

impl Default for Priority {
    fn default() -> Priority {
        Priority::Interactive
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Multi-turn continuation key: a follow-up turn with the same session
    /// id resumes from the session's accumulated prompt+generated token ids
    /// (served from radix-cached blocks) and routes to the same shard.
    pub session_id: Option<u64>,
    /// Scheduling class (wire field `priority`); defaults to interactive.
    pub priority: Priority,
    /// Named quantization policy (wire field `policy`, v2.3).  `None` uses
    /// the worker's default codec; a name must match one of the pool's
    /// configured `--policies` or the request is rejected at admission.
    pub policy: Option<String>,
}

impl Request {
    pub fn greedy(id: u64, prompt: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            temperature: 0.0,
            top_k: 0,
            seed: id,
            session_id: None,
            priority: Priority::Interactive,
            policy: None,
        }
    }

    /// Attach this request to a multi-turn session.
    pub fn in_session(mut self, session_id: u64) -> Request {
        self.session_id = Some(session_id);
        self
    }

    /// Serve this request under a named quantization policy.
    pub fn with_policy(mut self, policy: &str) -> Request {
        self.policy = Some(policy.to_string());
        self
    }

    /// Mark this request as batch (throughput) traffic: its prefill chunks
    /// yield to any pending interactive prefill on the same worker.
    pub fn batch_priority(mut self) -> Request {
        self.priority = Priority::Batch;
        self
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    /// Prompt tokens served from radix-cached blocks (quantize+store was
    /// skipped for this span).
    pub prefix_hit_tokens: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    /// Time-to-first-token: request arrival at the worker to the first
    /// `Token` event (end of prefill).
    pub ttft_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub cache_bytes: usize,
}

impl Response {
    /// A terminal rejection/error reply (no tokens were produced).
    pub fn failure(id: u64, text: String) -> Response {
        Response {
            id,
            text,
            prompt_tokens: 0,
            prefix_hit_tokens: 0,
            gen_tokens: 0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            cache_bytes: 0,
        }
    }
}

/// One request-lifecycle event, pushed by the serve worker into the
/// per-request channel.  `Done` and `Failed` are terminal; exactly one of
/// them ends every stream the worker accepted.
#[derive(Clone, Debug)]
pub enum Event {
    /// The worker accepted the request and is about to admit it.
    Started { id: u64 },
    /// One generated token (`index` counts from 0; index 0 is emitted at the
    /// end of prefill).  `text` is the token's own decoded bytes — for the
    /// byte-level tokenizer, concatenating all token texts reproduces the
    /// final `Response::text` for ASCII output.
    Token { id: u64, index: usize, text: String },
    /// Terminal: the full aggregated response.
    Done(Response),
    /// Terminal: rejection, prefill failure, cancellation, session
    /// eviction/reroute, or worker death.  `retryable` tells the client
    /// whether resubmitting the identical request can succeed (transient
    /// capacity or infrastructure failure) or not (cancellation, protocol
    /// errors, and the `session_evicted` / `resend_history` signals, which
    /// require the client to resend its conversation history first).
    Failed { id: u64, reason: String, retryable: bool },
}

impl Event {
    /// True for the stream-ending variants.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done(_) | Event::Failed { .. })
    }
}

/// Messages into one serve-loop worker.  The optional [`LoadToken`] is the
/// router's in-flight marker; it is dropped (decrementing the worker's load)
/// when the request reaches any terminal state.
pub enum Inbound {
    /// A request riding inside its [`EventSink`] (request + event-stream
    /// sender + crash-recovery state).
    Submit(EventSink, Option<LoadToken>),
    /// Cancel the in-flight request with this id: free its lane, release its
    /// cache reservation (full blocks still promote) and emit
    /// [`Event::Failed`].  Unknown ids (already completed) are ignored.
    Cancel(u64),
    /// Drain in-flight work and exit.
    Shutdown,
}

/// Messages to the pool supervisor thread (worker lifecycle + recovery).
pub enum SupervisorMsg {
    /// A worker thread exited.  `clean` distinguishes an orderly shutdown
    /// from a crash (panic / loop error); only crashes count as dead.
    WorkerDied { worker: usize, clean: bool },
    /// A request died *unprocessed* with its worker: re-dispatch it to a
    /// live worker on the same event stream.  `attempts` counts dispatches
    /// so a request cannot ping-pong across dying workers forever.
    Redispatch { req: Request, events: Sender<Event>, attempts: usize },
    /// A session turn died mid-flight with its worker: scrub the session
    /// from every published directory so the client's resent-history turn
    /// places fresh instead of bouncing off the dead owner again.
    SessionLost(u64),
    /// Stop the supervisor (pool shutdown/drop).
    Stop,
}

/// One request's server-side event channel plus the crash-recovery state
/// that makes stream termination unconditional.
///
/// Invariant: every stream the router dispatched ends with exactly one
/// terminal event, on every path:
///
/// * normal processing sends `Done`/`Failed` via [`Self::send_terminal`];
/// * a worker dying with the request still *queued* (never picked up — see
///   [`Self::begin`]) re-routes the pending request through the supervisor,
///   which dispatches it to a live worker on the same channel;
/// * a worker dying with the request *mid-flight* (admitted, possibly
///   already streaming tokens) emits a terminal `Failed` from the drop
///   hook — re-running a half-streamed request would duplicate its token
///   events, so the retry decision belongs to the client.  Non-session
///   requests get `retryable: true` (resubmitting the identical line can
///   succeed); session turns get the non-retryable `resend_history` signal
///   instead, because their history died with the worker and an identical
///   resubmission could never be served correctly.
pub struct EventSink {
    id: u64,
    /// Session id of the request (kept past `begin()` so the drop hook can
    /// emit the right death signal for session turns).
    session_id: Option<u64>,
    tx: Sender<Event>,
    /// `Some` until the worker picks the request up; the redispatch payload.
    pending: Option<(Request, usize)>,
    /// Recovery route for unprocessed requests (absent for direct
    /// serve-loop callers, which fall back to the `Failed` drop path).
    supervisor: Option<Sender<SupervisorMsg>>,
    terminal: bool,
}

impl EventSink {
    /// Sink without supervisor recovery (tests / direct serve-loop callers).
    pub fn new(req: Request, tx: Sender<Event>) -> EventSink {
        EventSink {
            id: req.id,
            session_id: req.session_id,
            tx,
            pending: Some((req, 0)),
            supervisor: None,
            terminal: false,
        }
    }

    /// Sink whose unprocessed-death path re-dispatches via the supervisor.
    pub fn supervised(
        req: Request,
        tx: Sender<Event>,
        supervisor: Sender<SupervisorMsg>,
        attempts: usize,
    ) -> EventSink {
        EventSink {
            id: req.id,
            session_id: req.session_id,
            tx,
            pending: Some((req, attempts)),
            supervisor: Some(supervisor),
            terminal: false,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// The worker starts processing: takes the request out and switches the
    /// death behavior from "re-dispatch" to "fail the stream".  `None` on a
    /// second call (the request was already begun).
    ///
    /// With chunked prefill the worker defers this call until the *prefill
    /// completes*: a worker death anywhere during prefill then re-dispatches
    /// the whole request instead of failing a stream that never produced a
    /// token.  The re-dispatched request may re-emit `Started`.
    pub fn begin(&mut self) -> Option<Request> {
        self.pending.take().map(|(req, _)| req)
    }

    /// Peek at the pending request without consuming it (admission builds
    /// run state from this while `begin()` stays deferred to the end of
    /// prefill).  `None` once the request was begun.
    pub fn request(&self) -> Option<Request> {
        self.pending.as_ref().map(|(req, _)| req.clone())
    }

    /// Dismantle an *undispatched* sink (e.g. a failed channel send the
    /// caller retries inline): returns the request and suppresses every
    /// drop-hook action.
    pub fn recover(mut self) -> Option<Request> {
        self.terminal = true;
        self.pending.take().map(|(req, _)| req)
    }

    /// Send a non-terminal event; `false` when the receiver is gone (the
    /// worker treats that as an implicit cancel).
    pub fn send(&self, ev: Event) -> bool {
        debug_assert!(!ev.is_terminal(), "terminal events go through send_terminal");
        self.tx.send(ev).is_ok()
    }

    /// Send the stream's single terminal event and disarm the drop hook.
    pub fn send_terminal(&mut self, ev: Event) {
        debug_assert!(ev.is_terminal(), "non-terminal event sent as terminal");
        self.terminal = true;
        self.pending = None;
        let _ = self.tx.send(ev);
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        if self.terminal {
            return;
        }
        // Dropped without a terminal event: the owning worker died (its
        // channel queue or batcher unwound), or the message never reached a
        // worker at all.
        if let Some((req, attempts)) = self.pending.take() {
            if let Some(sup) = &self.supervisor {
                let msg = SupervisorMsg::Redispatch {
                    req,
                    events: self.tx.clone(),
                    attempts: attempts + 1,
                };
                if sup.send(msg).is_ok() {
                    return; // the supervisor owns termination now
                }
            }
        }
        // Mid-flight death.  A session turn's history died with the worker:
        // resubmitting the identical line can never be served correctly, so
        // signal resend_history (and have the supervisor scrub the session's
        // directory entry so the resent turn places fresh immediately).
        if let Some(sid) = self.session_id {
            if let Some(sup) = &self.supervisor {
                let _ = sup.send(SupervisorMsg::SessionLost(sid));
            }
            let _ = self.tx.send(Event::Failed {
                id: self.id,
                reason: format!(
                    "[resend_history: session {sid} lost with its worker; resend full history]"
                ),
                retryable: false,
            });
            return;
        }
        let _ = self.tx.send(Event::Failed {
            id: self.id,
            reason: "[error: serve worker died]".into(),
            retryable: true,
        });
    }
}

//! Serving coordinator: request routing, continuous batching and the decode
//! scheduler — the Layer-3 system that turns the paper's quantized cache
//! into a serving win (vLLM-router-style architecture, DESIGN.md §3.3).
//!
//! Threading model: PJRT handles are not `Send`, so each serve-loop worker
//! owns its [`crate::runtime::Engine`] on a dedicated thread.  The sharded
//! [`pool::ServePool`] fronts N such workers with a least-loaded router;
//! the TCP frontend (`server`) and in-process clients talk to the pool over
//! per-worker mpsc channels.  [`pool::ServeHandle`] is the 1-worker case.

pub mod batcher;
pub mod pool;
pub mod sampler;
pub mod serve_loop;

pub use batcher::{Batcher, SeqRun};
pub use pool::{LoadToken, ServeHandle, ServePool, WorkerLoad};
pub use sampler::{sample, SampleCfg};
pub use serve_loop::{serve_loop, ServeConfig};

use std::sync::mpsc::Sender;

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl Request {
    pub fn greedy(id: u64, prompt: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            temperature: 0.0,
            top_k: 0,
            seed: id,
        }
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    /// Prompt tokens served from radix-cached blocks (quantize+store was
    /// skipped for this span).
    pub prefix_hit_tokens: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub cache_bytes: usize,
}

impl Response {
    /// A terminal rejection/error reply (no tokens were produced).
    pub fn failure(id: u64, text: String) -> Response {
        Response {
            id,
            text,
            prompt_tokens: 0,
            prefix_hit_tokens: 0,
            gen_tokens: 0,
            queue_ms: 0.0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            cache_bytes: 0,
        }
    }
}

/// Messages into one serve-loop worker.  The optional [`LoadToken`] is the
/// router's in-flight marker; it is dropped (decrementing the worker's load)
/// when the request reaches any terminal state.
pub enum Inbound {
    Submit(Request, Sender<Response>, Option<LoadToken>),
    /// Drain in-flight work and exit.
    Shutdown,
}

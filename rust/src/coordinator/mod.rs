//! Serving coordinator: request routing, continuous batching and the decode
//! scheduler — the Layer-3 system that turns the paper's quantized cache
//! into a serving win (vLLM-router-style architecture, DESIGN.md §3.3).
//!
//! Threading model: PJRT handles are not `Send`, so each serve-loop worker
//! owns its [`crate::runtime::Engine`] on a dedicated thread.  The sharded
//! [`pool::ServePool`] fronts N such workers with a least-loaded router;
//! the TCP frontend (`server`) and in-process clients talk to the pool over
//! per-worker mpsc channels.  [`pool::ServeHandle`] is the 1-worker case.
//!
//! Request lifecycle (v2): every request is an **event stream**.  The worker
//! pushes [`Event`]s — `Started` at admission, one `Token` per generated
//! token (the first arrives at end of prefill: that emission *is* the TTFT
//! mark), then a terminal `Done(Response)` or `Failed` — into the per-request
//! channel carried by [`Inbound::Submit`].  [`pool::StreamHandle`] is the
//! client end; `ServePool::submit`/`submit_async` survive as thin
//! drain-to-[`Response`] wrappers.  [`Inbound::Cancel`] (sent by
//! `StreamHandle::cancel`, or implied by a dropped event receiver) aborts a
//! request mid-decode: the batch lane frees immediately, the shard releases
//! its reserved blocks (completed full blocks still promote into the radix
//! index so the interrupted prefix stays warm) and the router's in-flight
//! token drops.
//!
//! Multi-turn continuation: a [`Request::session_id`] keys a per-worker
//! session table mapping the conversation so far (prompt ++ generated token
//! ids) to the radix key a follow-up turn resumes from — the client sends
//! only the new turn's text, the worker prepends the stored history, and the
//! paged cache serves the shared span from already-quantized blocks.  The
//! pool routes session requests by affinity hash so every turn lands on the
//! shard holding those blocks.

pub mod batcher;
pub mod pool;
pub mod sampler;
pub mod serve_loop;

pub use batcher::{Batcher, SeqRun};
pub use pool::{CancelHandle, LoadToken, ServeHandle, ServePool, StreamHandle, WorkerLoad};
pub use sampler::{sample, SampleCfg};
pub use serve_loop::{serve_loop, ServeConfig};

use std::sync::mpsc::Sender;

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Multi-turn continuation key: a follow-up turn with the same session
    /// id resumes from the session's accumulated prompt+generated token ids
    /// (served from radix-cached blocks) and routes to the same shard.
    pub session_id: Option<u64>,
}

impl Request {
    pub fn greedy(id: u64, prompt: &str, max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            temperature: 0.0,
            top_k: 0,
            seed: id,
            session_id: None,
        }
    }

    /// Attach this request to a multi-turn session.
    pub fn in_session(mut self, session_id: u64) -> Request {
        self.session_id = Some(session_id);
        self
    }
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    /// Prompt tokens served from radix-cached blocks (quantize+store was
    /// skipped for this span).
    pub prefix_hit_tokens: usize,
    pub gen_tokens: usize,
    pub queue_ms: f64,
    /// Time-to-first-token: request arrival at the worker to the first
    /// `Token` event (end of prefill).
    pub ttft_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub cache_bytes: usize,
}

impl Response {
    /// A terminal rejection/error reply (no tokens were produced).
    pub fn failure(id: u64, text: String) -> Response {
        Response {
            id,
            text,
            prompt_tokens: 0,
            prefix_hit_tokens: 0,
            gen_tokens: 0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            cache_bytes: 0,
        }
    }
}

/// One request-lifecycle event, pushed by the serve worker into the
/// per-request channel.  `Done` and `Failed` are terminal; exactly one of
/// them ends every stream the worker accepted.
#[derive(Clone, Debug)]
pub enum Event {
    /// The worker accepted the request and is about to admit it.
    Started { id: u64 },
    /// One generated token (`index` counts from 0; index 0 is emitted at the
    /// end of prefill).  `text` is the token's own decoded bytes — for the
    /// byte-level tokenizer, concatenating all token texts reproduces the
    /// final `Response::text` for ASCII output.
    Token { id: u64, index: usize, text: String },
    /// Terminal: the full aggregated response.
    Done(Response),
    /// Terminal: rejection, prefill failure, or cancellation.
    Failed { id: u64, reason: String },
}

impl Event {
    /// True for the stream-ending variants.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done(_) | Event::Failed { .. })
    }
}

/// Messages into one serve-loop worker.  The optional [`LoadToken`] is the
/// router's in-flight marker; it is dropped (decrementing the worker's load)
/// when the request reaches any terminal state.
pub enum Inbound {
    /// A request plus its event stream's sender.
    Submit(Request, Sender<Event>, Option<LoadToken>),
    /// Cancel the in-flight request with this id: free its lane, release its
    /// cache reservation (full blocks still promote) and emit
    /// [`Event::Failed`].  Unknown ids (already completed) are ignored.
    Cancel(u64),
    /// Drain in-flight work and exit.
    Shutdown,
}

//! Token sampling: greedy / temperature / top-k, allocation-light.

use crate::util::rng::Pcg64;

/// Sampling configuration; `temperature == 0` means greedy.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_k: usize,
}

impl SampleCfg {
    pub fn greedy() -> SampleCfg {
        SampleCfg { temperature: 0.0, top_k: 0 }
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], cfg: SampleCfg, rng: &mut Pcg64) -> i32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k restriction (0 = all).
    let k = if cfg.top_k == 0 { logits.len() } else { cfg.top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap()
    });
    idx.truncate(k);
    // Softmax over the candidate set at the given temperature.
    let inv_t = 1.0 / cfg.temperature;
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) * inv_t) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)] as i32
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Pcg64::seed(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(sample(&logits, SampleCfg::greedy(), &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Pcg64::seed(1);
        let logits = vec![5.0, 4.9, -100.0, -100.0];
        for _ in 0..50 {
            let t = sample(&logits, SampleCfg { temperature: 1.0, top_k: 2 }, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Pcg64::seed(2);
        let logits = vec![1.0, 0.0, 0.5];
        let picks: Vec<i32> = (0..100)
            .map(|_| sample(&logits, SampleCfg { temperature: 0.05, top_k: 0 }, &mut rng))
            .collect();
        assert!(picks.iter().filter(|&&t| t == 0).count() > 95);
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Pcg64::seed(3);
        let logits = vec![1.0, 0.9, 0.8];
        let mut seen = [false; 3];
        for _ in 0..200 {
            let t = sample(&logits, SampleCfg { temperature: 5.0, top_k: 0 }, &mut rng);
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}

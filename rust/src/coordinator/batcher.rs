//! Continuous batcher: slot lifecycle, priority admission, chunked-prefill
//! queue state and step bookkeeping.
//!
//! The batcher is engine-agnostic (it never touches PJRT), which makes its
//! invariants property-testable: priority admission (interactive before
//! batch, FIFO within a class), no token loss, slot conservation, and
//! cache-byte accounting (see tests).  `serve_loop` binds it to the real
//! decode artifacts.
//!
//! Chunked prefill: a queued [`SeqRun`] carries an optional
//! [`PrefillState`] while its prompt is still being quantized+stored chunk
//! by chunk.  Such runs are *not admissible* into a decode lane; the serve
//! loop advances one chunk per scheduler iteration
//! ([`Batcher::next_prefill_index`] picks whose) and clears the state when
//! the prompt is fully cached, at which point ordinary admission takes
//! over.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::kvcache::{CacheGeom, PagedSeqCache};
use crate::metrics::ServeMetrics;
use crate::tensor::TensorF;

use super::pool::LoadToken;
use super::{EventSink, Priority, Request};

/// Resumable chunked-prefill progress, held on a queued run.  The serve
/// loop advances it one `--prefill-chunk` token span at a time; every
/// boundary between advances is a yield point (cancel / chaos gates /
/// decode steps run there).
pub struct PrefillState {
    /// Prompt tokens already cached (starts at the radix-hit span).
    pub filled: usize,
    /// Chunks this run has completed.
    pub chunks: usize,
    /// Set when the first chunk starts computing.
    pub started: Option<Instant>,
    /// Accumulated chunk compute time (becomes the response's prefill_ms;
    /// queue time between chunks is excluded on purpose).
    pub work_ms: f64,
    /// Mode-specific artifact output needed to sample the first token,
    /// produced by the first chunk (`None` on the sim backend).
    pub seed: Option<PrefillSeed>,
}

impl PrefillState {
    pub fn new(filled: usize) -> PrefillState {
        PrefillState { filled, chunks: 0, started: None, work_ms: 0.0, seed: None }
    }
}

/// What survives the single full-prompt artifact run that CQ/FP prefill
/// still performs (the model forward is not incremental — only
/// quantize+store is chunked): the activations to encode span by span and
/// the last-position logits row that samples the first token.
pub enum PrefillSeed {
    /// CQ: raw K/V activations for per-chunk span encoding + logits row.
    Cq { k: TensorF, v: TensorF, row: Vec<f32> },
    /// FP: the K/V seed already lives on the packed cache; only the
    /// logits row remains to carry.
    Fp { row: Vec<f32> },
}

/// Crash guard for a run's cache reservation.  If the worker panics while
/// the run is alive (mid-prefill or mid-decode), the unwind drops this
/// guard, which credits the whole reservation back to the shard's
/// released-bytes counter — the dead worker's accounting returns to its
/// idle baseline (in_use == cached) and pool-level cache sums stay
/// truthful.  Every orderly settlement path (complete / cancel / abort)
/// disarms the guard first, because the shard credits the release itself
/// there.
pub struct ReservationGuard {
    metrics: Arc<ServeMetrics>,
    bytes: u64,
    /// Named policy whose resident-byte ledger this reservation was charged
    /// to; a crash settles that ledger too, not just the shard counter.
    policy: Option<String>,
}

impl ReservationGuard {
    pub fn new(metrics: Arc<ServeMetrics>, bytes: u64) -> ReservationGuard {
        ReservationGuard { metrics, bytes, policy: None }
    }

    /// Also settle `policy`'s per-tenant byte ledger on a crash unwind.
    pub fn for_policy(mut self, policy: Option<&str>) -> ReservationGuard {
        self.policy = policy.map(str::to_string);
        self
    }

    /// Orderly settlement: the shard accounts the release itself.
    pub fn disarm(mut self) {
        self.bytes = 0;
    }
}

impl Drop for ReservationGuard {
    fn drop(&mut self) {
        if self.bytes > 0 {
            self.metrics.cache_released_bytes.add(self.bytes);
            if let Some(p) = &self.policy {
                self.metrics.policy_bytes.sub(p, self.bytes);
            }
        }
    }
}

/// One running sequence occupying a batch lane.
pub struct SeqRun {
    pub req: Request,
    /// Per-request event stream (None for headless runs); `Token` events go
    /// out as they are sampled, then one terminal `Done`/`Failed`.  The
    /// sink's drop hook guarantees a terminal event even if this run is
    /// destroyed by a worker crash (see [`EventSink`]).
    pub events: Option<EventSink>,
    /// Router in-flight marker; dropping it (with this run) decrements the
    /// owning worker's load in the serve pool.
    pub load_token: Option<LoadToken>,
    /// Pool blocks reserved at admission; settled exactly on completion
    /// (promoted blocks stay cached, the rest return to the budget).
    pub reserved_blocks: usize,
    pub prompt_tokens: usize,
    /// Prompt token ids after router trimming — the key under which the
    /// finished sequence's blocks are promoted into the radix index.
    pub prompt_ids: Vec<i32>,
    /// Prompt tokens served from cached blocks at admission (reported via
    /// `ServeMetrics::prefix_hit_tokens` and the response).
    pub prefix_hit_tokens: usize,
    /// Generated token ids (the last one is the next decode input).
    pub generated: Vec<i32>,
    pub packed: PagedSeqCache,
    pub enqueued_at: Instant,
    pub prefill_ms: f64,
    /// Arrival-to-first-token latency, fixed at the end of prefill (the
    /// first `Token` event's emission time).
    pub ttft_ms: f64,
    pub decode_started: Option<Instant>,
    /// `Some` while chunked prefill is still in progress; the run stays in
    /// the batcher queue (inadmissible) until this clears.
    pub prefill: Option<PrefillState>,
    /// Restores the shard's reservation accounting if the worker unwinds
    /// with this run alive (see [`ReservationGuard`]).
    pub crash_guard: Option<ReservationGuard>,
    /// Flight-recorder handle (None when tracing is disabled).  The
    /// recorder keeps its own `Arc` in the live map, so a crash that
    /// destroys this run still leaves the trace dumpable post-mortem.
    pub trace: Option<Arc<crate::metrics::trace::RequestTrace>>,
}

impl SeqRun {
    /// Total sequence length currently cached (prompt + generated-but-cached).
    pub fn cached_len(&self) -> usize {
        self.packed.len
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new
    }
}

/// Continuous batcher over `batch` lanes.
pub struct Batcher {
    pub batch: usize,
    pub geom: CacheGeom,
    queue: VecDeque<SeqRun>,
    slots: Vec<Option<SeqRun>>,
    pub total_admitted: usize,
    pub total_completed: usize,
}

impl Batcher {
    pub fn new(batch: usize, geom: CacheGeom) -> Batcher {
        Batcher {
            batch,
            geom,
            queue: VecDeque::new(),
            slots: (0..batch).map(|_| None).collect(),
            total_admitted: 0,
            total_completed: 0,
        }
    }

    pub fn enqueue(&mut self, run: SeqRun) {
        self.queue.push_back(run);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.active() == 0 && self.queue.is_empty()
    }

    /// Admit queued, *prefill-complete* sequences into free slots:
    /// interactive runs jump ahead of batch runs, FIFO within each class.
    /// Runs still mid-prefill stay queued.  Returns the slots filled this
    /// call so the serve loop can stage their caches.
    pub fn admit(&mut self) -> Vec<usize> {
        let mut filled = Vec::new();
        for i in 0..self.batch {
            if self.slots[i].is_none() {
                let ready = |r: &SeqRun| r.prefill.is_none();
                let pos = self
                    .queue
                    .iter()
                    .position(|r| ready(r) && r.req.priority == Priority::Interactive)
                    .or_else(|| self.queue.iter().position(ready));
                let Some(pos) = pos else { break };
                let run = self.queue.remove(pos).expect("position within queue");
                // Capacity guard: a sequence that can never fit is a
                // protocol error caught at submit time; here we only
                // check remaining room.
                debug_assert!(run.cached_len() < self.geom.tmax);
                self.slots[i] = Some(run);
                self.total_admitted += 1;
                filled.push(i);
            }
        }
        filled
    }

    /// Queue position of the next run with pending prefill work:
    /// interactive before batch, FIFO within each class.  Batch prefill
    /// chunks are thereby deferred while any interactive request still has
    /// un-prefilled prompt tokens.
    pub fn next_prefill_index(&self) -> Option<usize> {
        let pending = |r: &SeqRun| r.prefill.is_some();
        self.queue
            .iter()
            .position(|r| pending(r) && r.req.priority == Priority::Interactive)
            .or_else(|| self.queue.iter().position(pending))
    }

    /// True when any queued run of class `priority` still has prefill work.
    pub fn has_pending_prefill(&self, priority: Priority) -> bool {
        self.queue.iter().any(|r| r.prefill.is_some() && r.req.priority == priority)
    }

    /// Prompt tokens still un-prefilled across the queue (the worker
    /// publishes this as `prefill_backlog_tokens` for SLO admission).
    pub fn pending_prefill_tokens(&self) -> u64 {
        self.queue
            .iter()
            .filter_map(|r| {
                r.prefill.as_ref().map(|p| r.prompt_tokens.saturating_sub(p.filled) as u64)
            })
            .sum()
    }

    /// Every live run — queued (any prefill stage) and slotted — in no
    /// particular order.  The serve loop republishes per-iteration occupancy
    /// levels (window-pen tokens) from this instead of tracking deltas.
    pub fn runs(&self) -> impl Iterator<Item = &SeqRun> {
        self.queue.iter().chain(self.slots.iter().filter_map(Option::as_ref))
    }

    pub fn queued(&self, i: usize) -> Option<&SeqRun> {
        self.queue.get(i)
    }

    pub fn queued_mut(&mut self, i: usize) -> Option<&mut SeqRun> {
        self.queue.get_mut(i)
    }

    /// Remove a queued run by queue position (prefill-failure path).
    pub fn remove_queued(&mut self, i: usize) -> Option<SeqRun> {
        self.queue.remove(i)
    }

    pub fn slot(&self, i: usize) -> Option<&SeqRun> {
        self.slots[i].as_ref()
    }

    pub fn slot_mut(&mut self, i: usize) -> Option<&mut SeqRun> {
        self.slots[i].as_mut()
    }

    /// Occupied slot indices.
    pub fn occupied(&self) -> Vec<usize> {
        (0..self.batch).filter(|&i| self.slots[i].is_some()).collect()
    }

    /// Remove a finished sequence from its slot.
    pub fn take(&mut self, i: usize) -> Option<SeqRun> {
        self.total_completed += self.slots[i].is_some() as usize;
        self.slots[i].take()
    }

    /// A sequence must also stop when its cache lane is full.
    pub fn must_stop(&self, i: usize) -> bool {
        self.slot(i)
            .map(|r| r.done() || r.cached_len() + 1 >= self.geom.tmax)
            .unwrap_or(false)
    }

    /// Lane currently running request `id` (cancellation lookup).
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        (0..self.batch).find(|&i| {
            self.slots[i].as_ref().map(|r| r.req.id == id).unwrap_or(false)
        })
    }

    /// Remove a queued (prefilled but not yet admitted into a lane) run by
    /// request id, preserving FIFO order for the rest of the queue.
    pub fn take_queued(&mut self, id: u64) -> Option<SeqRun> {
        let pos = self.queue.iter().position(|r| r.req.id == id)?;
        self.queue.remove(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Pcg64;

    fn geom() -> CacheGeom {
        CacheGeom { n_layers: 1, n_heads: 1, groups: 2, bits: 4, tmax: 16 }
    }

    fn mk_run(id: u64, prompt_len: usize, max_new: usize) -> SeqRun {
        // Lane scheduling only depends on lengths, so the accounting-only
        // cache keeps these tests free of a block pool.
        let mut packed = PagedSeqCache::new_unstored(geom());
        for _ in 0..prompt_len {
            packed.append_unstored().unwrap();
        }
        SeqRun {
            req: Request::greedy(id, "x", max_new),
            events: None,
            load_token: None,
            reserved_blocks: 0,
            prompt_tokens: prompt_len,
            prompt_ids: vec![0; prompt_len],
            prefix_hit_tokens: 0,
            generated: vec![7],
            packed,
            enqueued_at: Instant::now(),
            prefill_ms: 0.0,
            ttft_ms: 0.0,
            decode_started: None,
            prefill: None,
            crash_guard: None,
            trace: None,
        }
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(2, geom());
        for id in 0..4 {
            b.enqueue(mk_run(id, 2, 4));
        }
        let filled = b.admit();
        assert_eq!(filled, vec![0, 1]);
        assert_eq!(b.slot(0).unwrap().req.id, 0);
        assert_eq!(b.slot(1).unwrap().req.id, 1);
        assert_eq!(b.queue_len(), 2);
        // Finish slot 0; next admit pulls request 2 into slot 0.
        b.take(0);
        let filled = b.admit();
        assert_eq!(filled, vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 2);
    }

    #[test]
    fn done_and_must_stop() {
        let mut b = Batcher::new(1, geom());
        b.enqueue(mk_run(0, 2, 2));
        b.admit();
        assert!(!b.must_stop(0));
        b.slot_mut(0).unwrap().generated.push(8);
        assert!(b.must_stop(0), "max_new reached");
        // Cache-full stop: fill the lane.
        let mut b2 = Batcher::new(1, geom());
        b2.enqueue(mk_run(1, 14, 100));
        b2.admit();
        let r = b2.slot_mut(0).unwrap();
        r.packed.append_unstored().unwrap(); // len 15, tmax 16
        assert!(b2.must_stop(0), "cache lane nearly full");
    }

    #[test]
    fn cancel_lookups_find_queued_and_slotted_runs() {
        let mut b = Batcher::new(1, geom());
        for id in 0..3 {
            b.enqueue(mk_run(id, 2, 4));
        }
        b.admit();
        assert_eq!(b.slot_of(0), Some(0), "admitted run is in its lane");
        assert_eq!(b.slot_of(1), None, "queued run is not in a lane");
        assert_eq!(b.slot_of(99), None);
        // Cancel the middle queued run; FIFO order survives for the rest.
        let run = b.take_queued(1).expect("queued run removable by id");
        assert_eq!(run.req.id, 1);
        assert!(b.take_queued(1).is_none(), "second take is a no-op");
        assert!(b.take_queued(0).is_none(), "slotted run is not in the queue");
        assert_eq!(b.queue_len(), 1);
        b.take(0);
        let filled = b.admit();
        assert_eq!(filled, vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 2, "survivor admitted in order");
    }

    #[test]
    fn mid_prefill_runs_are_not_admissible() {
        let mut b = Batcher::new(2, geom());
        let mut r0 = mk_run(0, 4, 2);
        r0.prefill = Some(PrefillState::new(1));
        b.enqueue(r0);
        b.enqueue(mk_run(1, 2, 2));
        // Only the prefill-complete run is admitted; the mid-prefill one
        // stays queued even with a free lane.
        let filled = b.admit();
        assert_eq!(filled, vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 1);
        assert_eq!(b.queue_len(), 1);
        assert_eq!(b.pending_prefill_tokens(), 3, "4 prompt - 1 filled");
        // Finishing its prefill makes it admissible.
        b.queued_mut(0).unwrap().prefill = None;
        assert_eq!(b.admit(), vec![1]);
        assert_eq!(b.slot(1).unwrap().req.id, 0);
        assert_eq!(b.pending_prefill_tokens(), 0);
    }

    #[test]
    fn prefill_scheduling_prefers_interactive_over_batch() {
        let mut b = Batcher::new(1, geom());
        let mut batch_run = mk_run(0, 6, 2);
        batch_run.req = batch_run.req.batch_priority();
        batch_run.prefill = Some(PrefillState::new(0));
        b.enqueue(batch_run);
        let mut inter = mk_run(1, 3, 2);
        inter.prefill = Some(PrefillState::new(0));
        b.enqueue(inter);
        // The interactive run's chunks run first despite arriving second.
        assert_eq!(b.next_prefill_index(), Some(1));
        assert!(b.has_pending_prefill(Priority::Batch));
        assert!(b.has_pending_prefill(Priority::Interactive));
        b.queued_mut(1).unwrap().prefill = None;
        assert_eq!(b.next_prefill_index(), Some(0), "batch resumes after");
        assert!(!b.has_pending_prefill(Priority::Interactive));
        // Admission prefers the ready interactive run too.
        assert_eq!(b.admit(), vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 1);
        // Lane full: the batch run keeps its queue spot for later.
        assert_eq!(b.queue_len(), 1);
        assert_eq!(b.remove_queued(0).unwrap().req.id, 0);
    }

    #[test]
    fn prop_slot_conservation_under_random_schedule() {
        run_prop(25, 31, |rng: &mut Pcg64| {
            let batch = 1 + rng.below(4);
            let mut b = Batcher::new(batch, geom());
            let total = 10 + rng.below(20);
            let mut submitted = 0usize;
            let mut completed = 0usize;
            let mut next_id = 0u64;
            while completed < total {
                // Random interleave of submit / step / finish.
                match rng.below(3) {
                    0 if submitted < total => {
                        b.enqueue(mk_run(next_id, 1 + rng.below(4), 1 + rng.below(3)));
                        next_id += 1;
                        submitted += 1;
                    }
                    1 => {
                        b.admit();
                    }
                    _ => {
                        for i in b.occupied() {
                            let r = b.slot_mut(i).unwrap();
                            r.generated.push(1);
                            if r.done() {
                                b.take(i);
                                completed += 1;
                            }
                        }
                    }
                }
                if b.active() > batch {
                    return Err("more active than lanes".into());
                }
                if submitted == total && b.is_idle() && completed < total {
                    // Everything admitted and finished must tally.
                    b.admit();
                    if b.is_idle() {
                        return Err(format!(
                            "lost sequences: completed {completed}/{total}"
                        ));
                    }
                }
            }
            if b.total_admitted != total {
                return Err(format!("admitted {} != {total}", b.total_admitted));
            }
            Ok(())
        });
    }
}

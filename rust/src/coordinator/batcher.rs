//! Continuous batcher: slot lifecycle + FIFO admission + step bookkeeping.
//!
//! The batcher is engine-agnostic (it never touches PJRT), which makes its
//! invariants property-testable: FIFO admission, no token loss, slot
//! conservation, and cache-byte accounting (see tests).  `serve_loop` binds
//! it to the real decode artifacts.

use std::collections::VecDeque;
use std::time::Instant;

use crate::kvcache::{CacheGeom, PagedSeqCache};

use super::pool::LoadToken;
use super::{EventSink, Request};

/// One running sequence occupying a batch lane.
pub struct SeqRun {
    pub req: Request,
    /// Per-request event stream (None for headless runs); `Token` events go
    /// out as they are sampled, then one terminal `Done`/`Failed`.  The
    /// sink's drop hook guarantees a terminal event even if this run is
    /// destroyed by a worker crash (see [`EventSink`]).
    pub events: Option<EventSink>,
    /// Router in-flight marker; dropping it (with this run) decrements the
    /// owning worker's load in the serve pool.
    pub load_token: Option<LoadToken>,
    /// Pool blocks reserved at admission; settled exactly on completion
    /// (promoted blocks stay cached, the rest return to the budget).
    pub reserved_blocks: usize,
    pub prompt_tokens: usize,
    /// Prompt token ids after router trimming — the key under which the
    /// finished sequence's blocks are promoted into the radix index.
    pub prompt_ids: Vec<i32>,
    /// Prompt tokens served from cached blocks at admission (reported via
    /// `ServeMetrics::prefix_hit_tokens` and the response).
    pub prefix_hit_tokens: usize,
    /// Generated token ids (the last one is the next decode input).
    pub generated: Vec<i32>,
    pub packed: PagedSeqCache,
    pub enqueued_at: Instant,
    pub prefill_ms: f64,
    /// Arrival-to-first-token latency, fixed at the end of prefill (the
    /// first `Token` event's emission time).
    pub ttft_ms: f64,
    pub decode_started: Option<Instant>,
}

impl SeqRun {
    /// Total sequence length currently cached (prompt + generated-but-cached).
    pub fn cached_len(&self) -> usize {
        self.packed.len
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new
    }
}

/// Continuous batcher over `batch` lanes.
pub struct Batcher {
    pub batch: usize,
    pub geom: CacheGeom,
    queue: VecDeque<SeqRun>,
    slots: Vec<Option<SeqRun>>,
    pub total_admitted: usize,
    pub total_completed: usize,
}

impl Batcher {
    pub fn new(batch: usize, geom: CacheGeom) -> Batcher {
        Batcher {
            batch,
            geom,
            queue: VecDeque::new(),
            slots: (0..batch).map(|_| None).collect(),
            total_admitted: 0,
            total_completed: 0,
        }
    }

    pub fn enqueue(&mut self, run: SeqRun) {
        self.queue.push_back(run);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.active() == 0 && self.queue.is_empty()
    }

    /// Admit queued sequences into free slots (FIFO).  Returns the slots
    /// filled this call so the serve loop can stage their caches.
    pub fn admit(&mut self) -> Vec<usize> {
        let mut filled = Vec::new();
        for i in 0..self.batch {
            if self.slots[i].is_none() {
                if let Some(run) = self.queue.pop_front() {
                    // Capacity guard: a sequence that can never fit is a
                    // protocol error caught at submit time; here we only
                    // check remaining room.
                    debug_assert!(run.cached_len() < self.geom.tmax);
                    self.slots[i] = Some(run);
                    self.total_admitted += 1;
                    filled.push(i);
                } else {
                    break;
                }
            }
        }
        filled
    }

    pub fn slot(&self, i: usize) -> Option<&SeqRun> {
        self.slots[i].as_ref()
    }

    pub fn slot_mut(&mut self, i: usize) -> Option<&mut SeqRun> {
        self.slots[i].as_mut()
    }

    /// Occupied slot indices.
    pub fn occupied(&self) -> Vec<usize> {
        (0..self.batch).filter(|&i| self.slots[i].is_some()).collect()
    }

    /// Remove a finished sequence from its slot.
    pub fn take(&mut self, i: usize) -> Option<SeqRun> {
        self.total_completed += self.slots[i].is_some() as usize;
        self.slots[i].take()
    }

    /// A sequence must also stop when its cache lane is full.
    pub fn must_stop(&self, i: usize) -> bool {
        self.slot(i)
            .map(|r| r.done() || r.cached_len() + 1 >= self.geom.tmax)
            .unwrap_or(false)
    }

    /// Lane currently running request `id` (cancellation lookup).
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        (0..self.batch).find(|&i| {
            self.slots[i].as_ref().map(|r| r.req.id == id).unwrap_or(false)
        })
    }

    /// Remove a queued (prefilled but not yet admitted into a lane) run by
    /// request id, preserving FIFO order for the rest of the queue.
    pub fn take_queued(&mut self, id: u64) -> Option<SeqRun> {
        let pos = self.queue.iter().position(|r| r.req.id == id)?;
        self.queue.remove(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Pcg64;

    fn geom() -> CacheGeom {
        CacheGeom { n_layers: 1, n_heads: 1, groups: 2, bits: 4, tmax: 16 }
    }

    fn mk_run(id: u64, prompt_len: usize, max_new: usize) -> SeqRun {
        // Lane scheduling only depends on lengths, so the accounting-only
        // cache keeps these tests free of a block pool.
        let mut packed = PagedSeqCache::new_unstored(geom());
        for _ in 0..prompt_len {
            packed.append_unstored().unwrap();
        }
        SeqRun {
            req: Request::greedy(id, "x", max_new),
            events: None,
            load_token: None,
            reserved_blocks: 0,
            prompt_tokens: prompt_len,
            prompt_ids: vec![0; prompt_len],
            prefix_hit_tokens: 0,
            generated: vec![7],
            packed,
            enqueued_at: Instant::now(),
            prefill_ms: 0.0,
            ttft_ms: 0.0,
            decode_started: None,
        }
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(2, geom());
        for id in 0..4 {
            b.enqueue(mk_run(id, 2, 4));
        }
        let filled = b.admit();
        assert_eq!(filled, vec![0, 1]);
        assert_eq!(b.slot(0).unwrap().req.id, 0);
        assert_eq!(b.slot(1).unwrap().req.id, 1);
        assert_eq!(b.queue_len(), 2);
        // Finish slot 0; next admit pulls request 2 into slot 0.
        b.take(0);
        let filled = b.admit();
        assert_eq!(filled, vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 2);
    }

    #[test]
    fn done_and_must_stop() {
        let mut b = Batcher::new(1, geom());
        b.enqueue(mk_run(0, 2, 2));
        b.admit();
        assert!(!b.must_stop(0));
        b.slot_mut(0).unwrap().generated.push(8);
        assert!(b.must_stop(0), "max_new reached");
        // Cache-full stop: fill the lane.
        let mut b2 = Batcher::new(1, geom());
        b2.enqueue(mk_run(1, 14, 100));
        b2.admit();
        let r = b2.slot_mut(0).unwrap();
        r.packed.append_unstored().unwrap(); // len 15, tmax 16
        assert!(b2.must_stop(0), "cache lane nearly full");
    }

    #[test]
    fn cancel_lookups_find_queued_and_slotted_runs() {
        let mut b = Batcher::new(1, geom());
        for id in 0..3 {
            b.enqueue(mk_run(id, 2, 4));
        }
        b.admit();
        assert_eq!(b.slot_of(0), Some(0), "admitted run is in its lane");
        assert_eq!(b.slot_of(1), None, "queued run is not in a lane");
        assert_eq!(b.slot_of(99), None);
        // Cancel the middle queued run; FIFO order survives for the rest.
        let run = b.take_queued(1).expect("queued run removable by id");
        assert_eq!(run.req.id, 1);
        assert!(b.take_queued(1).is_none(), "second take is a no-op");
        assert!(b.take_queued(0).is_none(), "slotted run is not in the queue");
        assert_eq!(b.queue_len(), 1);
        b.take(0);
        let filled = b.admit();
        assert_eq!(filled, vec![0]);
        assert_eq!(b.slot(0).unwrap().req.id, 2, "survivor admitted in order");
    }

    #[test]
    fn prop_slot_conservation_under_random_schedule() {
        run_prop(25, 31, |rng: &mut Pcg64| {
            let batch = 1 + rng.below(4);
            let mut b = Batcher::new(batch, geom());
            let total = 10 + rng.below(20);
            let mut submitted = 0usize;
            let mut completed = 0usize;
            let mut next_id = 0u64;
            while completed < total {
                // Random interleave of submit / step / finish.
                match rng.below(3) {
                    0 if submitted < total => {
                        b.enqueue(mk_run(next_id, 1 + rng.below(4), 1 + rng.below(3)));
                        next_id += 1;
                        submitted += 1;
                    }
                    1 => {
                        b.admit();
                    }
                    _ => {
                        for i in b.occupied() {
                            let r = b.slot_mut(i).unwrap();
                            r.generated.push(1);
                            if r.done() {
                                b.take(i);
                                completed += 1;
                            }
                        }
                    }
                }
                if b.active() > batch {
                    return Err("more active than lanes".into());
                }
                if submitted == total && b.is_idle() && completed < total {
                    // Everything admitted and finished must tally.
                    b.admit();
                    if b.is_idle() {
                        return Err(format!(
                            "lost sequences: completed {completed}/{total}"
                        ));
                    }
                }
            }
            if b.total_admitted != total {
                return Err(format!("admitted {} != {total}", b.total_admitted));
            }
            Ok(())
        });
    }
}

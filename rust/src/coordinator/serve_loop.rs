//! The serve loop: binds the batcher to the PJRT decode artifacts with a
//! **chunked, preemptible prefill** state machine.
//!
//! One thread owns the [`Engine`] (PJRT handles are not `Send`) and runs:
//!
//! ```text
//! loop {
//!   fault gate (hold / kill)                        (chaos harness)
//!   drain inbound -> radix match + block reserve    (admission: enqueue with
//!                  -> enqueue w/ PrefillState        a resumable PrefillState,
//!                 -> cancel: free lane/queue entry,  crash guard armed; no
//!                    release blocks + reservation    prefill work yet)
//!   advance ONE prefill chunk                       (interactive before
//!     (chunk-boundary chaos gates fire here;         batch; completion
//!      completion samples token 0 = TTFT mark)       makes run admissible)
//!   admit prefill-complete sequences into lanes     (batcher, interactive
//!   if any lane active: one fused decode step        first)
//!   sample, append codes, stream Token events,      (a dead event receiver
//!   complete finished lanes                          is an implicit cancel)
//! }
//! ```
//!
//! **Chunked prefill.** Admission no longer runs prefill inline: it
//! tokenizes, reserves blocks, and enqueues a [`SeqRun`] carrying a
//! [`super::batcher::PrefillState`] (`filled` starts at the radix-hit
//! span).  The main loop advances exactly one `--prefill-chunk`-token span
//! per iteration — quantize+store for that span only — so between any two
//! chunks the worker drains cancels, fires chaos gates, admits ready runs
//! and advances decode lanes.  A 32k-token batch prompt therefore cannot
//! monopolize the worker: a short interactive request reaches its first
//! `Token` while the long prefill is still mid-flight.  The model forward
//! itself is not incremental, so the first CQ/FP chunk performs the single
//! full-prompt artifact run and stashes its K/V + logits on the state
//! (`PrefillSeed`); the sim backend needs no seed at all.
//!
//! **Yield-point semantics.** A queued run's [`super::EventSink`] is only
//! *begun* when its prefill completes: a worker death at any chunk boundary
//! re-dispatches the whole request to a live worker (PR 5 machinery), with
//! the partial reservation credited back by the run's
//! [`super::batcher::ReservationGuard`] so the dead shard's accounting
//! returns to its idle baseline.  `Inbound::Cancel` on a mid-prefill run
//! takes effect at the next chunk boundary, rolling the partial sequence
//! back through [`PagedShard::cancel`].
//!
//! **Scheduling.** [`super::Priority`] orders both prefill chunks and lane
//! admission: interactive before batch, FIFO within a class; decode always
//! advances between chunks (decode-first within an iteration's budget).
//! `prefill_preemptions` counts interactive chunks that deferred pending
//! batch work, and the worker publishes `prefill_backlog_tokens` each
//! iteration for the router's `--ttft-slo-chunks` admission estimate.
//!
//! Every request is an event stream (see [`super::Event`]): `Started` at
//! acceptance, `Token` per sampled token — the first at end of prefill,
//! which is also the TTFT mark — then `Done` or `Failed`.  A per-worker
//! session table maps [`Request::session_id`] to the conversation's token
//! ids so a follow-up turn resumes from radix-cached blocks instead of
//! re-sending (and re-quantizing) its whole history.
//!
//! Cache representation is selected by [`ServeConfig::cq`]: `Some(tag)` uses
//! the channel-coupled quantized cache (the paper's system); `None` the fp
//! baseline.  Both run the same batcher, so the serve-throughput bench
//! isolates exactly the cache effect.  A third, engine-free **sim** backend
//! ([`ServeConfig::sim`]) runs the identical scheduler, paged shard,
//! batcher, session and cancellation machinery against a synthetic
//! deterministic model — the substrate the chaos suite injects faults into
//! on hosts without the XLA runtime.
//!
//! Fault hooks (all no-ops without a [`FaultPlan`]): the loop top passes the
//! plan's hold gate and immediate-kill check every iteration; each decode
//! step passes the step-indexed kill and slow-shard delay; every prefill
//! chunk boundary passes the chunk-indexed hold and kill gates.  Injected
//! kills are genuine panics, so recovery is exercised through real stack
//! unwinding: lane [`EventSink`]s fail their streams, channel-queued and
//! mid-prefill sinks re-dispatch via the pool supervisor.
//!
//! Sessions live in a bounded [`SessionTable`] (LRU cap + idle TTL,
//! `ServeConfig::{session_cap, session_ttl}`).  A turn referencing an
//! evicted session fails with a `session_evicted` reason instead of being
//! silently served from partial context.
//!
//! **Per-tenant policies** (`ServeConfig::policies`, wire field `policy`,
//! protocol v2.3): a request naming a policy from the worker's table is
//! admitted under *that policy's* byte math instead of the pool-wide
//! defaults — an `fp16` tenant runs unstored at the fp16 rate
//! ([`PagedShard::admit_unstored_bytes`]), a windowed tenant (e.g.
//! `cq-8c8b-w64-s4`) keeps its sink + trailing-window tokens in an fp pen
//! and quantizes them on retire ([`PagedShard::admit_retained`]; the retire
//! itself happens inside the store-phase `append`, and the loop counts it
//! via `window_retired_tokens`).  Policies are validated against the
//! backend at startup: sim serves any base (codes are fabricated), a CQ
//! worker serves only its own `cq-<tag>` base, an fp worker only `fp16`.
//! Per-policy reserved bytes are mirrored in the
//! [`crate::metrics::PolicyBytes`] ledger at admission and settled on every
//! terminal path, crash unwinding included (the run's `ReservationGuard`
//! carries the policy name).

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::tokenizer::{ByteTokenizer, Tokenizer};
use crate::kvcache::{BatchStage, CacheGeom, PagedShard, DEFAULT_BLOCK_TOKENS};
use crate::metrics::trace::{sample_decode_step, TraceEventKind, TraceOutcome};
use crate::metrics::ServeMetrics;
use crate::quant::cq::CqCodebooks;
use crate::quant::policy::PolicyTable;
use crate::quant::{factory, Codec, KvKind};
use crate::runtime::{engine::{Arg, DevBuf}, Engine, Value};
use crate::tensor::{TensorF, TensorI};
use crate::util::rng::Pcg64;
use crate::util::workpool::WorkPool;

use super::batcher::{Batcher, PrefillSeed, PrefillState, ReservationGuard, SeqRun};
use super::fault::{FaultPlan, SimSpec};
use super::pool::LoadToken;
use super::sampler::{sample, SampleCfg};
use super::session::{SessionLookup, SessionTable};
use super::{Event, EventSink, Inbound, Priority, Request, Response};

/// Token-id space of the sim backend (matches the byte tokenizer).
const SIM_VOCAB: usize = 256;

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    /// CQ tag ("2c8b" | "4c8b" | "8c8b") or None for the fp cache baseline.
    pub cq: Option<String>,
    pub batch: usize,
    /// Global cache budget in bytes (None = unlimited).  Each shard converts
    /// its split to whole blocks (floor), and the block pool enforces it as
    /// a hard allocation ceiling.
    pub cache_budget: Option<usize>,
    /// Path to learned codebooks (required when `cq` is set).
    pub codebook_path: Option<std::path::PathBuf>,
    /// Path to trained parameters.
    pub params_path: std::path::PathBuf,
    /// Decode kernel lowering: "pallas" (L1 interpret kernel) or "xla"
    /// (XLA-fused CPU fast path) — see EXPERIMENTS.md §Perf.
    pub kernel: String,
    /// Paging granularity of the block-pool cache, in tokens per block
    /// (see `kvcache::paged`; `DEFAULT_BLOCK_TOKENS` unless tuning).
    pub block_tokens: usize,
    /// Radix-tree prefix sharing across requests (CQ mode): new requests
    /// attach to already-quantized prompt-prefix blocks and skip
    /// quantize+store for the matched span.
    pub prefix_sharing: bool,
    /// Engine-free deterministic backend (chaos/fault tests): when set, the
    /// worker never touches PJRT and `params_path`/`codebook_path` are
    /// ignored.
    pub sim: Option<SimSpec>,
    /// Scripted fault-injection plan shared across the pool (tests only;
    /// `None` in production — every hook is then a no-op).
    pub faults: Option<Arc<FaultPlan>>,
    /// This worker's index in its pool (`ServePool::start` assigns it; 0
    /// for standalone loops) — the key fault hooks and logs identify the
    /// worker by.
    pub worker_index: usize,
    /// Bound on live sessions per worker; beyond it the least-recently-used
    /// session is evicted (surfaced as a `session_evicted` failure).
    pub session_cap: usize,
    /// Idle TTL for sessions (`None` = no TTL; the LRU cap still bounds the
    /// table).
    pub session_ttl: Option<Duration>,
    /// Prefill chunk size in tokens: the scheduler's yield granularity.  The
    /// loop quantizes+stores at most this many prompt tokens per iteration,
    /// so cancels, chaos gates, admissions and decode steps all interleave
    /// with a long prefill at chunk boundaries.
    pub prefill_chunk: usize,
    /// Router-side TTFT admission bound, in prefill chunks: an interactive
    /// request whose estimated time-to-first-token (pending prefill backlog
    /// plus its own prompt, divided by `prefill_chunk`) exceeds this on
    /// every live worker is rejected retryably instead of queued behind a
    /// long batch prefill.  `None` disables the gate.
    pub ttft_slo_chunks: Option<u64>,
    /// Flight-recorder ring capacity: terminal request traces retained per
    /// worker for `{"op":"trace"}` scrapes and crash post-mortems
    /// (`--trace-ring`; 0 disables per-request tracing entirely).
    pub trace_ring: usize,
    /// Persistent encode-pool width for chunked CQ prefill: threads per
    /// worker, spawned once at startup and reused for every chunk (no
    /// per-chunk thread churn).  `0` auto-sizes to
    /// `min(n_layers, available parallelism)`; `1` encodes inline on the
    /// serve thread (`--encode-threads`).
    pub encode_threads: usize,
    /// Scalar fake-quant codec for the fp baseline (`--codec <table row>`):
    /// the prefill seed K/V is quantized through this codec before staging,
    /// so the decode artifact attends over quantized prompt state while
    /// decode-written rows stay exact ("prefill-quantized, decode-fresh").
    /// Calibration-needing rows (cq-*, kvquant-*) are rejected — CQ serving
    /// selects its codec via `cq` + codebooks.  `None` = exact fp16.
    pub codec: Option<String>,
    /// Named per-tenant policy specs this pool serves (`--policies a,b,c`,
    /// syntax [`crate::quant::policy::PolicyDescriptor::parse`]).  Requests
    /// carrying a `policy` field must name one of these; an empty table
    /// rejects every policy-carrying request.
    pub policies: Vec<String>,
}

impl ServeConfig {
    /// Default kernel selection: measured on this substrate the pallas
    /// interpret lowering beats the jnp/XLA one at batch 1 (63.6 vs 91.2
    /// ms/token, EXPERIMENTS.md §Perf), so it is the default; pass "xla"
    /// for the alternative lowering.
    pub fn default_kernel() -> String {
        "pallas".to_string()
    }

    /// Default paging granularity (tokens per block).
    pub fn default_block_tokens() -> usize {
        DEFAULT_BLOCK_TOKENS
    }

    /// Default live-session bound per worker.
    pub fn default_session_cap() -> usize {
        256
    }

    /// Default prefill chunk (tokens): small enough that an interactive
    /// request waits at most one chunk of a batch prompt before its own
    /// prefill starts, large enough to amortize per-chunk staging cost.
    pub fn default_prefill_chunk() -> usize {
        512
    }

    /// Default flight-recorder ring capacity (terminal traces per worker).
    pub fn default_trace_ring() -> usize {
        crate::metrics::trace::DEFAULT_TRACE_RING
    }

    /// Default encode-pool sizing: `0` = auto (one thread per layer, capped
    /// by the machine's available parallelism, resolved at worker startup).
    pub fn default_encode_threads() -> usize {
        0
    }
}

impl Default for ServeConfig {
    /// Every knob at its default (fp16 cache, batch 1, no budget, no sim,
    /// no faults).  Callers override the fields they care about with
    /// struct-update syntax instead of re-listing the whole config.
    fn default() -> ServeConfig {
        ServeConfig {
            model: String::from("small"),
            cq: None,
            batch: 1,
            cache_budget: None,
            codebook_path: None,
            params_path: std::path::PathBuf::new(),
            kernel: ServeConfig::default_kernel(),
            block_tokens: ServeConfig::default_block_tokens(),
            prefix_sharing: true,
            sim: None,
            faults: None,
            worker_index: 0,
            session_cap: ServeConfig::default_session_cap(),
            session_ttl: None,
            prefill_chunk: ServeConfig::default_prefill_chunk(),
            ttft_slo_chunks: None,
            trace_ring: ServeConfig::default_trace_ring(),
            encode_threads: ServeConfig::default_encode_threads(),
            codec: None,
            policies: Vec::new(),
        }
    }
}

enum CacheMode {
    Cq {
        books: CqCodebooks,
        stage: BatchStage,
        /// Centroid tables resident on device (uploaded once).
        ck_buf: DevBuf,
        cv_buf: DevBuf,
        art: String,
    },
    Fp {
        k_cache: TensorF,
        v_cache: TensorF,
        pos: Vec<i32>,
        art: String,
        tmax: usize,
        /// `--codec` fake-quant: applied to the prefill seed K/V before
        /// staging (decode-written rows stay exact).
        seed_codec: Option<Box<dyn Codec>>,
    },
    /// Engine-free deterministic backend: same staging tensors and paged
    /// store as CQ, synthetic codes/logits instead of PJRT artifacts.
    Sim { stage: BatchStage },
}

/// Everything the loop needs per model.
struct Ctx {
    /// `None` in sim mode — no PJRT anywhere near the loop.
    engine: Option<Engine>,
    /// Parameter vector resident on device (uploaded once; `None` in sim).
    params_buf: Option<DevBuf>,
    mode: CacheMode,
    geom: CacheGeom,
    batch: usize,
    /// (ctx, artifact) pairs sorted ascending — bucketed prefill.
    prefills: Vec<(usize, String)>,
    head_dim: usize,
    vocab: usize,
    /// Pool worker index (fault hooks + logs).
    worker: usize,
    faults: Option<Arc<FaultPlan>>,
    /// Persistent encode pool: spawned once here, borrowed by every CQ
    /// prefill chunk, joined when the worker retires (Ctx drop).
    encode_pool: WorkPool,
}

/// Deterministic sim "quantization": per-token codes derived from the token
/// id — the same token always stores the same record, so radix sharing and
/// re-dispatch reproduce byte-identical cache state on any worker.
fn sim_codes(geom: &CacheGeom, tok: i32, k_out: &mut Vec<u32>, v_out: &mut Vec<u32>) {
    let per_side = geom.n_layers * geom.n_heads * geom.groups;
    let mask = (1u32 << geom.bits.min(31)) - 1;
    k_out.clear();
    v_out.clear();
    let t = tok as u32;
    for j in 0..per_side as u32 {
        k_out.push(t.wrapping_mul(2_654_435_761).wrapping_add(j) & mask);
        v_out.push(t.wrapping_mul(40_503).wrapping_add(j.wrapping_mul(7).wrapping_add(1)) & mask);
    }
}

/// The sim model's token-successor function: greedy decode follows a fixed
/// deterministic walk, reproducible across workers and re-dispatches.
fn sim_next(tok: i32) -> i32 {
    (tok.wrapping_mul(31).wrapping_add(17)).rem_euclid(SIM_VOCAB as i32)
}

/// Build the worker's persistent encode pool.  Threads spawn once here and
/// are reused across every prefill chunk; `encode_threads == 0` auto-sizes
/// to the layer count capped by the machine's parallelism, `1` disables
/// threading (inline encode on the serve thread).  The live thread count is
/// published as `encode_pool_threads` at construction and zeroed by the
/// pool's exit hook after drop joins the workers — chaos tests read 0 as
/// proof a retired worker's encode threads are gone.
fn build_encode_pool(cfg: &ServeConfig, n_layers: usize, metrics: &Arc<ServeMetrics>) -> WorkPool {
    let threads = match cfg.encode_threads {
        0 => {
            let avail = std::thread::available_parallelism().map_or(1, |n| n.get());
            n_layers.min(avail)
        }
        n => n,
    };
    let mut pool = WorkPool::new(threads);
    metrics.encode_pool_threads.set(pool.threads() as u64);
    let m = metrics.clone();
    pool.on_exit(move || m.encode_pool_threads.set(0));
    pool
}

/// Build the `--codec` fake-quant codec for the fp baseline, validating the
/// name against the factory table.  Calibration-needing rows have no serve
/// path here: CQ serves through `cq` + codebooks, KVQuant is eval-only.
fn build_seed_codec(cfg: &ServeConfig) -> Result<Option<Box<dyn Codec>>> {
    let Some(name) = &cfg.codec else { return Ok(None) };
    let n = name.trim().to_ascii_lowercase();
    anyhow::ensure!(
        cfg.cq.is_none() && cfg.sim.is_none(),
        "--codec is the fp-baseline fake-quant path; CQ serving selects its \
         codec via --cq, and the sim backend fabricates codes"
    );
    anyhow::ensure!(
        factory::table_rows().contains(&n.as_str()),
        "--codec '{name}' is not a table row (rows: {:?})",
        factory::table_rows()
    );
    anyhow::ensure!(
        !factory::needs_calibration(&n),
        "--codec '{name}' needs calibration; serve CQ rows via --cq and codebooks"
    );
    Ok(Some(factory::build_codec(&n, None, factory::FactoryCfg::default())?))
}

fn build_ctx(cfg: &ServeConfig, metrics: &Arc<ServeMetrics>) -> Result<Ctx> {
    let seed_codec = build_seed_codec(cfg)?;
    if let Some(sim) = &cfg.sim {
        anyhow::ensure!(
            sim.max_prompt < sim.tmax,
            "sim max_prompt ({}) must leave decode room under tmax ({})",
            sim.max_prompt,
            sim.tmax
        );
        let geom = CacheGeom {
            n_layers: sim.n_layers,
            n_heads: sim.n_heads,
            groups: sim.groups,
            bits: sim.bits,
            tmax: sim.tmax,
        };
        return Ok(Ctx {
            engine: None,
            params_buf: None,
            mode: CacheMode::Sim { stage: BatchStage::new(geom, cfg.batch) },
            geom,
            batch: cfg.batch,
            prefills: vec![(sim.max_prompt, String::from("sim"))],
            head_dim: 1,
            vocab: SIM_VOCAB,
            worker: cfg.worker_index,
            faults: cfg.faults.clone(),
            encode_pool: build_encode_pool(cfg, geom.n_layers, metrics),
        });
    }
    let engine = Engine::load_default()?;
    let mm = engine.manifest.model(&cfg.model)?.clone();
    let params = Value::F(
        TensorF::read_f32_file(&cfg.params_path, &[mm.param_count])
            .with_context(|| format!("params at {}", cfg.params_path.display()))?,
    );
    let batch = cfg.batch;
    anyhow::ensure!(
        mm.decode_batches.contains(&batch),
        "batch {batch} not compiled (available: {:?})",
        mm.decode_batches
    );
    let (mode, geom) = match &cfg.cq {
        Some(tag) => {
            let path = cfg
                .codebook_path
                .clone()
                .ok_or_else(|| anyhow!("--codebooks required for CQ serving"))?;
            let books = CqCodebooks::load(&path)?;
            anyhow::ensure!(
                books.spec.tag() == *tag,
                "codebook file is {} but serving {tag}",
                books.spec.tag()
            );
            let geom = CacheGeom {
                n_layers: mm.n_layers,
                n_heads: mm.n_heads,
                groups: books.spec.n_groups(mm.head_dim),
                bits: books.spec.bits as u32,
                tmax: mm.serve_ctx,
            };
            let stage = BatchStage::new(geom, batch);
            let ck_buf = engine.upload(&Value::F(books.export_tensor(KvKind::Key)))?;
            let cv_buf = engine.upload(&Value::F(books.export_tensor(KvKind::Value)))?;
            let kprefix = if cfg.kernel == "xla" { "xla_" } else { "" };
            let art = format!("{}.decode_cq_{kprefix}{tag}_b{batch}", cfg.model);
            engine.manifest.artifact(&art)?;
            (CacheMode::Cq { books, stage, ck_buf, cv_buf, art }, geom)
        }
        None => {
            let geom = CacheGeom {
                n_layers: mm.n_layers,
                n_heads: mm.n_heads,
                groups: mm.head_dim, // 1 channel per "group"
                bits: 16,
                tmax: mm.serve_ctx,
            };
            let shape = [mm.n_layers, batch, mm.n_heads, mm.serve_ctx, mm.head_dim];
            let art = format!("{}.decode_fp_b{batch}", cfg.model);
            engine.manifest.artifact(&art)?;
            (
                CacheMode::Fp {
                    k_cache: TensorF::zeros(&shape),
                    v_cache: TensorF::zeros(&shape),
                    pos: vec![0; batch],
                    art,
                    tmax: mm.serve_ctx,
                    seed_codec,
                },
                geom,
            )
        }
    };
    let params_buf = engine.upload(&params)?;
    // Bucketed prefill: every "<model>.prefill*" artifact, smallest first.
    let mut prefills: Vec<(usize, String)> = engine
        .manifest
        .artifacts
        .iter()
        .filter(|(k, _)| k.starts_with(&format!("{}.prefill", cfg.model)))
        .map(|(k, a)| (a.meta.num_or("ctx", 0.0) as usize, k.clone()))
        .collect();
    prefills.sort();
    anyhow::ensure!(!prefills.is_empty(), "no prefill artifact for {}", cfg.model);
    Ok(Ctx {
        engine: Some(engine),
        params_buf: Some(params_buf),
        mode,
        geom,
        batch,
        prefills,
        head_dim: mm.head_dim,
        vocab: mm.vocab,
        worker: cfg.worker_index,
        faults: cfg.faults.clone(),
        encode_pool: build_encode_pool(cfg, geom.n_layers, metrics),
    })
}

/// Tokenize + router-trim one request's prompt (sliding-window tail policy,
/// like a chat server keeping the most recent context).  A session request
/// prepends its resolved history, so the follow-up turn's effective prompt
/// is the whole conversation — and its prefix matches the blocks the
/// previous turn promoted.
fn prompt_ids(ctx: &Ctx, history: Option<&[i32]>, req: &Request) -> Vec<i32> {
    let tok = ByteTokenizer;
    let mut prompt = Vec::new();
    if let Some(hist) = history {
        prompt.extend_from_slice(hist);
    }
    prompt.extend(tok.encode(&req.prompt));
    if prompt.is_empty() {
        prompt.push(b'\n' as i32);
    }
    let max_ctx = ctx.prefills.last().unwrap().0;
    if prompt.len() > max_ctx {
        prompt = prompt[prompt.len() - max_ctx..].to_vec();
    }
    prompt
}

/// The one full-prompt artifact forward that chunked CQ/FP prefill still
/// needs (the model itself is not incremental): pick the smallest compiled
/// bucket that fits, run it, and return the last-position logits row plus
/// the raw prompt K/V activations for per-chunk quantize+store.
fn run_prefill_artifact(ctx: &Ctx, prompt: &[i32]) -> Result<(Vec<f32>, TensorF, TensorF)> {
    let p = prompt.len();
    let (bucket_ctx, art) = ctx
        .prefills
        .iter()
        .find(|(t, _)| *t >= p)
        .unwrap_or_else(|| ctx.prefills.last().unwrap());
    let mut padded = prompt.to_vec();
    padded.resize(*bucket_ctx, b' ' as i32);
    let tokens = Value::I(TensorI::from_vec(&[1, *bucket_ctx], padded)?);
    let engine = ctx.engine.as_ref().expect("engine present outside sim mode");
    let params_buf = ctx.params_buf.as_ref().expect("params resident outside sim mode");
    let out = engine
        .executable(art)?
        .run_mixed(&[Arg::B(params_buf), Arg::V(&tokens)])?;
    let logits = out[0].as_f()?;
    let row = logits.data[(p - 1) * ctx.vocab..p * ctx.vocab].to_vec();
    Ok((row, out[1].as_f()?.clone(), out[2].as_f()?.clone()))
}

/// Advance one run's prefill by up to `chunk` tokens (quantize+store for
/// that span only), mutating its [`PrefillState`] in place.  The first
/// chunk of a CQ/FP run performs the single artifact forward and stashes
/// its outputs as the state's [`PrefillSeed`]; the sim backend derives
/// codes per token and needs no seed.  Returns Ok(true) once the whole
/// prompt is cached.
fn prefill_chunk_fill(
    ctx: &Ctx,
    shard: &mut PagedShard,
    run: &mut SeqRun,
    metrics: &ServeMetrics,
    chunk: usize,
) -> Result<bool> {
    let p = run.prompt_ids.len();
    let state = run.prefill.as_mut().expect("run has pending prefill");
    if state.started.is_none() {
        state.started = Some(Instant::now());
        // Poisoned prefill (chaos) fails the first chunk, driving the same
        // rollback path a real artifact error would.
        if let Some(plan) = &ctx.faults {
            if plan.take_poison(run.req.id) {
                bail!("[chaos] poisoned prefill (request {})", run.req.id);
            }
        }
    }
    let t0 = Instant::now();
    let start = state.filled;
    let end = (state.filled + chunk.max(1)).min(p);
    match &ctx.mode {
        CacheMode::Sim { .. } if !run.packed.is_stored() => {
            // fp16-policy tenant on sim: occupancy accounting only, nothing
            // to encode or store (sim logits depend only on the last token).
            for _ in state.filled..end {
                run.packed.append_unstored()?;
            }
        }
        CacheMode::Sim { .. } => {
            // Synthetic quantize+store over this chunk's span only — the
            // radix hit skipped exactly the same tokens as in CQ serving.
            let t_enc = Instant::now();
            let (mut k, mut v) = (Vec::new(), Vec::new());
            let retired0 = run.packed.retired_tokens;
            for &t in &run.prompt_ids[state.filled..end] {
                sim_codes(&ctx.geom, t, &mut k, &mut v);
                run.packed.append(&mut shard.pool, &k, &v)?;
            }
            metrics.window_retired_tokens.add(run.packed.retired_tokens - retired0);
            metrics.phases.record_encode(t_enc.elapsed());
        }
        CacheMode::Cq { books, .. } => {
            if state.seed.is_none() {
                let (row, k, v) = run_prefill_artifact(ctx, &run.prompt_ids)?;
                state.seed = Some(PrefillSeed::Cq { k, v, row });
            }
            let Some(PrefillSeed::Cq { k, v, .. }) = &state.seed else {
                bail!("cq prefill seed missing");
            };
            // Batched encode for this chunk: (layer, token-piece) work fans
            // across the worker's persistent pool threads, each book's
            // centroid table is walked once for the span, and the codes
            // bulk-append as packed records.
            let t_enc = Instant::now();
            let (kc, vc) = books.encode_span_pooled(k, v, state.filled, end, &ctx.encode_pool);
            metrics.phases.record_encode(t_enc.elapsed());
            metrics.encode_pool_busy.set(ctx.encode_pool.last_scope_tasks());
            let retired0 = run.packed.retired_tokens;
            run.packed.append_span(&mut shard.pool, &kc, &vc, end - state.filled)?;
            metrics.window_retired_tokens.add(run.packed.retired_tokens - retired0);
        }
        CacheMode::Fp { seed_codec, .. } => {
            if state.seed.is_none() {
                let (row, mut k, mut v) = run_prefill_artifact(ctx, &run.prompt_ids)?;
                // `--codec` fake-quant ("prefill-quantized, decode-fresh"):
                // the seed is quantized once here, before staging.
                if let Some(c) = seed_codec {
                    c.apply(KvKind::Key, &mut k);
                    c.apply(KvKind::Value, &mut v);
                }
                // Stash prefill K/V for staging at admission time.
                run.packed.fp_seed = Some((k, v));
                state.seed = Some(PrefillSeed::Fp { row });
            }
            for _ in state.filled..end {
                run.packed.append_unstored()?;
            }
        }
    }
    state.filled = end;
    state.chunks += 1;
    state.work_ms += t0.elapsed().as_secs_f64() * 1e3;
    let chunk_index = state.chunks - 1;
    if let Some(t) = &run.trace {
        t.mark(TraceEventKind::PrefillChunk { index: chunk_index, tokens: end - start });
    }
    Ok(end == p)
}

/// End of prefill: sample the first token (the TTFT mark), record prefill
/// and TTFT metrics (per priority class), and switch the run's sink into
/// mid-flight mode (`begin`) — from here a worker death fails the stream
/// instead of re-dispatching a half-streamed request.
fn finish_prefill(run: &mut SeqRun, metrics: &ServeMetrics) {
    let state = run.prefill.take().expect("prefill completes exactly once");
    let first = match &state.seed {
        None => sim_next(*run.prompt_ids.last().expect("non-empty prompt")),
        Some(PrefillSeed::Cq { row, .. }) | Some(PrefillSeed::Fp { row }) => {
            let mut rng = Pcg64::seed(run.req.seed);
            sample(
                row,
                SampleCfg { temperature: run.req.temperature, top_k: run.req.top_k },
                &mut rng,
            )
        }
    };
    run.generated.push(first);
    run.prefill_ms = state.work_ms;
    metrics
        .prefill_latency
        .record(Duration::from_secs_f64(state.work_ms / 1e3));
    let ttft = run.enqueued_at.elapsed();
    run.ttft_ms = ttft.as_secs_f64() * 1e3;
    metrics.ttft.record(ttft);
    match run.req.priority {
        Priority::Interactive => metrics.ttft_interactive.record(ttft),
        Priority::Batch => metrics.ttft_batch.record(ttft),
    }
    if let Some(t) = &run.trace {
        t.mark(TraceEventKind::FirstToken);
    }
    if let Some(sink) = run.events.as_mut() {
        let _ = sink.begin();
        // First token: streamed before the run ever waits on a decode lane.
        let _ = sink.send(Event::Token {
            id: run.req.id,
            index: 0,
            text: ByteTokenizer.decode(&run.generated[..1]),
        });
    }
}

/// Advance chunked prefill by ONE chunk — the scheduler's yield
/// granularity.  Picks the next run (interactive before batch, FIFO within
/// a class), fires the chunk-boundary chaos gates against the worker's
/// lifetime chunk counter, computes the chunk, and on prompt completion
/// finishes the run (first token + TTFT + lane admissibility).  A failed
/// chunk rolls the whole admission back (blocks + reservation returned)
/// and fails the stream.  Returns true if any prefill work was done.
fn advance_prefill(
    ctx: &Ctx,
    shard: &mut PagedShard,
    batcher: &mut Batcher,
    metrics: &ServeMetrics,
    chunk_tokens: usize,
    prefill_chunks: &mut u64,
) -> bool {
    let Some(qi) = batcher.next_prefill_index() else {
        return false;
    };
    // Chunk-boundary chaos gates fire BEFORE the chunk is computed: a hold
    // parks the worker with the chunk still pending, a kill panics at the
    // exact boundary — both observe the same worker-lifetime chunk index.
    if let Some(plan) = &ctx.faults {
        plan.prefill_chunk_gate(ctx.worker, *prefill_chunks);
        if plan.take_kill_at_prefill_chunk(ctx.worker, *prefill_chunks) {
            panic!(
                "[chaos] worker {} killed at prefill chunk {}",
                ctx.worker, *prefill_chunks
            );
        }
    }
    let preempts = {
        let run = batcher.queued(qi).expect("prefill index in queue");
        run.req.priority == Priority::Interactive && batcher.has_pending_prefill(Priority::Batch)
    };
    let run = batcher.queued_mut(qi).expect("prefill index in queue");
    match prefill_chunk_fill(ctx, shard, run, metrics, chunk_tokens) {
        Ok(done) => {
            if done {
                finish_prefill(run, metrics);
            }
            *prefill_chunks += 1;
            metrics.prefill_chunks.add(1);
            if preempts {
                metrics.prefill_preemptions.add(1);
            }
            true
        }
        Err(e) => {
            log::error!("prefill failed: {e:#}");
            let mut run = batcher.remove_queued(qi).expect("prefill index in queue");
            shard.abort(&mut run.packed, run.reserved_blocks, metrics);
            if let Some(g) = run.crash_guard.take() {
                g.disarm();
            }
            settle_policy_bytes(metrics, &run);
            if let Some(t) = run.trace.take() {
                metrics.trace.settle(&t, TraceOutcome::Failed, &format!("prefill failed: {e:#}"));
            }
            // Explicit error reply (like the rejection path) so pipelined
            // TCP clients keep their connection instead of a dropped-channel
            // error tearing it down.
            if let Some(mut sink) = run.events.take() {
                sink.send_terminal(Event::Failed {
                    id: run.req.id,
                    reason: format!("[error: prefill failed: {e:#}]"),
                    retryable: false,
                });
            }
            true
        }
    }
}

/// Router admission for one inbound request: resolve its session (failing
/// evicted sessions with the `session_evicted` signal), match the prompt
/// (with any history prepended) against this shard's radix index, reserve
/// blocks (evicting cold cached prefixes under pressure), and enqueue with
/// a fresh [`PrefillState`] — NO prefill work happens here; the main loop
/// advances it chunk by chunk.  Lifecycle events: `Started` on acceptance,
/// the first `Token` at end of prefill (TTFT), `Failed` on rejection or
/// prefill error.  The [`LoadToken`] rides in the `SeqRun` so the pool's
/// in-flight count drops on every terminal path.
fn admit_request(
    ctx: &Ctx,
    shard: &mut PagedShard,
    batcher: &mut Batcher,
    sessions: &mut SessionTable,
    policies: &PolicyTable,
    metrics: &Arc<ServeMetrics>,
    mut sink: EventSink,
    token: Option<LoadToken>,
) {
    // Peek, don't `begin()`: the sink stays channel-armed until prefill
    // completes, so a worker death anywhere mid-prefill re-dispatches the
    // whole request instead of failing a stream that never saw a token.
    let Some(mut req) = sink.request() else { return };
    let arrived = Instant::now();
    let _ = sink.send(Event::Started { id: req.id });
    // The decode loop always appends at least one token before `must_stop`
    // is consulted, so max_new = 0 would under-reserve by one block and the
    // unbacked append could fail mid-decode; serve at least one token.
    // `ServePool::submit_stream` already clamps before its pool-wide byte
    // estimate — this repeat only covers callers driving a serve loop
    // directly, so router estimate and shard reservation always agree.
    req.max_new = req.max_new.max(1);
    let history: Option<&[i32]> = match req.session_id {
        None => None,
        Some(sid) => match sessions.lookup(sid, metrics) {
            SessionLookup::Hit(ids) => Some(ids),
            SessionLookup::New => None,
            SessionLookup::Evicted => {
                // Serving only the new turn's text would silently answer
                // from partial context; make the client resend history.
                sink.send_terminal(Event::Failed {
                    id: req.id,
                    reason: format!("[session_evicted: session {sid} expired; resend history]"),
                    retryable: false,
                });
                return;
            }
        },
    };
    // Resolve the request's named policy before touching the shard: an
    // unknown name is a client error (non-retryable), not cache pressure.
    let policy = match req.policy.as_deref() {
        None => None,
        Some(name) => match policies.get(name) {
            Some(d) => Some(d),
            None => {
                metrics.requests_rejected.add(1);
                sink.send_terminal(Event::Failed {
                    id: req.id,
                    reason: format!(
                        "[rejected: unknown policy '{name}' (serving: {:?})]",
                        policies.names()
                    ),
                    retryable: false,
                });
                return;
            }
        },
    };
    let prompt = prompt_ids(ctx, history, &req);
    // Flight recorder: the trace starts at enqueue and survives this run
    // (the recorder holds its own Arc) so a crash still leaves a record.
    let trace = metrics.trace.begin(
        req.id,
        match req.priority {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        },
        prompt.len(),
    );
    // Per-request admission math: a policy-carrying request reserves at ITS
    // byte rates, not the pool-wide default (ISSUE: `bytes_per_token` is
    // per-request now).  Startup validation guarantees the backend can
    // execute whatever policy reaches this match.
    let fp_bpt = ctx.geom.fp16_bytes_per_token(ctx.head_dim);
    let admitted = match (&ctx.mode, policy) {
        // fp16 tenant: unstored accounting at the fp16 rate.
        (_, Some(d)) if d.is_fp() => {
            shard.admit_unstored_bytes(prompt.len(), req.max_new, fp_bpt, metrics)
        }
        // Windowed tenant: fp pen for sinks + trailing window, mixed-rate
        // reservation; tokens quantize-on-retire inside `append`.
        (CacheMode::Cq { .. } | CacheMode::Sim { .. }, Some(d)) if d.retention().is_some() => {
            let r = d.retention().expect("guard checked retention");
            shard.admit_retained(prompt.len(), req.max_new, r, fp_bpt, metrics)
        }
        (CacheMode::Fp { .. }, _) => shard.admit_unstored(prompt.len(), req.max_new, metrics),
        (CacheMode::Cq { .. } | CacheMode::Sim { .. }, _) => {
            shard.admit_stored(&prompt, req.max_new, metrics)
        }
    };
    let adm = match admitted {
        Ok(adm) => adm,
        Err(_) => {
            metrics.requests_rejected.add(1);
            if let Some(t) = &trace {
                metrics.trace.settle(t, TraceOutcome::Failed, "rejected: cache budget");
            }
            sink.send_terminal(Event::Failed {
                id: req.id,
                reason: "[rejected: cache budget]".into(),
                retryable: true,
            });
            return; // token drops here -> router sees the slot free again
        }
    };
    // Radix compute-skip: the matched prefix is admitted already encoded —
    // `PrefillState::new(hit_tokens)` below starts `filled` past it, so
    // prefill performs zero centroid assignments for the span.  (Fp-mode
    // admissions don't share and always report a zero hit.)
    metrics.prefill_tokens_skipped.add(adm.hit_tokens as u64);
    // The crash guard mirrors the shard's reservation: if this worker dies
    // before the run settles through finish/cancel/abort, the guard's
    // unwind-time credit returns the partial reservation so the dead
    // shard's accounting reads idle again.  (`block_bytes` was published
    // as a gauge before the loop started serving.)
    let reserved_bytes = adm.reserved_blocks as u64 * metrics.block_bytes.get();
    // Mirror the reservation in the per-policy ledger; every terminal path
    // (finish/cancel/abort/crash-unwind) settles it back out.
    if let Some(p) = &req.policy {
        metrics.policy_bytes.add(p, reserved_bytes);
    }
    let guard = ReservationGuard::new(metrics.clone(), reserved_bytes)
        .for_policy(req.policy.as_deref());
    batcher.enqueue(SeqRun {
        req,
        events: Some(sink),
        load_token: token,
        reserved_blocks: adm.reserved_blocks,
        prompt_tokens: prompt.len(),
        prompt_ids: prompt,
        prefix_hit_tokens: adm.hit_tokens,
        generated: Vec::new(),
        packed: adm.seq,
        enqueued_at: arrived,
        prefill_ms: 0.0,
        ttft_ms: 0.0,
        decode_started: None,
        prefill: Some(PrefillState::new(adm.hit_tokens)),
        crash_guard: Some(guard),
        trace,
    });
}

/// Stage a newly admitted sequence into its lane.  Shared prefix blocks and
/// privately quantized tokens alike are read out of the shard's block pool.
fn stage_admitted(ctx: &mut Ctx, shard: &PagedShard, slot: usize, batcher: &Batcher) {
    let run = batcher.slot(slot).expect("admitted slot");
    match &mut ctx.mode {
        CacheMode::Cq { stage, .. } | CacheMode::Sim { stage } => {
            if run.packed.is_stored() {
                // load_sequence leaves pos at the next write position.
                // (Retention pens unpack through the same read path.)
                stage.load_sequence(slot, &run.packed, &shard.pool);
            } else {
                // fp16-policy tenant (sim): no pool-backed codes to load.
                stage.mark_occupied(slot, run.packed.len);
            }
        }
        CacheMode::Fp { k_cache, v_cache, pos, tmax, .. } => {
            let (k, v) = run.packed.fp_seed.as_ref().expect("fp prefill seed");
            let d = crate::quant::KvDims::of(k);
            let hd = d.hd;
            let b = ctx.batch;
            for l in 0..d.l {
                for h in 0..d.h {
                    for t in 0..run.packed.len {
                        let src = d.vec_off(l, 0, h, t);
                        let dst = (((l * b + slot) * d.h + h) * *tmax + t) * hd;
                        k_cache.data[dst..dst + hd].copy_from_slice(&k.data[src..src + hd]);
                        v_cache.data[dst..dst + hd].copy_from_slice(&v.data[src..src + hd]);
                    }
                }
            }
            pos[slot] = run.packed.len as i32;
        }
    }
}

/// Reusable per-token code buffers for the decode hot loop: staging
/// write-back and paged-store append run allocation-free across steps.
#[derive(Default)]
struct CodeScratch {
    kc: Vec<u32>,
    vc: Vec<u32>,
}

/// One fused decode step over all lanes.  Returns per-slot logits rows.
fn decode_step(
    ctx: &mut Ctx,
    batcher: &Batcher,
    scratch: &mut CodeScratch,
) -> Result<Vec<Vec<f32>>> {
    let b = ctx.batch;
    let mut tok = vec![0i32; b];
    let mut pos = vec![0i32; b];
    for i in batcher.occupied() {
        let run = batcher.slot(i).unwrap();
        tok[i] = *run.generated.last().unwrap();
        pos[i] = run.packed.len as i32;
    }
    // Both vectors are still read below (pos by apply_updates, tok by the
    // sim decode arm), so the tensors take clones.
    let pos_t = Value::I(TensorI::from_vec(&[b], pos.clone())?);
    let tok_t = Value::I(TensorI::from_vec(&[b], tok.clone())?);

    let (logits, updates) = match &ctx.mode {
        CacheMode::Cq { stage, ck_buf, cv_buf, art, .. } => {
            // Staging code tensors are moved (not cloned): run_mixed borrows.
            let kc = Value::I(stage.k_codes.clone());
            let vc = Value::I(stage.v_codes.clone());
            let engine = ctx.engine.as_ref().expect("engine present in cq mode");
            let params_buf = ctx.params_buf.as_ref().expect("params resident in cq mode");
            let out = engine.executable(art)?.run_mixed(&[
                Arg::B(params_buf),
                Arg::B(ck_buf),
                Arg::B(cv_buf),
                Arg::V(&kc),
                Arg::V(&vc),
                Arg::V(&pos_t),
                Arg::V(&tok_t),
            ])?;
            let logits = out[0].as_f()?.clone();
            let kn = out[1].as_i()?.clone();
            let vn = out[2].as_i()?.clone();
            (logits, StepUpdate::Cq(kn, vn))
        }
        CacheMode::Fp { k_cache, v_cache, art, .. } => {
            let kc = Value::F(k_cache.clone());
            let vc = Value::F(v_cache.clone());
            let engine = ctx.engine.as_ref().expect("engine present in fp mode");
            let params_buf = ctx.params_buf.as_ref().expect("params resident in fp mode");
            let out = engine.executable(art)?.run_mixed(&[
                Arg::B(params_buf),
                Arg::V(&kc),
                Arg::V(&vc),
                Arg::V(&pos_t),
                Arg::V(&tok_t),
            ])?;
            let logits = out[0].as_f()?.clone();
            let kn = out[1].as_f()?.clone();
            let vn = out[2].as_f()?.clone();
            (logits, StepUpdate::Fp(kn, vn))
        }
        CacheMode::Sim { .. } => {
            // Emulate the decode artifact's contract exactly: new KV codes
            // `[L, B, H, G]` for each lane's input token plus a one-hot
            // logits row at its deterministic successor.
            let (l_n, h_n, g_n) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.geom.groups);
            let mut kn = vec![0i32; l_n * b * h_n * g_n];
            let mut vn = vec![0i32; l_n * b * h_n * g_n];
            let mut lg = vec![0f32; b * ctx.vocab];
            let (mut ks, mut vs) = (Vec::new(), Vec::new());
            for i in batcher.occupied() {
                sim_codes(&ctx.geom, tok[i], &mut ks, &mut vs);
                for l in 0..l_n {
                    for h in 0..h_n {
                        let dst = ((l * b + i) * h_n + h) * g_n;
                        let src = (l * h_n + h) * g_n;
                        for g in 0..g_n {
                            kn[dst + g] = ks[src + g] as i32;
                            vn[dst + g] = vs[src + g] as i32;
                        }
                    }
                }
                lg[i * ctx.vocab + sim_next(tok[i]) as usize] = 1.0;
            }
            let logits = TensorF::from_vec(&[b, ctx.vocab], lg)?;
            let kn = TensorI::from_vec(&[l_n, b, h_n, g_n], kn)?;
            let vn = TensorI::from_vec(&[l_n, b, h_n, g_n], vn)?;
            (logits, StepUpdate::Cq(kn, vn))
        }
    };

    // Apply cache updates for occupied lanes.
    apply_updates(ctx, batcher, &pos, updates, scratch)?;

    let v = ctx.vocab;
    Ok((0..b)
        .map(|i| logits.data[i * v..(i + 1) * v].to_vec())
        .collect())
}

enum StepUpdate {
    /// New codes `[L, B, H, G]` for keys and values.
    Cq(TensorI, TensorI),
    /// New rows `[L, B, H, hd]`.
    Fp(TensorF, TensorF),
}

fn apply_updates(
    ctx: &mut Ctx,
    batcher: &Batcher,
    pos: &[i32],
    up: StepUpdate,
    scratch: &mut CodeScratch,
) -> Result<()> {
    let b = ctx.batch;
    match (&mut ctx.mode, up) {
        (CacheMode::Cq { stage, .. }, StepUpdate::Cq(kn, vn))
        | (CacheMode::Sim { stage }, StepUpdate::Cq(kn, vn)) => {
            let (l_n, h_n, g_n) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.geom.groups);
            for i in batcher.occupied() {
                let t = pos[i] as usize;
                scratch.kc.clear();
                scratch.vc.clear();
                for l in 0..l_n {
                    for h in 0..h_n {
                        let off = ((l * b + i) * h_n + h) * g_n;
                        for g in 0..g_n {
                            scratch.kc.push(kn.data[off + g] as u32);
                            scratch.vc.push(vn.data[off + g] as u32);
                        }
                    }
                }
                stage.write_token(i, t, &scratch.kc, &scratch.vc);
                stage.pos[i] = (t + 1) as i32;
            }
            Ok(())
        }
        (CacheMode::Fp { k_cache, v_cache, tmax, pos: fpos, .. }, StepUpdate::Fp(kn, vn)) => {
            let _ = &fpos;
            let (l_n, h_n, hd) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.head_dim);
            for i in batcher.occupied() {
                let t = pos[i] as usize;
                for l in 0..l_n {
                    for h in 0..h_n {
                        let src = ((l * b + i) * h_n + h) * hd;
                        let dst = (((l * b + i) * h_n + h) * *tmax + t) * hd;
                        k_cache.data[dst..dst + hd]
                            .copy_from_slice(&kn.data[src..src + hd]);
                        v_cache.data[dst..dst + hd]
                            .copy_from_slice(&vn.data[src..src + hd]);
                    }
                }
                fpos[i] = (t + 1) as i32;
            }
            Ok(())
        }
        _ => bail!("cache mode / update mismatch"),
    }
}

/// Build and validate the worker's policy table against its backend.  The
/// sim backend fabricates codes, so any base serves; an engine worker can
/// only serve policies its compiled decode artifact can actually execute —
/// a CQ worker its own `cq-<tag>` base (retention suffixes ride along: the
/// retire path packs the same wire codes), an fp worker only `fp16`.
pub fn build_policy_table(cfg: &ServeConfig) -> Result<PolicyTable> {
    let table = PolicyTable::build(&cfg.policies)?;
    if cfg.sim.is_some() {
        return Ok(table);
    }
    for name in table.names() {
        let d = table.get(name).expect("name came from the table");
        match (&cfg.cq, d.is_fp()) {
            (Some(_), true) => bail!(
                "policy '{name}': this worker decodes the CQ artifact and cannot \
                 serve fp16 tenants (route them to an fp worker)"
            ),
            (Some(tag), false) => anyhow::ensure!(
                d.base == format!("cq-{tag}"),
                "policy '{name}': base '{}' does not match this worker's wire \
                 codec 'cq-{tag}'",
                d.base
            ),
            (None, true) => {}
            (None, false) => bail!(
                "policy '{name}': an fp worker serves only the 'fp16' policy \
                 (quantized bases need a CQ or sim worker)"
            ),
        }
    }
    Ok(table)
}

/// Return a settled run's reserved bytes to its policy ledger.  The shard
/// settles the block accounting itself; this mirrors it per tenant on the
/// deliberate paths (finish / cancel / prefill abort) — the crash path goes
/// through the run's [`ReservationGuard`] instead.
fn settle_policy_bytes(metrics: &ServeMetrics, run: &SeqRun) {
    if let Some(p) = &run.req.policy {
        metrics
            .policy_bytes
            .sub(p, run.reserved_blocks as u64 * metrics.block_bytes.get());
    }
}

/// Run the serve loop until `Shutdown` arrives and all work drains.
pub fn serve_loop(
    cfg: ServeConfig,
    rx: Receiver<Inbound>,
    metrics: Arc<ServeMetrics>,
) -> Result<()> {
    let mut ctx = build_ctx(&cfg, &metrics)?;
    // Per-tenant policy table, validated against this worker's backend
    // before the first request can name a policy it cannot execute.
    let policies = build_policy_table(&cfg)?;
    // Warmup: compile the hot artifacts before the first request arrives so
    // first-token latency reflects steady state, not XLA compilation.
    // (Sim mode has no engine and nothing to warm.)
    if let Some(engine) = &ctx.engine {
        match &ctx.mode {
            CacheMode::Cq { art, .. } | CacheMode::Fp { art, .. } => {
                engine.executable(art)?;
            }
            CacheMode::Sim { .. } => {}
        }
        for (_, p) in &ctx.prefills {
            engine.executable(p)?;
        }
    }
    let mut batcher = Batcher::new(ctx.batch, ctx.geom);
    // Block-pool cache shard: the byte budget becomes a whole-block budget
    // (floor), enforced both by reservation accounting and by the pool's
    // allocator itself.
    let block_tokens = cfg.block_tokens.max(1);
    let block_bytes = block_tokens * ctx.geom.bytes_per_token();
    if let Some(b) = cfg.cache_budget {
        // A budget below one block would floor to zero blocks and silently
        // reject every request; fail loudly at startup instead.
        anyhow::ensure!(
            b >= block_bytes,
            "cache budget {b} B is smaller than one block ({block_bytes} B); \
             lower --block-tokens or raise the budget"
        );
    }
    let budget_blocks = cfg.cache_budget.map(|b| b / block_bytes);
    // The sim backend stores real packed codes, so it shares prefixes like
    // CQ does; only the fp baseline serves unstored.
    let mut shard = PagedShard::new(
        ctx.geom,
        block_tokens,
        budget_blocks,
        cfg.prefix_sharing && (cfg.cq.is_some() || cfg.sim.is_some()),
    );
    // Multi-turn continuation state, bounded by LRU cap + idle TTL.
    let mut sessions = SessionTable::new(cfg.session_cap, cfg.session_ttl);
    // Publish shard geometry for the router's pool-wide admission estimate.
    // The fp16 rate rides along so per-policy router math (fp16 tenants,
    // retention windows) prices pen-resident tokens correctly.
    metrics.bytes_per_token.observe_max(ctx.geom.bytes_per_token() as u64);
    metrics
        .fp16_bytes_per_token
        .observe_max(ctx.geom.fp16_bytes_per_token(ctx.head_dim) as u64);
    metrics.block_bytes.observe_max(block_bytes as u64);
    metrics
        .max_prompt_tokens
        .observe_max(ctx.prefills.last().unwrap().0 as u64);
    // Flight recorder sizing (0 disables tracing for this worker).
    metrics.trace.set_capacity(cfg.trace_ring);
    let mut rngs: Vec<Pcg64> = (0..ctx.batch).map(|i| Pcg64::seed(i as u64)).collect();
    let mut shutting_down = false;
    // Decode-path code buffers, reused across every step and lane.
    let mut scratch = CodeScratch::default();
    // Lifetime decode-step counter: the index `FaultPlan::kill_worker_at_step`
    // schedules against.
    let mut decode_steps: u64 = 0;
    // Lifetime prefill-chunk counter: the index the chunk-boundary chaos
    // gates (`kill_at_prefill_chunk` / `hold_at_prefill_chunk`) fire on.
    let mut prefill_chunks: u64 = 0;
    let chunk_tokens = cfg.prefill_chunk.max(1);

    loop {
        metrics.phases.iterations.add(1);
        // --- Fault gate (chaos harness; no-op without a plan) ----------
        if let Some(plan) = &ctx.faults {
            plan.pause_point(ctx.worker);
            if plan.take_kill_now(ctx.worker) {
                panic!("[chaos] worker {} killed by fault plan", ctx.worker);
            }
        }

        // --- Router: drain inbound ------------------------------------
        loop {
            match rx.try_recv() {
                Ok(Inbound::Submit(sink, token)) => {
                    admit_request(
                        &ctx,
                        &mut shard,
                        &mut batcher,
                        &mut sessions,
                        &policies,
                        &metrics,
                        sink,
                        token,
                    );
                }
                Ok(Inbound::Cancel(id)) => {
                    cancel_request(&mut ctx, &mut batcher, &mut shard, &mut sessions, &metrics, id);
                }
                Ok(Inbound::Shutdown) => shutting_down = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutting_down = true,
            }
            if shutting_down {
                break;
            }
        }

        // --- Prefill: one chunk per iteration ---------------------------
        // Exactly one chunk between decode steps keeps both making
        // progress: a long batch prefill yields to inbound cancels, chaos
        // gates, interactive chunks and active lanes at every boundary.
        let t_prefill = Instant::now();
        let prefilled = advance_prefill(
            &ctx,
            &mut shard,
            &mut batcher,
            &metrics,
            chunk_tokens,
            &mut prefill_chunks,
        );
        if prefilled {
            metrics.phases.record_prefill(t_prefill.elapsed());
        }
        // Published every iteration for the router's `--ttft-slo-chunks`
        // admission estimate (instantaneous level, not a high-watermark).
        metrics
            .prefill_backlog_tokens
            .set(batcher.pending_prefill_tokens());
        // Pen occupancy across every live run: fp-resident window + sink
        // tokens, for the policy observables scrape (instantaneous level).
        metrics
            .window_tokens
            .set(batcher.runs().map(|r| r.packed.window_tokens() as u64).sum());

        // --- Admission --------------------------------------------------
        for slot in batcher.admit() {
            let run = batcher.slot(slot).unwrap();
            metrics
                .queue_wait
                .record(run.enqueued_at.elapsed());
            if let Some(t) = &run.trace {
                t.mark(TraceEventKind::Admitted);
            }
            rngs[slot] = Pcg64::seed(run.req.seed.wrapping_add(1));
            stage_admitted(&mut ctx, &shard, slot, &batcher);
            if let Some(r) = batcher.slot_mut(slot) {
                r.decode_started = Some(Instant::now());
            }
        }

        // --- Decode ------------------------------------------------------
        if batcher.active() > 0 {
            if let Some(plan) = &ctx.faults {
                if plan.take_kill_at_step(ctx.worker, decode_steps) {
                    panic!(
                        "[chaos] worker {} killed at decode step {decode_steps}",
                        ctx.worker
                    );
                }
                if let Some(d) = plan.step_delay(ctx.worker) {
                    std::thread::sleep(d);
                }
            }
            decode_steps += 1;
            let t0 = Instant::now();
            let logits = decode_step(&mut ctx, &batcher, &mut scratch)?;
            let decode_dur = t0.elapsed();
            metrics.decode_step_latency.record(decode_dur);
            metrics.phases.record_decode(decode_dur);

            // Everything below the fused step is quantize+store and stream
            // bookkeeping: code append, sampling, token emission.
            let t_store = Instant::now();
            for i in batcher.occupied() {
                // Account the token written this step.
                {
                    let run = batcher.slot_mut(i).unwrap();
                    match &ctx.mode {
                        CacheMode::Cq { .. } | CacheMode::Sim { .. }
                            if run.packed.is_stored() =>
                        {
                            // Codes were staged; append to the paged store
                            // from the staging lane for durability.  Under a
                            // retention policy this is the retire step: the
                            // new token enters the fp pen and the oldest
                            // window token packs into pool blocks.
                            let t = run.packed.len;
                            read_stage_token_into(&ctx, i, t, &mut scratch);
                            let retired0 = run.packed.retired_tokens;
                            run.packed.append(&mut shard.pool, &scratch.kc, &scratch.vc)?;
                            metrics
                                .window_retired_tokens
                                .add(run.packed.retired_tokens - retired0);
                        }
                        // fp baseline, or an fp16-policy tenant on sim.
                        _ => run.packed.append_unstored()?,
                    }
                }
                let run = batcher.slot_mut(i).unwrap();
                let cfg_s = SampleCfg {
                    temperature: run.req.temperature,
                    top_k: run.req.top_k,
                };
                let next = sample(&logits[i], cfg_s, &mut rngs[i]);
                run.generated.push(next);
                metrics.tokens_out.add(1);
                let step = run.generated.len() - 1;
                if sample_decode_step(step) {
                    if let Some(t) = &run.trace {
                        t.mark(TraceEventKind::DecodeStep { index: step });
                    }
                }

                // Stream the token out.  A dead receiver (dropped
                // StreamHandle, exited drain thread, disconnected TCP
                // writer) means nobody can ever read the rest of this
                // generation: treat it as an implicit cancel and reclaim
                // the lane + blocks right away.
                let receiver_gone = match &run.events {
                    Some(sink) => !sink.send(Event::Token {
                        id: run.req.id,
                        index: run.generated.len() - 1,
                        text: ByteTokenizer.decode(&[next]),
                    }),
                    None => false,
                };
                if receiver_gone {
                    cancel_lane(&mut ctx, &mut batcher, &mut shard, &mut sessions, &metrics, i);
                    continue;
                }

                if batcher.must_stop(i) {
                    complete(&mut ctx, &mut batcher, &mut shard, &mut sessions, i, &metrics);
                }
            }
            metrics.phases.record_store(t_store.elapsed());
        } else if shutting_down && batcher.is_idle() {
            debug_assert!(shard.idle(), "shard accounting not at idle baseline on shutdown");
            return Ok(());
        } else if batcher.is_idle() {
            // Idle: block briefly for the next request.  (A queue holding
            // only mid-prefill runs is NOT idle — the loop falls through
            // and advances their chunks without sleeping.)
            let t_idle = Instant::now();
            let msg = rx.recv_timeout(Duration::from_millis(20));
            metrics.phases.record_idle(t_idle.elapsed());
            match msg {
                Ok(Inbound::Submit(sink, token)) => {
                    admit_request(
                        &ctx,
                        &mut shard,
                        &mut batcher,
                        &mut sessions,
                        &policies,
                        &metrics,
                        sink,
                        token,
                    );
                }
                Ok(Inbound::Cancel(id)) => {
                    cancel_request(&mut ctx, &mut batcher, &mut shard, &mut sessions, &metrics, id);
                }
                Ok(Inbound::Shutdown) => shutting_down = true,
                Err(_) => {
                    if shutting_down {
                        debug_assert!(
                            shard.idle(),
                            "shard accounting not at idle baseline on shutdown"
                        );
                        return Ok(());
                    }
                }
            }
        }
    }
}

/// Read a token's codes back from the staging lane (CQ mode) into the
/// reusable decode scratch.
fn read_stage_token_into(ctx: &Ctx, slot: usize, t: usize, scratch: &mut CodeScratch) {
    match &ctx.mode {
        CacheMode::Cq { stage, .. } | CacheMode::Sim { stage } => {
            let (l_n, h_n, g_n) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.geom.groups);
            let b = ctx.batch;
            scratch.kc.clear();
            scratch.vc.clear();
            for l in 0..l_n {
                for h in 0..h_n {
                    let off = (((l * b + slot) * h_n + h) * ctx.geom.tmax + t) * g_n;
                    scratch
                        .kc
                        .extend(stage.k_codes.data[off..off + g_n].iter().map(|&c| c as u32));
                    scratch
                        .vc
                        .extend(stage.v_codes.data[off..off + g_n].iter().map(|&c| c as u32));
                }
            }
        }
        CacheMode::Fp { .. } => unreachable!("fp mode stores no codes"),
    }
}

/// The radix key a run's cached tokens are promoted under: prompt ids plus
/// every generated token whose KV actually landed in the paged store (the
/// final sampled token is returned but never decoded, so it is not cached).
fn promote_key(run: &SeqRun) -> Vec<i32> {
    let cached_gen = run.packed.len.saturating_sub(run.prompt_tokens);
    let mut key = run.prompt_ids.clone();
    key.extend_from_slice(&run.generated[..cached_gen.min(run.generated.len())]);
    key
}

/// Record the finished (or cancelled) turn in the session table so the next
/// turn with this session id resumes from the full conversation.  The table
/// publishes the session's token count for the router's reservation
/// estimate and LRU-evicts over-cap sessions.
fn note_session(sessions: &mut SessionTable, metrics: &ServeMetrics, run: &SeqRun) {
    if let Some(sid) = run.req.session_id {
        let mut hist = run.prompt_ids.clone();
        hist.extend_from_slice(&run.generated);
        sessions.record(sid, hist, metrics);
    }
}

/// Handle `Inbound::Cancel(id)`: the request may be decoding in a lane,
/// still queued behind full lanes, or already gone (no-op — cancellation is
/// idempotent).
fn cancel_request(
    ctx: &mut Ctx,
    batcher: &mut Batcher,
    shard: &mut PagedShard,
    sessions: &mut SessionTable,
    metrics: &ServeMetrics,
    id: u64,
) {
    if let Some(slot) = batcher.slot_of(id) {
        cancel_lane(ctx, batcher, shard, sessions, metrics, slot);
    } else if let Some(run) = batcher.take_queued(id) {
        // Prefilled but never staged: no lane to release.
        settle_cancelled(shard, sessions, metrics, run);
    }
}

/// Cancel the sequence occupying `slot`: free the stage lane immediately,
/// then settle its cache state.
fn cancel_lane(
    ctx: &mut Ctx,
    batcher: &mut Batcher,
    shard: &mut PagedShard,
    sessions: &mut SessionTable,
    metrics: &ServeMetrics,
    slot: usize,
) {
    if let Some(run) = batcher.take(slot) {
        match &mut ctx.mode {
            CacheMode::Cq { stage, .. } | CacheMode::Sim { stage } => stage.release(slot),
            CacheMode::Fp { pos, .. } => pos[slot] = 0,
        }
        settle_cancelled(shard, sessions, metrics, run);
    }
}

/// Common cancel settlement: promote the completed full blocks (the decoded
/// prefix stays warm for a session follow-up), release the rest + the whole
/// reservation, record the session, emit the terminal `Failed` event, and
/// drop the run — which releases its [`LoadToken`], so the router's
/// in-flight count for this worker falls the moment the cancel lands.
fn settle_cancelled(
    shard: &mut PagedShard,
    sessions: &mut SessionTable,
    metrics: &ServeMetrics,
    mut run: SeqRun,
) {
    // Deliberate settlement: the shard's own cancel path does the
    // accounting, so the crash guard must not also fire on drop.
    if let Some(g) = run.crash_guard.take() {
        g.disarm();
    }
    settle_policy_bytes(metrics, &run);
    let key = promote_key(&run);
    shard.cancel(&mut run.packed, &key, run.reserved_blocks, metrics);
    note_session(sessions, metrics, &run);
    metrics.requests_cancelled.add(1);
    if let Some(t) = run.trace.take() {
        metrics.trace.settle(&t, TraceOutcome::Cancelled, "");
    }
    if let Some(mut sink) = run.events.take() {
        sink.send_terminal(Event::Failed {
            id: run.req.id,
            reason: "[cancelled]".into(),
            retryable: false,
        });
    }
    // `run` (and its LoadToken) drops here.
}

fn complete(
    ctx: &mut Ctx,
    batcher: &mut Batcher,
    shard: &mut PagedShard,
    sessions: &mut SessionTable,
    slot: usize,
    metrics: &ServeMetrics,
) {
    if let Some(mut run) = batcher.take(slot) {
        match &mut ctx.mode {
            CacheMode::Cq { stage, .. } | CacheMode::Sim { stage } => stage.release(slot),
            CacheMode::Fp { pos, .. } => pos[slot] = 0,
        }
        // Deliberate settlement: `shard.finish` does the accounting, so the
        // crash guard must not also fire on drop.
        if let Some(g) = run.crash_guard.take() {
            g.disarm();
        }
        settle_policy_bytes(metrics, &run);
        let cache_bytes = run.packed.logical_bytes();
        // Promote the sequence's full blocks into the radix index under its
        // (prompt ++ generated) token key, then settle blocks + reservation.
        // Cache position `prompt_tokens + j` holds the KV of generated[j].
        let key = promote_key(&run);
        shard.finish(&mut run.packed, &key, run.reserved_blocks, metrics);
        note_session(sessions, metrics, &run);
        let tok = ByteTokenizer;
        let text = tok.decode(&run.generated);
        let decode_ms = run
            .decode_started
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let queue_ms = run
            .decode_started
            .map(|t| (t.duration_since(run.enqueued_at)).as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        metrics.requests_done.add(1);
        metrics
            .request_latency
            .record(run.enqueued_at.elapsed());
        if let Some(t) = run.trace.take() {
            metrics.trace.settle(&t, TraceOutcome::Done, "");
        }
        if let Some(mut sink) = run.events.take() {
            sink.send_terminal(Event::Done(Response {
                id: run.req.id,
                text,
                prompt_tokens: run.prompt_tokens,
                prefix_hit_tokens: run.prefix_hit_tokens,
                gen_tokens: run.generated.len(),
                queue_ms,
                ttft_ms: run.ttft_ms,
                prefill_ms: run.prefill_ms,
                decode_ms,
                cache_bytes,
            }));
        }
        // `run` (and its LoadToken) drops here: the router's in-flight count
        // for this worker decrements only after the response is sent.
    }
}

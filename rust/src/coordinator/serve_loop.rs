//! The serve loop: binds the batcher to the PJRT decode artifacts.
//!
//! One thread owns the [`Engine`] (PJRT handles are not `Send`) and runs:
//!
//! ```text
//! loop {
//!   drain inbound channel -> prefill + enqueue      (router)
//!   admit queued sequences into free lanes          (batcher)
//!   if any lane active: one fused decode step       (decode_cq / decode_fp)
//!   sample, append codes, complete finished lanes
//! }
//! ```
//!
//! Cache representation is selected by [`ServeConfig::cq`]: `Some(tag)` uses
//! the channel-coupled quantized cache (the paper's system); `None` the fp
//! baseline.  Both run the same batcher, so the serve-throughput bench
//! isolates exactly the cache effect.

use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::tokenizer::{ByteTokenizer, Tokenizer};
use crate::kvcache::{BatchStage, CacheGeom, CacheManager, PackedSeqCache};
use crate::metrics::ServeMetrics;
use crate::quant::cq::CqCodebooks;
use crate::quant::KvKind;
use crate::runtime::{engine::{Arg, DevBuf}, Engine, Value};
use crate::tensor::{TensorF, TensorI};
use crate::util::rng::Pcg64;

use super::batcher::{Batcher, SeqRun};
use super::pool::LoadToken;
use super::sampler::{sample, SampleCfg};
use super::{Inbound, Request, Response};

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    /// CQ tag ("2c8b" | "4c8b" | "8c8b") or None for the fp cache baseline.
    pub cq: Option<String>,
    pub batch: usize,
    /// Global cache budget in bytes (None = unlimited).
    pub cache_budget: Option<usize>,
    /// Path to learned codebooks (required when `cq` is set).
    pub codebook_path: Option<std::path::PathBuf>,
    /// Path to trained parameters.
    pub params_path: std::path::PathBuf,
    /// Decode kernel lowering: "pallas" (L1 interpret kernel) or "xla"
    /// (XLA-fused CPU fast path) — see EXPERIMENTS.md §Perf.
    pub kernel: String,
}

impl ServeConfig {
    /// Default kernel selection: measured on this substrate the pallas
    /// interpret lowering beats the jnp/XLA one at batch 1 (63.6 vs 91.2
    /// ms/token, EXPERIMENTS.md §Perf), so it is the default; pass "xla"
    /// for the alternative lowering.
    pub fn default_kernel() -> String {
        "pallas".to_string()
    }
}

enum CacheMode {
    Cq {
        books: CqCodebooks,
        stage: BatchStage,
        /// Centroid tables resident on device (uploaded once).
        ck_buf: DevBuf,
        cv_buf: DevBuf,
        art: String,
    },
    Fp {
        k_cache: TensorF,
        v_cache: TensorF,
        pos: Vec<i32>,
        art: String,
        tmax: usize,
    },
}

/// Everything the loop needs per model.
struct Ctx {
    engine: Engine,
    /// Parameter vector resident on device (uploaded once).
    params_buf: DevBuf,
    mode: CacheMode,
    geom: CacheGeom,
    batch: usize,
    /// (ctx, artifact) pairs sorted ascending — bucketed prefill.
    prefills: Vec<(usize, String)>,
    head_dim: usize,
    vocab: usize,
}

fn build_ctx(cfg: &ServeConfig) -> Result<Ctx> {
    let engine = Engine::load_default()?;
    let mm = engine.manifest.model(&cfg.model)?.clone();
    let params = Value::F(
        TensorF::read_f32_file(&cfg.params_path, &[mm.param_count])
            .with_context(|| format!("params at {}", cfg.params_path.display()))?,
    );
    let batch = cfg.batch;
    anyhow::ensure!(
        mm.decode_batches.contains(&batch),
        "batch {batch} not compiled (available: {:?})",
        mm.decode_batches
    );
    let (mode, geom) = match &cfg.cq {
        Some(tag) => {
            let path = cfg
                .codebook_path
                .clone()
                .ok_or_else(|| anyhow!("--codebooks required for CQ serving"))?;
            let books = CqCodebooks::load(&path)?;
            anyhow::ensure!(
                books.spec.tag() == *tag,
                "codebook file is {} but serving {tag}",
                books.spec.tag()
            );
            let geom = CacheGeom {
                n_layers: mm.n_layers,
                n_heads: mm.n_heads,
                groups: books.spec.n_groups(mm.head_dim),
                bits: books.spec.bits as u32,
                tmax: mm.serve_ctx,
            };
            let stage = BatchStage::new(geom, batch);
            let ck_buf = engine.upload(&Value::F(books.export_tensor(KvKind::Key)))?;
            let cv_buf = engine.upload(&Value::F(books.export_tensor(KvKind::Value)))?;
            let kprefix = if cfg.kernel == "xla" { "xla_" } else { "" };
            let art = format!("{}.decode_cq_{kprefix}{tag}_b{batch}", cfg.model);
            engine.manifest.artifact(&art)?;
            (CacheMode::Cq { books, stage, ck_buf, cv_buf, art }, geom)
        }
        None => {
            let geom = CacheGeom {
                n_layers: mm.n_layers,
                n_heads: mm.n_heads,
                groups: mm.head_dim, // 1 channel per "group"
                bits: 16,
                tmax: mm.serve_ctx,
            };
            let shape = [mm.n_layers, batch, mm.n_heads, mm.serve_ctx, mm.head_dim];
            let art = format!("{}.decode_fp_b{batch}", cfg.model);
            engine.manifest.artifact(&art)?;
            (
                CacheMode::Fp {
                    k_cache: TensorF::zeros(&shape),
                    v_cache: TensorF::zeros(&shape),
                    pos: vec![0; batch],
                    art,
                    tmax: mm.serve_ctx,
                },
                geom,
            )
        }
    };
    let params_buf = engine.upload(&params)?;
    // Bucketed prefill: every "<model>.prefill*" artifact, smallest first.
    let mut prefills: Vec<(usize, String)> = engine
        .manifest
        .artifacts
        .iter()
        .filter(|(k, _)| k.starts_with(&format!("{}.prefill", cfg.model)))
        .map(|(k, a)| (a.meta.num_or("ctx", 0.0) as usize, k.clone()))
        .collect();
    prefills.sort();
    anyhow::ensure!(!prefills.is_empty(), "no prefill artifact for {}", cfg.model);
    Ok(Ctx {
        engine,
        params_buf,
        mode,
        geom,
        batch,
        prefills,
        head_dim: mm.head_dim,
        vocab: mm.vocab,
    })
}

/// Prefill one request: returns a ready [`SeqRun`] with its first sampled
/// token and (for CQ) a populated packed cache.
fn prefill(
    ctx: &Ctx,
    req: &Request,
    respond: Option<Sender<Response>>,
    load_token: Option<LoadToken>,
    metrics: &ServeMetrics,
) -> Result<SeqRun> {
    let t0 = Instant::now();
    let tok = ByteTokenizer;
    let mut prompt = tok.encode(&req.prompt);
    if prompt.is_empty() {
        prompt.push(b'\n' as i32);
    }
    let max_ctx = ctx.prefills.last().unwrap().0;
    if prompt.len() > max_ctx {
        // Router policy: keep the tail (most recent context), like a
        // sliding-window chat server.
        prompt = prompt[prompt.len() - max_ctx..].to_vec();
    }
    let p = prompt.len();
    // Smallest compiled prefill bucket that fits the prompt.
    let (bucket_ctx, art) = ctx
        .prefills
        .iter()
        .find(|(t, _)| *t >= p)
        .unwrap_or_else(|| ctx.prefills.last().unwrap());
    let mut padded = prompt.clone();
    padded.resize(*bucket_ctx, b' ' as i32);
    let tokens = Value::I(TensorI::from_vec(&[1, *bucket_ctx], padded)?);
    let out = ctx
        .engine
        .executable(art)?
        .run_mixed(&[Arg::B(&ctx.params_buf), Arg::V(&tokens)])?;
    let logits = out[0].as_f()?;
    let k = out[1].as_f()?;
    let v = out[2].as_f()?;

    let mut packed = match &ctx.mode {
        CacheMode::Cq { books, .. } => {
            let mut packed = PackedSeqCache::new(ctx.geom);
            let d = crate::quant::KvDims::of(k);
            let per_side = ctx.geom.n_layers * ctx.geom.n_heads * ctx.geom.groups;
            let mut kc = Vec::with_capacity(per_side);
            let mut vc = Vec::with_capacity(per_side);
            for t in 0..p {
                kc.clear();
                vc.clear();
                for l in 0..d.l {
                    for h in 0..d.h {
                        let off = d.vec_off(l, 0, h, t);
                        kc.extend(books.encode_vec(l, KvKind::Key, h, &k.data[off..off + d.hd]));
                        vc.extend(books.encode_vec(l, KvKind::Value, h, &v.data[off..off + d.hd]));
                    }
                }
                packed.append(&kc, &vc)?;
            }
            packed
        }
        CacheMode::Fp { .. } => {
            let mut packed = PackedSeqCache::new_unstored(ctx.geom);
            for _ in 0..p {
                packed.append_unstored()?;
            }
            packed
        }
    };
    // Stash prefill K/V for fp mode staging at admission time.
    if let CacheMode::Fp { .. } = &ctx.mode {
        packed.fp_seed = Some((k.clone(), v.clone()));
    }

    // First generated token from the last prompt position.
    let row = &logits.data[(p - 1) * ctx.vocab..p * ctx.vocab];
    let mut rng = Pcg64::seed(req.seed);
    let t0_tok = sample(
        row,
        SampleCfg { temperature: req.temperature, top_k: req.top_k },
        &mut rng,
    );
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
    metrics.prefill_latency.record(t0.elapsed());

    Ok(SeqRun {
        req: req.clone(),
        respond,
        load_token,
        reserved_bytes: 0,
        prompt_tokens: p,
        generated: vec![t0_tok],
        packed,
        enqueued_at: Instant::now(),
        prefill_ms,
        decode_started: None,
    })
}

/// Router admission for one inbound request: reserve this shard's cache
/// budget, prefill, and enqueue.  On budget exhaustion the client gets an
/// explicit rejection; on prefill failure the reservation is returned (the
/// seed leaked it).  The [`LoadToken`] rides in the `SeqRun` so the pool's
/// in-flight count drops on every terminal path.
fn admit_request(
    ctx: &Ctx,
    cache_mgr: &mut CacheManager,
    batcher: &mut Batcher,
    metrics: &ServeMetrics,
    req: Request,
    resp_tx: Sender<Response>,
    token: Option<LoadToken>,
) {
    let reserve = ctx.geom.bytes_per_token()
        * (req.prompt.len().min(ctx.prefills.last().unwrap().0) + req.max_new);
    if cache_mgr.reserve(reserve).is_err() {
        metrics.requests_rejected.add(1);
        let _ = resp_tx.send(Response {
            id: req.id,
            text: String::from("[rejected: cache budget]"),
            prompt_tokens: 0,
            gen_tokens: 0,
            queue_ms: 0.0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            cache_bytes: 0,
        });
        return; // token drops here -> router sees the slot free again
    }
    metrics.cache_reserved_bytes.add(reserve as u64);
    metrics.cache_peak_bytes.observe_max(cache_mgr.bytes_in_use as u64);
    match prefill(ctx, &req, Some(resp_tx.clone()), token, metrics) {
        Ok(mut run) => {
            run.reserved_bytes = reserve;
            run.enqueued_at = Instant::now();
            batcher.enqueue(run);
        }
        Err(e) => {
            log::error!("prefill failed: {e:#}");
            cache_mgr.release(reserve);
            metrics.cache_released_bytes.add(reserve as u64);
            // Explicit error reply (like the rejection path) so pipelined
            // TCP clients keep their connection instead of a dropped-channel
            // error tearing it down.
            let _ = resp_tx.send(Response {
                id: req.id,
                text: format!("[error: prefill failed: {e:#}]"),
                prompt_tokens: 0,
                gen_tokens: 0,
                queue_ms: 0.0,
                prefill_ms: 0.0,
                decode_ms: 0.0,
                cache_bytes: 0,
            });
        }
    }
}

/// Stage a newly admitted sequence into its lane.
fn stage_admitted(ctx: &mut Ctx, slot: usize, batcher: &Batcher) {
    let run = batcher.slot(slot).expect("admitted slot");
    match &mut ctx.mode {
        CacheMode::Cq { stage, .. } => {
            stage.load_sequence(slot, &run.packed);
            stage.pos[slot] = run.packed.len as i32; // next write position
        }
        CacheMode::Fp { k_cache, v_cache, pos, tmax, .. } => {
            let (k, v) = run.packed.fp_seed.as_ref().expect("fp prefill seed");
            let d = crate::quant::KvDims::of(k);
            let hd = d.hd;
            let b = ctx.batch;
            for l in 0..d.l {
                for h in 0..d.h {
                    for t in 0..run.packed.len {
                        let src = d.vec_off(l, 0, h, t);
                        let dst = (((l * b + slot) * d.h + h) * *tmax + t) * hd;
                        k_cache.data[dst..dst + hd].copy_from_slice(&k.data[src..src + hd]);
                        v_cache.data[dst..dst + hd].copy_from_slice(&v.data[src..src + hd]);
                    }
                }
            }
            pos[slot] = run.packed.len as i32;
        }
    }
}

/// One fused decode step over all lanes.  Returns per-slot logits rows.
fn decode_step(ctx: &mut Ctx, batcher: &Batcher) -> Result<Vec<Vec<f32>>> {
    let b = ctx.batch;
    let mut tok = vec![0i32; b];
    let mut pos = vec![0i32; b];
    for i in batcher.occupied() {
        let run = batcher.slot(i).unwrap();
        tok[i] = *run.generated.last().unwrap();
        pos[i] = run.packed.len as i32;
    }
    let pos_t = Value::I(TensorI::from_vec(&[b], pos.clone())?);
    let tok_t = Value::I(TensorI::from_vec(&[b], tok)?);

    let (logits, updates) = match &ctx.mode {
        CacheMode::Cq { stage, ck_buf, cv_buf, art, .. } => {
            // Staging code tensors are moved (not cloned): run_mixed borrows.
            let kc = Value::I(stage.k_codes.clone());
            let vc = Value::I(stage.v_codes.clone());
            let out = ctx.engine.executable(art)?.run_mixed(&[
                Arg::B(&ctx.params_buf),
                Arg::B(ck_buf),
                Arg::B(cv_buf),
                Arg::V(&kc),
                Arg::V(&vc),
                Arg::V(&pos_t),
                Arg::V(&tok_t),
            ])?;
            let logits = out[0].as_f()?.clone();
            let kn = out[1].as_i()?.clone();
            let vn = out[2].as_i()?.clone();
            (logits, StepUpdate::Cq(kn, vn))
        }
        CacheMode::Fp { k_cache, v_cache, art, .. } => {
            let kc = Value::F(k_cache.clone());
            let vc = Value::F(v_cache.clone());
            let out = ctx.engine.executable(art)?.run_mixed(&[
                Arg::B(&ctx.params_buf),
                Arg::V(&kc),
                Arg::V(&vc),
                Arg::V(&pos_t),
                Arg::V(&tok_t),
            ])?;
            let logits = out[0].as_f()?.clone();
            let kn = out[1].as_f()?.clone();
            let vn = out[2].as_f()?.clone();
            (logits, StepUpdate::Fp(kn, vn))
        }
    };

    // Apply cache updates for occupied lanes.
    apply_updates(ctx, batcher, &pos, updates)?;

    let v = ctx.vocab;
    Ok((0..b)
        .map(|i| logits.data[i * v..(i + 1) * v].to_vec())
        .collect())
}

enum StepUpdate {
    /// New codes `[L, B, H, G]` for keys and values.
    Cq(TensorI, TensorI),
    /// New rows `[L, B, H, hd]`.
    Fp(TensorF, TensorF),
}

fn apply_updates(
    ctx: &mut Ctx,
    batcher: &Batcher,
    pos: &[i32],
    up: StepUpdate,
) -> Result<()> {
    let b = ctx.batch;
    match (&mut ctx.mode, up) {
        (CacheMode::Cq { stage, .. }, StepUpdate::Cq(kn, vn)) => {
            let (l_n, h_n, g_n) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.geom.groups);
            for i in batcher.occupied() {
                let t = pos[i] as usize;
                let mut kc = Vec::with_capacity(l_n * h_n * g_n);
                let mut vc = Vec::with_capacity(l_n * h_n * g_n);
                for l in 0..l_n {
                    for h in 0..h_n {
                        let off = ((l * b + i) * h_n + h) * g_n;
                        for g in 0..g_n {
                            kc.push(kn.data[off + g] as u32);
                            vc.push(vn.data[off + g] as u32);
                        }
                    }
                }
                stage.write_token(i, t, &kc, &vc);
                stage.pos[i] = (t + 1) as i32;
            }
            Ok(())
        }
        (CacheMode::Fp { k_cache, v_cache, tmax, pos: fpos, .. }, StepUpdate::Fp(kn, vn)) => {
            let _ = &fpos;
            let (l_n, h_n, hd) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.head_dim);
            for i in batcher.occupied() {
                let t = pos[i] as usize;
                for l in 0..l_n {
                    for h in 0..h_n {
                        let src = ((l * b + i) * h_n + h) * hd;
                        let dst = (((l * b + i) * h_n + h) * *tmax + t) * hd;
                        k_cache.data[dst..dst + hd]
                            .copy_from_slice(&kn.data[src..src + hd]);
                        v_cache.data[dst..dst + hd]
                            .copy_from_slice(&vn.data[src..src + hd]);
                    }
                }
                fpos[i] = (t + 1) as i32;
            }
            Ok(())
        }
        _ => bail!("cache mode / update mismatch"),
    }
}

/// Run the serve loop until `Shutdown` arrives and all work drains.
pub fn serve_loop(
    cfg: ServeConfig,
    rx: Receiver<Inbound>,
    metrics: Arc<ServeMetrics>,
) -> Result<()> {
    let mut ctx = build_ctx(&cfg)?;
    // Warmup: compile the hot artifacts before the first request arrives so
    // first-token latency reflects steady state, not XLA compilation.
    {
        let art = match &ctx.mode {
            CacheMode::Cq { art, .. } => art.clone(),
            CacheMode::Fp { art, .. } => art.clone(),
        };
        ctx.engine.executable(&art)?;
        for (_, p) in ctx.prefills.clone() {
            ctx.engine.executable(&p)?;
        }
    }
    let mut batcher = Batcher::new(ctx.batch, ctx.geom);
    let mut cache_mgr = match cfg.cache_budget {
        Some(b) => CacheManager::with_budget(b),
        None => CacheManager::default(),
    };
    let mut rngs: Vec<Pcg64> = (0..ctx.batch).map(|i| Pcg64::seed(i as u64)).collect();
    let mut shutting_down = false;

    loop {
        // --- Router: drain inbound ------------------------------------
        loop {
            match rx.try_recv() {
                Ok(Inbound::Submit(req, resp_tx, token)) => {
                    admit_request(
                        &ctx, &mut cache_mgr, &mut batcher, &metrics, req, resp_tx, token,
                    );
                }
                Ok(Inbound::Shutdown) => shutting_down = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutting_down = true,
            }
            if shutting_down {
                break;
            }
        }

        // --- Admission --------------------------------------------------
        for slot in batcher.admit() {
            let run = batcher.slot(slot).unwrap();
            metrics
                .queue_wait
                .record(run.enqueued_at.elapsed());
            rngs[slot] = Pcg64::seed(run.req.seed.wrapping_add(1));
            stage_admitted(&mut ctx, slot, &batcher);
            if let Some(r) = batcher.slot_mut(slot) {
                r.decode_started = Some(Instant::now());
            }
        }

        // --- Decode ------------------------------------------------------
        if batcher.active() > 0 {
            let t0 = Instant::now();
            let logits = decode_step(&mut ctx, &batcher)?;
            metrics.decode_step_latency.record(t0.elapsed());

            for i in batcher.occupied() {
                // Account the token written this step.
                {
                    let run = batcher.slot_mut(i).unwrap();
                    match &ctx.mode {
                        CacheMode::Cq { .. } => {
                            // Codes were staged; append to the packed store
                            // from the staging lane for durability.
                            let t = run.packed.len;
                            let (kc, vc) = read_stage_token(&ctx, i, t);
                            run.packed.append(&kc, &vc)?;
                        }
                        CacheMode::Fp { .. } => run.packed.append_unstored()?,
                    }
                }
                let run = batcher.slot_mut(i).unwrap();
                let cfg_s = SampleCfg {
                    temperature: run.req.temperature,
                    top_k: run.req.top_k,
                };
                let next = sample(&logits[i], cfg_s, &mut rngs[i]);
                run.generated.push(next);
                metrics.tokens_out.add(1);

                if batcher.must_stop(i) {
                    complete(&mut ctx, &mut batcher, &mut cache_mgr, i, &metrics);
                }
            }
        } else if shutting_down && batcher.is_idle() {
            return Ok(());
        } else if batcher.is_idle() {
            // Idle: block briefly for the next request.
            match rx.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(Inbound::Submit(req, resp_tx, token)) => {
                    admit_request(
                        &ctx, &mut cache_mgr, &mut batcher, &metrics, req, resp_tx, token,
                    );
                }
                Ok(Inbound::Shutdown) => shutting_down = true,
                Err(_) => {
                    if shutting_down {
                        return Ok(());
                    }
                }
            }
        }
    }
}

/// Read a token's codes back from the staging lane (CQ mode).
fn read_stage_token(ctx: &Ctx, slot: usize, t: usize) -> (Vec<u32>, Vec<u32>) {
    match &ctx.mode {
        CacheMode::Cq { stage, .. } => {
            let (l_n, h_n, g_n) = (ctx.geom.n_layers, ctx.geom.n_heads, ctx.geom.groups);
            let b = ctx.batch;
            let mut kc = Vec::with_capacity(l_n * h_n * g_n);
            let mut vc = Vec::with_capacity(l_n * h_n * g_n);
            for l in 0..l_n {
                for h in 0..h_n {
                    let off = (((l * b + slot) * h_n + h) * ctx.geom.tmax + t) * g_n;
                    for g in 0..g_n {
                        kc.push(stage.k_codes.data[off + g] as u32);
                        vc.push(stage.v_codes.data[off + g] as u32);
                    }
                }
            }
            (kc, vc)
        }
        CacheMode::Fp { .. } => unreachable!("fp mode stores no codes"),
    }
}

fn complete(
    ctx: &mut Ctx,
    batcher: &mut Batcher,
    cache_mgr: &mut CacheManager,
    slot: usize,
    metrics: &ServeMetrics,
) {
    if let Some(run) = batcher.take(slot) {
        match &mut ctx.mode {
            CacheMode::Cq { stage, .. } => stage.release(slot),
            CacheMode::Fp { pos, .. } => pos[slot] = 0,
        }
        // Release exactly what admission reserved so shard accounting
        // returns to zero when the shard drains.
        cache_mgr.release(run.reserved_bytes);
        metrics.cache_released_bytes.add(run.reserved_bytes as u64);
        let tok = ByteTokenizer;
        let text = tok.decode(&run.generated);
        let decode_ms = run
            .decode_started
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let queue_ms = run
            .decode_started
            .map(|t| (t.duration_since(run.enqueued_at)).as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        metrics.requests_done.add(1);
        metrics
            .request_latency
            .record(run.enqueued_at.elapsed());
        if let Some(tx) = run.respond {
            let _ = tx.send(Response {
                id: run.req.id,
                text,
                prompt_tokens: run.prompt_tokens,
                gen_tokens: run.generated.len(),
                queue_ms,
                prefill_ms: run.prefill_ms,
                decode_ms,
                cache_bytes: run.packed.logical_bytes(),
            });
        }
        // `run` (and its LoadToken) drops here: the router's in-flight count
        // for this worker decrements only after the response is sent.
    }
}

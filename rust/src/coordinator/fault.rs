//! Deterministic fault injection for the serve pool — the chaos-test
//! harness' control plane.
//!
//! A [`FaultPlan`] is a shared, scripted schedule of failures that test
//! scenarios arm *before or during* a run and serve-loop workers consult at
//! two well-defined points:
//!
//! * the **loop top** (once per scheduler iteration, idle iterations
//!   included): the hold gate ([`FaultPlan::hold_worker`] /
//!   [`FaultPlan::release_worker`]) and the immediate kill
//!   ([`FaultPlan::kill_worker`]);
//! * **just before a decode step**: the step-indexed kill
//!   ([`FaultPlan::kill_worker_at_step`], counting the worker's lifetime
//!   decode steps from 0) and the per-step delay
//!   ([`FaultPlan::delay_steps`], a slow-shard simulation);
//! * **at every prefill chunk boundary** (chunked prefill makes these real
//!   yield points): the chunk-indexed kill
//!   ([`FaultPlan::kill_worker_at_prefill_chunk`]) and hold
//!   ([`FaultPlan::hold_worker_at_prefill_chunk`]), both counting the
//!   worker's lifetime prefill chunks from 0.  The chunk hold converts into
//!   the ordinary held/paused park, so [`FaultPlan::await_paused`] /
//!   [`FaultPlan::release_worker`] script around it.
//!
//! Prefill poisoning ([`FaultPlan::poison_prefill`]) is keyed by request id
//! and consumed by the first prefill that sees it, driving the
//! prefill-failure path without touching the runtime.
//!
//! Kills are real `panic!`s on the worker thread: the stack unwinds exactly
//! as a genuine crash would, dropping the batcher (whose in-flight
//! [`super::EventSink`]s emit terminal `Failed { retryable: true }` events),
//! then the inbound receiver (whose still-queued sinks re-dispatch through
//! the pool supervisor).  Tests therefore exercise the same recovery
//! machinery a production panic would.
//!
//! [`SimSpec`] selects the engine-free deterministic serve backend (see
//! `serve_loop`): synthetic per-token codes and a fixed token-successor
//! function stand in for the PJRT artifacts, so every chaos scenario runs
//! on hosts without the XLA runtime.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Geometry of the engine-free simulated backend (chaos/fault tests).
///
/// The sim worker stores real packed codes in the real paged shard — only
/// the model math is synthetic — so block/budget accounting behaves exactly
/// as in CQ serving.
#[derive(Clone, Copy, Debug)]
pub struct SimSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub groups: usize,
    pub bits: u32,
    /// Cache lane capacity in tokens (prompt + generated must fit).
    pub tmax: usize,
    /// Largest prompt accepted; longer prompts keep their tail (the same
    /// sliding-window trim the prefill buckets apply).
    pub max_prompt: usize,
}

impl SimSpec {
    /// Small geometry for fast deterministic tests: 4 codes/token at
    /// 4 bits = 2 packed bytes per token.
    pub fn tiny() -> SimSpec {
        SimSpec { n_layers: 1, n_heads: 1, groups: 2, bits: 4, tmax: 96, max_prompt: 48 }
    }
}

#[derive(Debug, Default)]
struct WorkerFaults {
    kill_now: bool,
    kill_at_step: Option<u64>,
    kill_at_prefill_chunk: Option<u64>,
    hold_at_prefill_chunk: Option<u64>,
    step_delay: Option<Duration>,
    held: bool,
    /// Set by the worker while parked at the hold gate (lets tests wait for
    /// a worker to be provably frozen before scripting around it).
    paused: bool,
}

/// Scripted failure schedule shared between a test scenario and the serve
/// workers (via `ServeConfig::faults`).  All methods are safe to call from
/// any thread at any time; worker-side hooks are no-ops for workers with no
/// armed faults.
#[derive(Debug, Default)]
pub struct FaultPlan {
    workers: Mutex<HashMap<usize, WorkerFaults>>,
    poisoned: Mutex<HashSet<u64>>,
    cv: Condvar,
}

/// Safety valve: a held worker un-parks after this long even if the test
/// never releases it, so a buggy scenario fails an assertion instead of
/// hanging the suite.
const HOLD_TIMEOUT: Duration = Duration::from_secs(30);

impl FaultPlan {
    /// Fresh, empty plan (shared handle; clone the `Arc` into
    /// `ServeConfig::faults`).
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::default())
    }

    /// Panic worker `w` at its next loop top (even while idle).
    pub fn kill_worker(&self, w: usize) {
        self.workers.lock().unwrap().entry(w).or_default().kill_now = true;
    }

    /// Panic worker `w` just before its `step`-th decode step (0-based,
    /// counted over the worker's lifetime since start).
    pub fn kill_worker_at_step(&self, w: usize, step: u64) {
        self.workers.lock().unwrap().entry(w).or_default().kill_at_step = Some(step);
    }

    /// Panic worker `w` at its `chunk`-th prefill chunk boundary (0-based,
    /// counted over the worker's lifetime): the kill lands *before* the
    /// chunk is computed, i.e. exactly at a yield point.
    pub fn kill_worker_at_prefill_chunk(&self, w: usize, chunk: u64) {
        self.workers.lock().unwrap().entry(w).or_default().kill_at_prefill_chunk = Some(chunk);
    }

    /// Freeze worker `w` at its `chunk`-th prefill chunk boundary (0-based,
    /// lifetime-counted).  The gate converts into the ordinary held park:
    /// use [`Self::await_paused`] / [`Self::release_worker`] around it.
    pub fn hold_worker_at_prefill_chunk(&self, w: usize, chunk: u64) {
        self.workers.lock().unwrap().entry(w).or_default().hold_at_prefill_chunk = Some(chunk);
    }

    /// Sleep `d` before every decode step of worker `w` (slow shard).
    pub fn delay_steps(&self, w: usize, d: Duration) {
        self.workers.lock().unwrap().entry(w).or_default().step_delay = Some(d);
    }

    /// Freeze worker `w` at its next loop top until released: inbound
    /// requests queue in its channel without being admitted.
    pub fn hold_worker(&self, w: usize) {
        self.workers.lock().unwrap().entry(w).or_default().held = true;
    }

    /// Release a held worker (wakes it at the gate).
    pub fn release_worker(&self, w: usize) {
        self.workers.lock().unwrap().entry(w).or_default().held = false;
        self.cv.notify_all();
    }

    /// Block until worker `w` is provably parked at the hold gate.
    pub fn await_paused(&self, w: usize) {
        let mut g = self.workers.lock().unwrap();
        while !g.get(&w).map(|f| f.paused).unwrap_or(false) {
            let (guard, timed_out) = self.cv.wait_timeout(g, HOLD_TIMEOUT).unwrap();
            g = guard;
            if timed_out.timed_out() {
                panic!("worker {w} never reached the hold gate");
            }
        }
    }

    /// Make the next prefill of request `id` fail (consumed on first use).
    pub fn poison_prefill(&self, id: u64) {
        self.poisoned.lock().unwrap().insert(id);
    }

    // --- Worker-side hooks ------------------------------------------------

    /// Loop-top gate: park while held (bounded by [`HOLD_TIMEOUT`]).
    pub fn pause_point(&self, w: usize) {
        let mut g = self.workers.lock().unwrap();
        if !g.get(&w).map(|f| f.held).unwrap_or(false) {
            return;
        }
        g.get_mut(&w).unwrap().paused = true;
        self.cv.notify_all();
        while g.get(&w).map(|f| f.held).unwrap_or(false) {
            let (guard, timed_out) = self.cv.wait_timeout(g, HOLD_TIMEOUT).unwrap();
            g = guard;
            if timed_out.timed_out() {
                break;
            }
        }
        if let Some(f) = g.get_mut(&w) {
            f.paused = false;
        }
    }

    /// True exactly once after [`Self::kill_worker`] was armed for `w`.
    pub fn take_kill_now(&self, w: usize) -> bool {
        let mut g = self.workers.lock().unwrap();
        match g.get_mut(&w) {
            Some(f) if f.kill_now => {
                f.kill_now = false;
                true
            }
            _ => false,
        }
    }

    /// True exactly once, the first time `step` reaches the armed threshold.
    pub fn take_kill_at_step(&self, w: usize, step: u64) -> bool {
        let mut g = self.workers.lock().unwrap();
        match g.get_mut(&w) {
            Some(f) if f.kill_at_step.map(|k| step >= k).unwrap_or(false) => {
                f.kill_at_step = None;
                true
            }
            _ => false,
        }
    }

    /// True exactly once, the first time the worker's lifetime prefill
    /// chunk counter reaches the armed threshold.
    pub fn take_kill_at_prefill_chunk(&self, w: usize, chunk: u64) -> bool {
        let mut g = self.workers.lock().unwrap();
        match g.get_mut(&w) {
            Some(f) if f.kill_at_prefill_chunk.map(|k| chunk >= k).unwrap_or(false) => {
                f.kill_at_prefill_chunk = None;
                true
            }
            _ => false,
        }
    }

    /// Prefill-chunk-boundary gate: if a chunk hold is armed and due, the
    /// worker converts it into the ordinary held park (consumed once).
    pub fn prefill_chunk_gate(&self, w: usize, chunk: u64) {
        {
            let mut g = self.workers.lock().unwrap();
            match g.get_mut(&w) {
                Some(f) if f.hold_at_prefill_chunk.map(|k| chunk >= k).unwrap_or(false) => {
                    f.hold_at_prefill_chunk = None;
                    f.held = true;
                }
                _ => return,
            }
        }
        self.pause_point(w);
    }

    /// Armed per-step delay for worker `w`, if any.
    pub fn step_delay(&self, w: usize) -> Option<Duration> {
        self.workers.lock().unwrap().get(&w).and_then(|f| f.step_delay)
    }

    /// True exactly once if request `id` was poisoned.
    pub fn take_poison(&self, id: u64) -> bool {
        self.poisoned.lock().unwrap().remove(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_and_poison_are_consumed_once() {
        let plan = FaultPlan::new();
        assert!(!plan.take_kill_now(0), "unarmed worker");
        plan.kill_worker(0);
        assert!(plan.take_kill_now(0));
        assert!(!plan.take_kill_now(0), "consumed");
        assert!(!plan.take_kill_now(1), "other worker unaffected");

        plan.poison_prefill(7);
        assert!(!plan.take_poison(6));
        assert!(plan.take_poison(7));
        assert!(!plan.take_poison(7), "consumed");
    }

    #[test]
    fn step_kill_fires_at_threshold() {
        let plan = FaultPlan::new();
        plan.kill_worker_at_step(2, 3);
        for step in 0..3 {
            assert!(!plan.take_kill_at_step(2, step), "step {step} too early");
        }
        assert!(!plan.take_kill_at_step(1, 5), "wrong worker");
        assert!(plan.take_kill_at_step(2, 3));
        assert!(!plan.take_kill_at_step(2, 4), "consumed");
    }

    #[test]
    fn prefill_chunk_kill_fires_at_threshold_once() {
        let plan = FaultPlan::new();
        plan.kill_worker_at_prefill_chunk(1, 2);
        assert!(!plan.take_kill_at_prefill_chunk(1, 0));
        assert!(!plan.take_kill_at_prefill_chunk(1, 1));
        assert!(!plan.take_kill_at_prefill_chunk(0, 5), "wrong worker");
        assert!(plan.take_kill_at_prefill_chunk(1, 2));
        assert!(!plan.take_kill_at_prefill_chunk(1, 3), "consumed");
    }

    #[test]
    fn prefill_chunk_hold_converts_to_pause_and_releases() {
        let plan = FaultPlan::new();
        plan.hold_worker_at_prefill_chunk(0, 1);
        // Chunk 0: not due yet, passes straight through.
        plan.prefill_chunk_gate(0, 0);
        let p2 = plan.clone();
        let t = std::thread::spawn(move || {
            p2.prefill_chunk_gate(0, 1); // due: parks as held
            true
        });
        plan.await_paused(0);
        assert!(!t.is_finished(), "worker must be parked at the chunk gate");
        plan.release_worker(0);
        assert!(t.join().unwrap());
        // Consumed: the same boundary passes through on a later chunk.
        plan.prefill_chunk_gate(0, 2);
    }

    #[test]
    fn hold_gate_parks_until_release() {
        let plan = FaultPlan::new();
        plan.hold_worker(0);
        let p2 = plan.clone();
        let t = std::thread::spawn(move || {
            p2.pause_point(0); // parks
            true
        });
        plan.await_paused(0);
        assert!(!t.is_finished(), "worker must be parked while held");
        plan.release_worker(0);
        assert!(t.join().unwrap());
        // Unheld worker passes straight through.
        plan.pause_point(0);
        assert_eq!(plan.step_delay(0), None);
        plan.delay_steps(0, Duration::from_millis(1));
        assert_eq!(plan.step_delay(0), Some(Duration::from_millis(1)));
    }
}

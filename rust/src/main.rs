//! `cq-serve` — CLI launcher for the Coupled Quantization serving stack.
//!
//! Pipeline (see README Quickstart):
//!   gen-corpus -> train -> calibrate -> learn-cq -> {eval-ppl, eval-tasks,
//!   serve / client / generate}
//!
//! Every subcommand runs fully in Rust against the AOT artifacts; Python is
//! only needed once, for `make artifacts`.


use anyhow::{bail, Context, Result};

use cq::calib::CalibData;
use cq::coordinator::{Request, ServeConfig, ServeHandle, ServePool};
use cq::data::corpus::{CorpusKind, CorpusSpec, Split};
use cq::data::{eval_batches, Dataset};
use cq::eval::tasks::{task_accuracy, TaskKind, TaskSet};
use cq::eval::{perplexity, PplMode};
use cq::quant::cq::{CqCodebooks, LearnCfg};
use cq::quant::factory::{build_codec, needs_calibration, parse_cq, FactoryCfg};
use cq::quant::policy::codec::{build_policy_codec, menu_from_rows};
use cq::quant::policy::{greedy_allocate, PolicyDescriptor, DEFAULT_MENU_ROWS};
use cq::runtime::Engine;
use cq::train::{ckpt_dir, load_checkpoint, save_checkpoint, train, TrainCfg};
use cq::util::cli::Args;
use cq::util::human_bytes;
use cq::util::json::Json;

const USAGE: &str = "\
cq-serve — Coupled Quantization KV-cache serving stack

USAGE: cq-serve <command> [flags]

COMMANDS
  selfcheck                      load artifacts, run one eval step (smoke)
  info                           print manifest + model inventory
  train       --model small --steps 400 [--lr 3e-3] [--seed 7]
  calibrate   --model small [--seqs 16]
  learn-cq    --model small --spec 8c8b [--no-fisher] [--iters 40]
  eval-ppl    --model small --codec cq-8c8b [--corpus wiki2s|c4s]
              [--batches 8] [--exact] [--no-fisher]
              (--codec also accepts policy specs like cq-8c8b-w64-s4;
               [--policy-file desc.json] evals an alloc-policy descriptor)
  eval-tasks  --model small --codec cq-8c8b [--items 120]
  alloc-policy --model small [--budget-bits 6] [--spec int2] [--probe int2]
              [--batches 4] [--corpus wiki2s] [--out policy.json]
  generate    --model small --prompt \"...\" [--max-tokens 48] [--cq 8c8b]
              [--policy name]
  serve       --model small --port 7878 [--cq 8c8b] [--batch 8]
              [--codec int4] [--policies cq-8c8b-w64-s4,fp16]
              [--workers 2] [--cache-budget-mb 64] [--block-tokens 16]
              [--no-prefix-sharing] [--session-cap 256] [--session-ttl-s 3600]
              [--prefill-chunk 512] [--ttft-slo-chunks 8] [--trace-ring 256]
              [--encode-threads 0] [--metrics-interval-s 10]
              [--max-conns 10000] [--max-line-bytes 262144]
              [--client-buffer 1048576] [--client-buffer-policy disconnect]
  client      --port 7878 --prompt \"...\" [--max-tokens 32] [--top-k 40]
              [--seed 7] [--session 12] [--stream] [--priority batch]
              [--policy name]
  gen-corpus  --corpus wiki2s --split train --bytes 200000 [--out file]
";

fn main() {
    if std::env::var_os("RUST_LOG").is_some() {
        // Minimal logger: level-filtered stderr (no env_logger offline).
        let _ = log::set_boxed_logger(Box::new(StderrLog));
        log::set_max_level(log::LevelFilter::Info);
    }
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct StderrLog;
impl log::Log for StderrLog {
    fn enabled(&self, m: &log::Metadata) -> bool {
        m.level() <= log::Level::Info
    }
    fn log(&self, r: &log::Record) {
        if self.enabled(r.metadata()) {
            eprintln!("[{}] {}", r.level(), r.args());
        }
    }
    fn flush(&self) {}
}

fn dispatch(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "selfcheck" => selfcheck(),
        "info" => info(),
        "train" => cmd_train(args),
        "calibrate" => cmd_calibrate(args),
        "learn-cq" => cmd_learn_cq(args),
        "eval-ppl" => cmd_eval_ppl(args),
        "eval-tasks" => cmd_eval_tasks(args),
        "alloc-policy" => cmd_alloc_policy(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "gen-corpus" => cmd_gen_corpus(args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn corpus_of(args: &Args, default: &str) -> Result<CorpusKind> {
    let name = args.str("corpus", default);
    CorpusKind::parse(&name).with_context(|| format!("unknown corpus '{name}'"))
}

fn selfcheck() -> Result<()> {
    let engine = Engine::load_default()?;
    println!("artifacts: {}", engine.dir.display());
    let params = engine.init_params("small")?;
    let ds = Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Test), 40_000);
    let batches = eval_batches(&ds, 4, engine.manifest.model("small")?.eval_ctx, 1);
    let r = perplexity(&engine, "small", &params, &cq::quant::Fp16, &batches, PplMode::Fast)?;
    println!(
        "selfcheck OK: eval_kv over {} tokens, random-init ppl {:.1} (≈ vocab 256 expected)",
        r.tokens,
        r.ppl()
    );
    Ok(())
}

fn info() -> Result<()> {
    let engine = Engine::load_default()?;
    println!("artifacts dir: {}", engine.dir.display());
    for (name, m) in &engine.manifest.models {
        println!(
            "model {name}: params={} L={} H={} hd={} d={} ctx(train/eval/serve)={}/{}/{}",
            m.param_count, m.n_layers, m.n_heads, m.head_dim, m.d_model,
            m.train_ctx, m.eval_ctx, m.serve_ctx
        );
    }
    for (name, a) in &engine.manifest.artifacts {
        let ins: usize = a.inputs.iter().map(|i| i.numel()).sum();
        println!("  {name}: {} inputs ({} elems)", a.inputs.len(), ins);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let engine = Engine::load_default()?;
    let params0 = engine.init_params(&model)?;
    let ds = Dataset::from_corpus(CorpusSpec::new(corpus_of(args, "wiki2s")?, Split::Train), 2_000_000);
    let cfg = TrainCfg {
        steps: args.usize("steps", 400),
        lr_max: args.f64("lr", 3e-3),
        warmup: args.usize("warmup", 40),
        seed: args.u64("seed", 7),
        log_every: args.usize("log-every", 20),
    };
    println!("training '{model}' for {} steps on {}", cfg.steps, ds.name);
    let result = train(&engine, &model, params0, &ds, &cfg)?;
    let dir = ckpt_dir(&model);
    save_checkpoint(&dir, &model, &result.params, &result.losses)?;
    println!(
        "done: final loss {:.4} in {:.1}s -> {}",
        result.final_loss,
        result.secs,
        dir.display()
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let engine = Engine::load_default()?;
    let params = load_checkpoint(&engine, &model, &ckpt_dir(&model))?;
    let ds = Dataset::from_corpus(CorpusSpec::new(corpus_of(args, "wiki2s")?, Split::Train), 2_000_000);
    let n_seqs = args.usize("seqs", 16);
    println!("calibrating '{model}' on {n_seqs} sequences (paper: 16)");
    let t0 = std::time::Instant::now();
    let calib = cq::calib::calibrate(&engine, &model, &params, &ds, n_seqs)?;
    calib.save(&ckpt_dir(&model))?;
    println!(
        "calibration saved: K/V {:?} in {:.1}s",
        calib.k.shape,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_learn_cq(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let spec = parse_cq(&args.str("spec", "8c8b"))?;
    let fisher = !args.flag("no-fisher");
    let engine = Engine::load_default()?;
    let dir = ckpt_dir(&model);
    let calib = CalibData::load(&dir)?;
    let _ = &engine;
    println!(
        "learning CQ-{} codebooks (fisher={fisher}, iters={})",
        spec.tag(),
        args.usize("iters", 40)
    );
    let books = CqCodebooks::learn(
        spec,
        &calib.k,
        &calib.v,
        fisher.then_some(&calib.gk),
        fisher.then_some(&calib.gv),
        LearnCfg { fisher, max_iters: args.usize("iters", 40), seed: args.u64("seed", 0) },
    );
    let path = dir.join(format!("cq_{}.cqb", spec.tag()));
    books.save(&path)?;
    println!(
        "saved {} ({} centroid params, learned in {:.1}s)",
        path.display(),
        books.centroid_param_count(),
        books.learn_secs
    );
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let codec_name = args.str("codec", "fp16");
    let engine = Engine::load_default()?;
    let params = load_checkpoint(&engine, &model, &ckpt_dir(&model))?;
    // `--codec` accepts full policy specs (`cq-8c8b-w64-s4`); a plain table
    // row builds the factory codec unwrapped.  `--policy-file` evals an
    // allocator-produced descriptor JSON (per-layer assignments included).
    let desc = if args.has("policy-file") {
        let path = args.str("policy-file", "");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read policy file {path}"))?;
        PolicyDescriptor::from_json(&Json::parse(text.trim())?)?
    } else {
        PolicyDescriptor::parse(&codec_name)?
    };
    let wants_calib = needs_calibration(&desc.base)
        || desc.layers.iter().any(|a| needs_calibration(&a.codec));
    let calib = if wants_calib {
        Some(CalibData::load(&ckpt_dir(&model))?)
    } else {
        None
    };
    let fcfg = FactoryCfg {
        fisher: !args.flag("no-fisher"),
        max_iters: args.usize("iters", 40),
        seed: args.u64("seed", 0),
    };
    let kind = corpus_of(args, "wiki2s")?;
    let mm = engine.manifest.model(&model)?;
    // Amortize any fp window over the eval context so the printed bits/FPN
    // matches what this run actually held resident.
    let codec = build_policy_codec(&desc, calib.as_ref(), fcfg, mm.eval_ctx)?;
    let n_batches = args.usize("batches", 8);
    let ds = Dataset::from_corpus(
        CorpusSpec::new(kind, Split::Test),
        n_batches * 4 * mm.eval_ctx + 4096,
    );
    let batches = eval_batches(&ds, 4, mm.eval_ctx, n_batches);
    let mode = if args.flag("exact") { PplMode::Exact } else { PplMode::Fast };
    let r = perplexity(&engine, &model, &params, codec.as_ref(), &batches, mode)?;
    println!(
        "{:<16} bits/FPN {:<5.2} corpus {:<7} ppl {:>9.3}  (kerr {:.1} verr {:.1}, {} tokens)",
        codec.name(),
        codec.bits_per_fpn(),
        kind.name(),
        r.ppl(),
        r.k_err,
        r.v_err,
        r.tokens
    );
    Ok(())
}

fn cmd_eval_tasks(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let codec_name = args.str("codec", "fp16");
    let engine = Engine::load_default()?;
    let params = load_checkpoint(&engine, &model, &ckpt_dir(&model))?;
    let calib = if needs_calibration(&codec_name) {
        Some(CalibData::load(&ckpt_dir(&model))?)
    } else {
        None
    };
    let codec = build_codec(
        &codec_name,
        calib.as_ref(),
        FactoryCfg { fisher: !args.flag("no-fisher"), max_iters: args.usize("iters", 40), seed: 0 },
    )?;
    let n = args.usize("items", 120);
    for kind in TaskKind::all() {
        let set = TaskSet::generate(kind, n, 42);
        let acc = task_accuracy(&engine, &model, &params, codec.as_ref(), &set)?;
        println!("{:<16} task {:<9} acc {:.2}%", codec.name(), kind.name(), acc * 100.0);
    }
    Ok(())
}

/// Calibration-time per-layer bit allocation: score each layer's ppl
/// sensitivity (nll delta when only that layer's cache is quantized by the
/// probe codec), then greedily spend a mean bits-per-layer budget across
/// the scalar precision ladder.  Prints the sensitivity table and emits the
/// resulting descriptor JSON (stdout or `--out`) for `eval-ppl
/// --policy-file`.
fn cmd_alloc_policy(args: &Args) -> Result<()> {
    let model = args.str("model", "small");
    let engine = Engine::load_default()?;
    let params = load_checkpoint(&engine, &model, &ckpt_dir(&model))?;
    let probe_name = args.str("probe", "int2");
    let calib = if needs_calibration(&probe_name) {
        Some(CalibData::load(&ckpt_dir(&model))?)
    } else {
        None
    };
    let fcfg = FactoryCfg {
        fisher: !args.flag("no-fisher"),
        max_iters: args.usize("iters", 40),
        seed: args.u64("seed", 0),
    };
    let probe = build_codec(&probe_name, calib.as_ref(), fcfg)?;
    let kind = corpus_of(args, "wiki2s")?;
    let mm = engine.manifest.model(&model)?;
    let n_batches = args.usize("batches", 4);
    let ds = Dataset::from_corpus(
        CorpusSpec::new(kind, Split::Test),
        n_batches * 4 * mm.eval_ctx + 4096,
    );
    let batches = eval_batches(&ds, 4, mm.eval_ctx, n_batches);
    println!(
        "scoring {}-layer sensitivity with probe '{}' over {n_batches} batches",
        mm.n_layers,
        probe.name()
    );
    let sens = cq::eval::layer_sensitivity(&engine, &model, &params, probe.as_ref(), &batches)?;
    for (l, s) in sens.iter().enumerate() {
        println!("  layer {l:>2}: nll delta {s:+.5}");
    }
    let menu = menu_from_rows(DEFAULT_MENU_ROWS, None, &fcfg)?;
    let budget = args.f64("budget-bits", 6.0);
    let mut desc = PolicyDescriptor::parse(&args.str("spec", "int2"))?;
    desc.layers = greedy_allocate(&sens, &menu, budget);
    let mean: f64 =
        desc.layers.iter().map(|a| a.bits).sum::<f64>() / desc.layers.len().max(1) as f64;
    println!("allocated {:.2} mean bits/layer under budget {budget:.2}:", mean);
    for a in &desc.layers {
        println!("  layer {:>2}: {} ({} bits)", a.layer, a.codec, a.bits);
    }
    let json = desc.to_json().dump();
    match args.has("out").then(|| args.str("out", "")) {
        Some(path) => {
            std::fs::write(&path, &json)?;
            println!("descriptor written to {path}");
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn serve_config(args: &Args) -> Result<ServeConfig> {
    let model = args.str("model", "small");
    let cq_tag = if args.has("cq") { Some(args.str("cq", "8c8b")) } else { None };
    let dir = ckpt_dir(&model);
    let codebook_path = cq_tag
        .as_ref()
        .map(|t| dir.join(format!("cq_{t}.cqb")));
    Ok(ServeConfig {
        model,
        cq: cq_tag,
        batch: args.usize("batch", 8),
        cache_budget: args
            .has("cache-budget-mb")
            .then(|| args.usize("cache-budget-mb", 64) * 1024 * 1024),
        codebook_path,
        params_path: dir.join("params.bin"),
        kernel: args.str("kernel", &ServeConfig::default_kernel()),
        block_tokens: args.usize("block-tokens", ServeConfig::default_block_tokens()),
        prefix_sharing: !args.flag("no-prefix-sharing"),
        sim: None,
        faults: None,
        worker_index: 0,
        session_cap: args.usize("session-cap", ServeConfig::default_session_cap()),
        session_ttl: args
            .has("session-ttl-s")
            .then(|| std::time::Duration::from_secs(args.u64("session-ttl-s", 3600))),
        prefill_chunk: args.usize("prefill-chunk", ServeConfig::default_prefill_chunk()),
        ttft_slo_chunks: args
            .has("ttft-slo-chunks")
            .then(|| args.u64("ttft-slo-chunks", 8)),
        trace_ring: args.usize("trace-ring", ServeConfig::default_trace_ring()),
        encode_threads: args.usize("encode-threads", ServeConfig::default_encode_threads()),
        codec: args.has("codec").then(|| args.str("codec", "fp16")),
        policies: args
            .has("policies")
            .then(|| args.str("policies", ""))
            .map(|s| {
                s.split(',')
                    .map(|p| p.trim().to_string())
                    .filter(|p| !p.is_empty())
                    .collect()
            })
            .unwrap_or_default(),
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut cfg = serve_config(args)?;
    // Single-stream generation: a batch-1 decode artifact avoids paying for
    // idle lanes (the serve command keeps the batched default).
    if !args.has("batch") {
        cfg.batch = 1;
    }
    let handle = ServeHandle::start(cfg);
    let req = Request {
        id: 1,
        prompt: args.str("prompt", "The castle of Aldenport "),
        max_new: args.usize("max-tokens", 48),
        temperature: args.f64("temperature", 0.0) as f32,
        top_k: args.usize("top-k", 0),
        seed: args.u64("seed", 1),
        session_id: None,
        priority: cq::coordinator::Priority::Interactive,
        policy: args.has("policy").then(|| args.str("policy", "")),
    };
    let resp = handle.submit(req)?;
    println!("--- completion ({} tokens, cache {}) ---", resp.gen_tokens, human_bytes(resp.cache_bytes));
    println!("{}", resp.text);
    println!(
        "prefill {:.1} ms, decode {:.1} ms ({:.1} tok/s)",
        resp.prefill_ms,
        resp.decode_ms,
        resp.gen_tokens as f64 / (resp.decode_ms / 1e3).max(1e-9)
    );
    handle.shutdown()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = serve_config(args)?;
    let port = args.usize("port", 7878);
    let workers = args.usize("workers", 1).max(1);
    println!(
        "serving model '{}' cache={} batch={} workers={workers} (cache budget sharded per worker)",
        cfg.model,
        cfg.cq.clone().unwrap_or_else(|| "fp16".into()),
        cfg.batch
    );
    if !cfg.policies.is_empty() {
        println!("policies: {}", cfg.policies.join(", "));
    }
    let dflt = cq::server::ServerConfig::default();
    let srv_cfg = cq::server::ServerConfig {
        max_conns: args.usize("max-conns", dflt.max_conns),
        max_line_bytes: args.usize("max-line-bytes", dflt.max_line_bytes),
        buffer: cq::server::BufferPolicy {
            max_bytes: args.usize("client-buffer", dflt.buffer.max_bytes),
            on_full: match args.str("client-buffer-policy", "disconnect").as_str() {
                "disconnect" => cq::server::OverflowPolicy::Disconnect,
                "drop-oldest" => cq::server::OverflowPolicy::DropOldest,
                other => {
                    bail!("unknown --client-buffer-policy {other:?} (use disconnect|drop-oldest)")
                }
            },
        },
    };
    let pool = ServePool::start(cfg, workers);
    let stop = cq::server::StopSignal::new();
    let addr = format!("127.0.0.1:{port}");
    let interval_s = args
        .has("metrics-interval-s")
        .then(|| args.u64("metrics-interval-s", 10).max(1));
    std::thread::scope(|scope| -> Result<()> {
        if let Some(secs) = interval_s {
            let stop = stop.clone();
            let pool = &pool;
            scope.spawn(move || {
                let t0 = std::time::Instant::now();
                let period = std::time::Duration::from_secs(secs);
                let tick = std::time::Duration::from_millis(200);
                let mut next = period;
                // Poll the stop flag at a short tick so shutdown is prompt
                // even with a long reporting interval.
                while !stop.raised() {
                    std::thread::sleep(tick);
                    if t0.elapsed() < next {
                        continue;
                    }
                    next += period;
                    println!("{}", pool.metrics.summary(t0.elapsed().as_secs_f64()));
                    let snap = cq::metrics::export::MetricsSnapshot::collect(
                        &pool.metrics,
                        pool.live_workers(),
                    );
                    if let Err(e) = std::fs::write("cq-serve-metrics.json", snap.to_json().dump()) {
                        log::warn!("metrics snapshot write failed: {e}");
                    }
                }
            });
        }
        let res = cq::server::serve_tcp_cfg(&pool, &addr, stop.clone(), srv_cfg);
        // Whatever path serve_tcp took (bind failure included), the reporter
        // thread must see the flag or the scope would never close.
        stop.raise();
        res
    })?;
    pool.shutdown()
}

fn cmd_client(args: &Args) -> Result<()> {
    let port = args.usize("port", 7878);
    let addr = format!("127.0.0.1:{port}");
    let mut pairs = vec![
        ("prompt", Json::Str(args.str("prompt", "The castle of Aldenport "))),
        ("max_tokens", Json::Num(args.usize("max-tokens", 32) as f64)),
        ("temperature", Json::Num(args.f64("temperature", 0.0))),
        ("top_k", Json::Num(args.usize("top-k", 0) as f64)),
    ];
    if args.has("seed") {
        pairs.push(("seed", Json::Num(args.u64("seed", 0) as f64)));
    }
    if args.has("session") {
        pairs.push(("session", Json::Num(args.u64("session", 0) as f64)));
    }
    if args.has("priority") {
        pairs.push(("priority", Json::Str(args.str("priority", "interactive"))));
    }
    if args.has("policy") {
        pairs.push(("policy", Json::Str(args.str("policy", ""))));
    }
    if args.flag("stream") {
        // Protocol v2: print token text as frames arrive, then the terminal
        // done/failed frame with its latency breakdown.
        pairs.push(("stream", Json::Bool(true)));
        let line = Json::obj(pairs).dump();
        let terminal = cq::server::client_stream(&addr, &line, |frame| {
            if frame.str_or("event", "") == "token" {
                print!("{}", frame.str_or("text", ""));
                use std::io::Write as _;
                let _ = std::io::stdout().flush();
            }
        })?;
        println!();
        println!("{}", terminal.dump());
        return Ok(());
    }
    let resp = cq::server::client_request_line(&addr, &Json::obj(pairs).dump())?;
    println!("{}", resp.dump());
    Ok(())
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let kind = corpus_of(args, "wiki2s")?;
    let split = if args.str("split", "train") == "test" { Split::Test } else { Split::Train };
    let bytes = args.usize("bytes", 200_000);
    let text = CorpusSpec::new(kind, split).generate(bytes);
    match args.has("out").then(|| args.str("out", "")) {
        Some(path) => {
            std::fs::write(&path, &text)?;
            println!("wrote {} bytes to {path}", text.len());
        }
        None => println!("{text}"),
    }
    Ok(())
}

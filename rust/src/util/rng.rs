//! Deterministic PCG64-family random number generator.
//!
//! Replaces the unavailable `rand` crate.  PCG XSL-RR 128/64 (O'Neill 2014):
//! a 128-bit LCG state with an output permutation — fast, statistically
//! solid, and trivially seedable, which matters because every experiment in
//! EXPERIMENTS.md must be reproducible from a printed seed.

/// PCG XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; `stream` selects an independent
    /// sequence (used to give each corpus / task / worker its own stream).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        r.next_u64();
        r.state = r.state.wrapping_add(seed as u128);
        r.next_u64();
        r
    }

    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method, simplified).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 64-bit multiply-shift; bias is < 2^-53 for all n used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        if total <= 0.0 {
            return self.below(w.len());
        }
        let mut x = self.next_f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            x -= wi;
            if x <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::seed(1);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_covers_range_without_overflow() {
        let mut r = Pcg64::seed(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy_bins() {
        let mut r = Pcg64::seed(4);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..5000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}

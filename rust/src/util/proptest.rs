//! Micro property-testing harness (proptest replacement).
//!
//! `run_prop(cases, seed, |rng| { ... })` executes a randomized property
//! `cases` times from a deterministic seed; on failure it reports the case
//! index and per-case seed so the exact input regenerates.  Used by the
//! codec round-trip, packer, scheduler and cache-accounting property tests.

use super::rng::Pcg64;

/// Run `prop` for `cases` randomized cases.  The closure receives a fresh,
/// per-case-seeded RNG; returning `Err(msg)` fails the property with a
/// reproducible seed in the panic message.
pub fn run_prop<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case as u64);
        let mut rng = Pcg64::new(case_seed, 0x5bd1e995);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case}/{cases} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop(50, 1, |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        run_prop(10, 2, |rng| {
            if rng.next_f64() < 0.5 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }
}

//! Benchmark harness (criterion replacement).
//!
//! Used by every target in `benches/` (`harness = false`).  Provides warmup,
//! fixed-iteration timing with percentile reporting, and a table printer so
//! each bench regenerates its paper table/figure as aligned text plus a CSV
//! dump under `bench_out/`.

use std::time::Instant;

/// Timing summary over a set of iterations, in seconds.
#[derive(Debug, Clone)]
pub struct Timing {
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Timing {
    pub fn from_samples(mut s: Vec<f64>) -> Timing {
        assert!(!s.is_empty());
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| s[((s.len() as f64 - 1.0) * p).round() as usize];
        Timing {
            iters: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: s[0],
            max: s[s.len() - 1],
        }
    }

    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing::from_samples(samples)
}

/// Aligned-text table builder used by the table/figure benches.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and dump a CSV copy to `bench_out/<slug>.csv`.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.render());
        let _ = std::fs::create_dir_all("bench_out");
        let mut csv = String::new();
        csv.push_str(&self.header.join(","));
        csv.push('\n');
        for r in &self.rows {
            csv.push_str(
                &r.iter()
                    .map(|c| {
                        if c.contains(',') {
                            format!("\"{c}\"")
                        } else {
                            c.clone()
                        }
                    })
                    .collect::<Vec<_>>()
                    .join(","),
            );
            csv.push('\n');
        }
        let path = format!("bench_out/{slug}.csv");
        if let Err(e) = std::fs::write(&path, csv) {
            eprintln!("warn: could not write {path}: {e}");
        } else {
            println!("[csv] {path}");
        }
    }
}

/// Resolve `name` against the workspace root — the outermost ancestor
/// directory containing a `Cargo.toml` — so machine-readable bench results
/// (`BENCH_*.json`) land at the repo root whether the bench runs from the
/// workspace root or the package directory.
pub fn workspace_file(name: &str) -> std::path::PathBuf {
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut best: Option<std::path::PathBuf> = None;
    loop {
        if d.join("Cargo.toml").exists() {
            best = Some(d.clone());
        }
        if !d.pop() {
            break;
        }
    }
    best.unwrap_or_else(|| ".".into()).join(name)
}

/// Write a machine-readable result file next to the human tables.  The perf
/// trajectory (ROADMAP) is tracked through these dumps, so failures warn
/// instead of panicking — a read-only checkout must not kill the bench.
pub fn emit_json(file_name: &str, json: &crate::util::json::Json) {
    let path = workspace_file(file_name);
    match std::fs::write(&path, json.dump() + "\n") {
        Ok(()) => println!("[json] {}", path.display()),
        Err(e) => eprintln!("warn: could not write {}: {e}", path.display()),
    }
}

/// Format seconds as an adaptive human string.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_percentiles() {
        let t = Timing::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(t.min, 1.0);
        assert_eq!(t.max, 100.0);
        assert_eq!(t.p50, 51.0); // round-half-up on the 49.5 index
        assert!((t.mean - 50.5).abs() < 1e-9);
        assert_eq!(t.p95, 95.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn time_fn_runs_expected_iters() {
        let mut n = 0;
        let t = time_fn(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn workspace_file_resolves_to_outermost_cargo_dir() {
        let p = workspace_file("BENCH_probe.json");
        assert_eq!(p.file_name().unwrap(), "BENCH_probe.json");
        assert!(
            p.parent().unwrap().join("Cargo.toml").exists(),
            "{} has no Cargo.toml",
            p.parent().unwrap().display()
        );
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-5).ends_with("µs"));
        assert!(fmt_secs(2e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with("s"));
    }
}

//! Tiny command-line parser (clap replacement).
//!
//! Supports `binary <subcommand> --flag value --switch` with typed accessors
//! and automatic usage generation from the registered flag set.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub cmd: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token is the subcommand, then `--key value`
    /// pairs, bare `--switch`es (followed by another flag or end), and
    /// positional arguments.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        if argv.is_empty() {
            return Ok(a);
        }
        a.cmd = argv[0].clone();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.switches.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn str_req(&self, key: &str) -> Result<String> {
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("required flag --{key} missing"))
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
            || self
                .flags
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key) || self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        // Note: a bare switch directly followed by a positional would bind as
        // a flag value (inherent --key value ambiguity); switches therefore
        // go last or use --key=true.
        let a = Args::parse(&v(&[
            "train", "--model", "small", "--steps", "400", "pos1", "--resume",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.str("model", "x"), "small");
        assert_eq!(a.usize("steps", 0), 400);
        assert!(a.flag("resume"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&v(&["serve", "--port=9090", "--lr=1e-3"])).unwrap();
        assert_eq!(a.usize("port", 0), 9090);
        assert!((a.f64("lr", 0.0) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(&v(&["x"])).unwrap();
        assert_eq!(a.str("missing", "dflt"), "dflt");
        assert!(a.str_req("missing").is_err());
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = Args::parse(&v(&["x", "--verbose", "--n", "3"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("n", 0), 3);
    }
}

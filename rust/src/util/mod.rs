//! Substrate utilities.
//!
//! The build image is fully offline and its crate cache only contains the
//! `xla` crate's dependency closure, so the usual ecosystem crates (serde,
//! clap, tokio, criterion, proptest, rand) are unavailable.  This module
//! reimplements the thin slices of each that the rest of the crate needs —
//! see DESIGN.md §1 (S17).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod workpool;

/// Format a byte count as a human-readable string.
pub fn human_bytes(n: usize) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut i = 0;
    while x >= 1024.0 && i + 1 < U.len() {
        x /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{n} B")
    } else {
        format!("{x:.2} {}", U[i])
    }
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}

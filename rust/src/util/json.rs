//! Minimal JSON parser/serializer (serde_json replacement).
//!
//! Supports the full JSON grammar minus exotic number formats; used for the
//! artifact manifest, the server wire protocol, and bench result dumps.
//! Numbers are kept as f64 (the manifest only carries shapes and counts,
//! all exactly representable).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Object keys are ordered (BTreeMap) so serialization is
/// deterministic — bench outputs diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
    }
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    // -- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
    }
    pub fn arr_num<T: Into<f64> + Copy>(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x.into())).collect())
    }

    /// Serialize compactly (single line — the server wire format).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: join if a high surrogate.
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    bail!("lone surrogate");
                                }
                            } else {
                                cp
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_structures() {
        let src = r#"{"m": {"x": {"y": [[1], [2, [3]]]}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café 😀 ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀 ok");
        let s = Json::Str("tab\t\"q\" ünïcode".into());
        assert_eq!(Json::parse(&s.dump()).unwrap(), s);
    }

    #[test]
    fn numbers() {
        for (s, x) in [("0", 0.0), ("-12", -12.0), ("3.5e2", 350.0), ("1e-3", 0.001)] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), x);
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(42.5).dump(), "42.5");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn manifest_shape_roundtrip() {
        // The exact structure written by python/compile/config.py.
        let src = r#"{"version": 1, "artifacts": [{"name": "m.eval_kv",
            "inputs": [{"dtype": "f32", "shape": [3, 4], "name": "params"}],
            "outputs": [], "meta": {"batch": 4}}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "m.eval_kv");
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect::<Vec<_>>();
        assert_eq!(shape, vec![3, 4]);
    }
}

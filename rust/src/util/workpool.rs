//! Persistent scoped worker pool for the prefill-encode hot path.
//!
//! `encode_span_parallel` used to pay `std::thread::scope` + one OS thread
//! spawn per layer on *every* prefill chunk — tens of µs of kernel time per
//! chunk before any centroid math ran.  A [`WorkPool`] amortizes that: each
//! serve worker creates one pool at startup (sized `--encode-threads`),
//! parks the threads on a condvar between chunks, and re-uses them for the
//! whole worker lifetime.  [`WorkPool::spawned_total`] is the probe that
//! proves the "no per-chunk spawns" claim in unit tests.
//!
//! # Lifecycle
//!
//! * **Create once** — [`WorkPool::new`] spawns `threads` workers
//!   (`threads <= 1` spawns none: the inline fallback runs every task on
//!   the caller, so tests and build-only hosts need no pool).
//! * **Borrow per chunk** — [`WorkPool::scope`] hands out a [`Scope`]
//!   whose [`Scope::spawn`] accepts non-`'static` closures (the encode
//!   tasks borrow the activation tensors and output buffers of the current
//!   chunk).  `scope` does not return until every spawned task has
//!   finished — enforced by a drop guard, so it holds even if the scope
//!   body unwinds.
//! * **Panics propagate** — each task runs under `catch_unwind`; a
//!   panicked task never takes down a pool thread.  Instead `scope`
//!   re-raises on the *caller* after the drain, so an encode bug surfaces
//!   on the serve loop (where the crash guards and the supervisor's
//!   retire/re-dispatch machinery expect it), not on an anonymous pool
//!   thread.
//! * **Join on drop** — dropping the pool (worker retirement, normal or
//!   panic unwind) sets the shutdown flag, wakes every worker, and joins
//!   them; the optional exit hook then fires, which serving uses to zero
//!   the `encode_pool_threads` gauge so "pool threads never outlive the
//!   retired worker" is observable from chaos tests.
//!
//! # Safety
//!
//! Tasks are transmuted to `'static` to cross the queue. This is sound for
//! the same reason `std::thread::scope` is: the scope's drop guard blocks
//! until `pending == 0` before control can leave `scope`, so no task can
//! outlive the borrows it captures.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A queued unit of work (type-erased, lifetime-erased encode task).
type Task = Box<dyn FnOnce() + Send + 'static>;

struct State {
    queue: VecDeque<Task>,
    /// Tasks spawned into the current scope and not yet finished
    /// (queued + running).  `scope` returns only once this is zero.
    pending: usize,
    /// Tasks that panicked since the last scope drain.
    panicked: usize,
    shutdown: bool,
    /// Per-worker executed-task counters (observability + tests).
    executed: Vec<u64>,
    /// Tasks spawned into the most recently drained scope.
    last_scope_tasks: u64,
    /// Tasks spawned into the scope currently open (if any).
    open_scope_tasks: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Signals workers: work available (or shutdown).
    work_cv: Condvar,
    /// Signals the scope owner: `pending` reached zero.
    done_cv: Condvar,
}

/// Long-lived encode worker pool.  See the module doc for the lifecycle.
pub struct WorkPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// OS threads ever spawned by this pool — constant after `new`, which
    /// is exactly the "no per-chunk thread spawns" claim.
    spawned_total: usize,
    /// Runs after every worker has been joined on drop.
    exit_hook: Option<Box<dyn FnOnce() + Send>>,
}

impl WorkPool {
    /// Create a pool with `threads` workers.  `threads <= 1` creates the
    /// inline fallback: no OS threads, every task runs on the caller.
    pub fn new(threads: usize) -> WorkPool {
        let workers = if threads <= 1 { 0 } else { threads };
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                pending: 0,
                panicked: 0,
                shutdown: false,
                executed: vec![0; workers],
                last_scope_tasks: 0,
                open_scope_tasks: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cq-encode-{i}"))
                    .spawn(move || worker_loop(&sh, i))
                    .expect("spawn encode worker")
            })
            .collect::<Vec<_>>();
        let spawned_total = handles.len();
        WorkPool { shared, handles, spawned_total, exit_hook: None }
    }

    /// Register a hook that runs once every worker thread has been joined
    /// (i.e. after the threads are provably dead).  Serving points this at
    /// the worker's `encode_pool_threads` gauge.
    pub fn on_exit(&mut self, hook: impl FnOnce() + Send + 'static) {
        self.exit_hook = Some(Box::new(hook));
    }

    /// Number of pool worker threads (0 for the inline fallback).
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Parallel width for fan-out sizing: worker threads, or 1 inline.
    pub fn width(&self) -> usize {
        self.handles.len().max(1)
    }

    /// OS threads ever spawned by this pool (constant after construction).
    pub fn spawned_total(&self) -> usize {
        self.spawned_total
    }

    /// Per-worker executed-task counters (empty for the inline fallback).
    pub fn per_thread_tasks(&self) -> Vec<u64> {
        self.shared.state.lock().unwrap().executed.clone()
    }

    /// Tasks spawned into the most recently completed scope (inline scopes
    /// included) — the instantaneous `encode_pool_busy` observable.
    pub fn last_scope_tasks(&self) -> u64 {
        self.shared.state.lock().unwrap().last_scope_tasks
    }

    /// Run `f` with a [`Scope`] that can spawn borrowing tasks onto the
    /// pool.  Returns only after every spawned task finished; re-raises on
    /// this thread if any task panicked.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'_, 'env>) -> R,
    {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open_scope_tasks = 0;
        }
        let scope = Scope { pool: self, _env: PhantomData };
        // The guard drains on unwind too: no task may outlive `'env`.
        let guard = DrainGuard(self);
        let r = f(&scope);
        drop(guard);
        r
    }

    fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.last_scope_tasks = st.open_scope_tasks;
    }
}

/// Blocks until the pool drains, then propagates task panics — runs even
/// when the scope body itself unwinds (in which case task panics are
/// swallowed: the caller is already panicking).
struct DrainGuard<'a>(&'a WorkPool);

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_idle();
        let n = {
            let mut st = self.0.shared.state.lock().unwrap();
            std::mem::take(&mut st.panicked)
        };
        if n > 0 && !std::thread::panicking() {
            panic!("workpool: {n} encode task(s) panicked");
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            // Workers catch task panics, so join only fails on a harness
            // bug; never double-panic during an unwind.
            if h.join().is_err() && !std::thread::panicking() {
                panic!("workpool: encode worker thread panicked");
            }
        }
        if let Some(hook) = self.exit_hook.take() {
            hook();
        }
    }
}

/// Handle for spawning borrowing tasks; only obtainable inside
/// [`WorkPool::scope`], which guarantees the drain before `'env` ends.
pub struct Scope<'pool, 'env> {
    pool: &'pool WorkPool,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> Scope<'_, 'env> {
    /// Queue `f` onto the pool (inline fallback: run it immediately on the
    /// caller, where a panic propagates natively).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        if self.pool.handles.is_empty() {
            let mut st = self.pool.shared.state.lock().unwrap();
            st.open_scope_tasks += 1;
            drop(st);
            f();
            return;
        }
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        // SAFETY: `scope` blocks until `pending == 0` before returning
        // (via DrainGuard, unwind included), so the task cannot outlive
        // the `'env` borrows it captures.  Same argument as
        // `std::thread::scope`.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
        };
        let mut st = self.pool.shared.state.lock().unwrap();
        st.pending += 1;
        st.open_scope_tasks += 1;
        st.queue.push_back(task);
        drop(st);
        self.pool.shared.work_cv.notify_one();
    }
}

fn worker_loop(sh: &Shared, index: usize) {
    let mut st = sh.state.lock().unwrap();
    loop {
        if let Some(task) = st.queue.pop_front() {
            drop(st);
            let panicked = catch_unwind(AssertUnwindSafe(task)).is_err();
            st = sh.state.lock().unwrap();
            st.executed[index] += 1;
            if panicked {
                st.panicked += 1;
            }
            st.pending -= 1;
            if st.pending == 0 {
                sh.done_cv.notify_all();
            }
        } else if st.shutdown {
            return;
        } else {
            st = sh.work_cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn inline_fallback_runs_tasks_on_the_caller() {
        let pool = WorkPool::new(1);
        assert_eq!(pool.threads(), 0, "<=1 threads means no pool threads");
        assert_eq!(pool.width(), 1);
        let caller = std::thread::current().id();
        let mut out = vec![0u32; 4];
        pool.scope(|s| {
            for (i, slot) in out.iter_mut().enumerate() {
                s.spawn(move || {
                    assert_eq!(std::thread::current().id(), caller);
                    *slot = i as u32 + 1;
                });
            }
        });
        assert_eq!(out, [1, 2, 3, 4]);
        assert_eq!(pool.last_scope_tasks(), 4);
        assert_eq!(pool.spawned_total(), 0);
    }

    #[test]
    fn threads_spawn_once_per_pool_lifetime_not_per_scope() {
        let pool = WorkPool::new(3);
        assert_eq!(pool.threads(), 3);
        let hits = AtomicUsize::new(0);
        // Many scopes — the per-chunk pattern.  The spawn counter must not
        // move: that is the "no per-chunk thread spawns" acceptance probe.
        for _ in 0..50 {
            pool.scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 200);
        assert_eq!(pool.spawned_total(), 3, "threads created once, reused across scopes");
        assert_eq!(pool.per_thread_tasks().iter().sum::<u64>(), 200);
        assert_eq!(pool.last_scope_tasks(), 4);
    }

    #[test]
    fn all_pool_threads_receive_work() {
        const THREADS: usize = 4;
        let pool = WorkPool::new(THREADS);
        // Each task parks until all THREADS tasks have started: a thread
        // cannot run a second task while its first is parked, so every
        // pool thread must pick up exactly one.
        let arrived = Arc::new((Mutex::new(0usize), Condvar::new()));
        pool.scope(|s| {
            for _ in 0..THREADS {
                let arrived = arrived.clone();
                s.spawn(move || {
                    let (lock, cv) = &*arrived;
                    let mut n = lock.lock().unwrap();
                    *n += 1;
                    cv.notify_all();
                    while *n < THREADS {
                        n = cv.wait(n).unwrap();
                    }
                });
            }
        });
        let per = pool.per_thread_tasks();
        assert_eq!(per.len(), THREADS);
        assert!(
            per.iter().all(|&c| c == 1),
            "every pool thread must have taken exactly one task: {per:?}"
        );
    }

    #[test]
    fn task_panic_propagates_to_the_scope_caller_and_pool_survives() {
        let pool = WorkPool::new(2);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
                s.spawn(|| {});
            });
        }));
        assert!(err.is_err(), "task panic must re-raise on the scope caller");
        // The pool is still serviceable: no thread died with the task.
        let ran = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(ran.load(Ordering::SeqCst), 4);
        assert_eq!(pool.spawned_total(), 2);
    }

    #[test]
    fn drop_joins_workers_then_fires_exit_hook() {
        let fired = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkPool::new(2);
        let f = fired.clone();
        pool.on_exit(move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        pool.scope(|s| s.spawn(|| {}));
        assert_eq!(fired.load(Ordering::SeqCst), 0, "hook only fires at drop");
        drop(pool);
        assert_eq!(fired.load(Ordering::SeqCst), 1, "hook fired after join");
    }

    #[test]
    fn scope_blocks_until_borrowed_work_finishes() {
        let pool = WorkPool::new(2);
        let mut data = vec![0u64; 64];
        pool.scope(|s| {
            for chunk in data.chunks_mut(16) {
                s.spawn(move || {
                    for v in chunk.iter_mut() {
                        *v += 7;
                    }
                });
            }
        });
        // If scope returned early this read would race the tasks (and
        // miri/tsan would flag it); the sum proves every task ran.
        assert_eq!(data.iter().sum::<u64>(), 64 * 7);
    }
}

//! Figure 3: 1-bit-per-channel quantization of the first two channels of
//! layer-0 keys — independent channel-wise (CQ-1c1b) vs coupled (CQ-2c2b),
//! both at 1 bit/FPN.  Prints the MSEs and dumps original + both
//! reconstructions as scatter CSVs.
//!
//! Expected shape (paper Fig. 3): channel-wise 1-bit collapses each channel
//! to 2 values (a 2×2 grid in the plane, large error); coupling places 4
//! centroids wherever the 2-D mass actually lies, cutting error sharply.
//!
//!     cargo bench --bench fig3_quantviz

use cq::bench_support::Pipeline;
use cq::quant::cq::CqSpec;
use cq::quant::{gather_channel, Codec, KvKind};
use cq::util::bench::Table;

fn main() {
    let pipe = Pipeline::ensure("small").expect("pipeline");
    let k = &pipe.calib.k;
    let ch0 = gather_channel(k, 0, 0, 0);
    let ch1 = gather_channel(k, 0, 0, 1);

    // Quantize the full key tensor with each scheme; read back the two
    // channels for the scatter.
    let mut rows: Vec<(String, Vec<f32>, Vec<f32>, f64)> =
        vec![("original".into(), ch0.clone(), ch1.clone(), 0.0)];
    let mut table = Table::new(
        "Figure 3: 1 bit/FPN on (ch0, ch1) of layer-0 keys — channel-wise vs coupled",
        &["scheme", "bits/FPN", "mse(ch0,ch1)", "distinct points"],
    );
    for (label, spec) in [
        ("channel-wise 1-bit (CQ-1c1b)", CqSpec::new(1, 1)),
        ("coupled 2-bit/2ch (CQ-2c2b)", CqSpec::new(2, 2)),
    ] {
        let codec = pipe.cq_codec(spec, false, 60).expect("codec");
        let mut kq = k.clone();
        codec.apply(KvKind::Key, &mut kq);
        let q0 = gather_channel(&kq, 0, 0, 0);
        let q1 = gather_channel(&kq, 0, 0, 1);
        let mse: f64 = ch0
            .iter()
            .zip(&q0)
            .chain(ch1.iter().zip(&q1))
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / (2.0 * ch0.len() as f64);
        let mut pts: Vec<(i64, i64)> = q0
            .iter()
            .zip(&q1)
            .map(|(a, b)| ((a * 1e4) as i64, (b * 1e4) as i64))
            .collect();
        pts.sort();
        pts.dedup();
        eprintln!("  {label}: mse {mse:.5}, {} distinct 2-D points", pts.len());
        table.row(vec![
            label.to_string(),
            "1.00".into(),
            format!("{mse:.5}"),
            pts.len().to_string(),
        ]);
        rows.push((label.to_string(), q0, q1, mse));
    }
    table.emit("fig3_quantviz");

    // Scatter CSV: x, y per scheme.
    let _ = std::fs::create_dir_all("bench_out");
    for (label, x, y, _) in &rows {
        let slug = label
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect::<String>();
        let csv: String = x
            .iter()
            .zip(y)
            .map(|(a, b)| format!("{a},{b}\n"))
            .collect();
        let path = format!("bench_out/fig3_scatter_{slug}.csv");
        let _ = std::fs::write(&path, csv);
        println!("[csv] {path}");
    }
    // Shape assertion: coupling must cut the MSE.
    assert!(
        rows[2].3 < rows[1].3 * 0.9,
        "coupled MSE {} should beat channel-wise {}",
        rows[2].3,
        rows[1].3
    );
    println!("coupled quantization reduces 2-channel MSE {:.1}x (paper Fig. 3 shape)",
             rows[1].3 / rows[2].3.max(1e-12));
}

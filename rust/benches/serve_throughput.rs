//! Serving bench (paper §2.2 von-Neumann argument, extra to the tables):
//! decode-step latency, end-to-end throughput, cache footprint and modelled
//! memory traffic for the fp16 cache vs CQ caches at batch 1 and 8.
//!
//! On this CPU-interpret testbed the *measured* decode time is compute-bound
//! (XLA CPU is not bandwidth-starved at these sizes), so the table reports
//! both the measured times AND the bandwidth-bound traffic model that
//! governs real accelerators: bytes-touched-per-token ratios are exact.
//!
//!     cargo bench --bench serve_throughput  [-- --requests 8 --max-tokens 16]

use std::time::Instant;

use cq::bench_support::Pipeline;
use cq::coordinator::{Request, ServeConfig, ServeHandle};
use cq::metrics::TrafficModel;
use cq::quant::cq::CqSpec;
use cq::util::bench::Table;
use cq::util::cli::Args;

struct ModeResult {
    label: String,
    bits: f64,
    tokens_per_s: f64,
    decode_p50_ms: f64,
    cache_bytes: usize,
}

fn run_mode(cq: Option<&str>, batch: usize, n_req: usize, max_new: usize) -> ModeResult {
    let label = cq.unwrap_or("fp16").to_string();
    let cfg = ServeConfig {
        model: "small".into(),
        cq: cq.map(|s| s.to_string()),
        batch,
        cache_budget: None,
        codebook_path: cq.map(|t| cq::train::ckpt_dir("small").join(format!("cq_{t}.cqb"))),
        params_path: cq::train::ckpt_dir("small").join("params.bin"),
        kernel: ServeConfig::default_kernel(),
    };
    let handle = ServeHandle::start(cfg);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            handle
                .submit_async(Request::greedy(i as u64, "The castle of Aldenport ", max_new))
                .unwrap()
        })
        .collect();
    let mut tokens = 0;
    let mut cache = 0;
    for rx in rxs {
        let r = rx.recv().unwrap();
        tokens += r.gen_tokens;
        cache += r.cache_bytes;
    }
    let wall = t0.elapsed().as_secs_f64();
    let bits = match cq {
        None => 16.0,
        Some(t) => {
            let spec: Vec<&str> = t.split('c').collect();
            let c: f64 = spec[0].parse().unwrap();
            let b: f64 = spec[1].trim_end_matches('b').parse().unwrap();
            b / c
        }
    };
    let res = ModeResult {
        label,
        bits,
        tokens_per_s: tokens as f64 / wall,
        decode_p50_ms: handle.metrics.decode_step_latency.percentile_ms(0.5),
        cache_bytes: cache,
    };
    handle.shutdown().unwrap();
    res
}

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let max_new = args.usize("max-tokens", 12);

    // Ensure checkpoint + all serve codebooks exist.
    {
        let pipe = Pipeline::ensure("small").expect("pipeline");
        for spec in [CqSpec::new(2, 8), CqSpec::new(4, 8), CqSpec::new(8, 8)] {
            pipe.cq_codec(spec, true, 40).expect("codebooks");
        }
    }

    let mut table = Table::new(
        "Serving: decode latency / throughput / cache bytes, fp16 vs CQ",
        &["cache", "bits/FPN", "batch", "tok/s", "decode p50 (ms)",
          "cache bytes", "traffic/token @T=512", "bw-bound speedup ceiling"],
    );
    for batch in [1usize, 8] {
        let n_req = args.usize("requests", batch.max(4));
        for mode in [None, Some("2c8b"), Some("4c8b"), Some("8c8b")] {
            let r = run_mode(mode, batch, n_req, max_new);
            let tm = TrafficModel {
                n_layers: 4,
                n_heads: 4,
                head_dim: 64,
                bits_per_fpn: r.bits,
            };
            eprintln!(
                "  {:<5} b{batch}: {:.1} tok/s, p50 {:.1} ms, cache {}",
                r.label, r.tokens_per_s, r.decode_p50_ms, r.cache_bytes
            );
            table.row(vec![
                r.label.clone(),
                format!("{:.2}", r.bits),
                batch.to_string(),
                format!("{:.1}", r.tokens_per_s),
                format!("{:.2}", r.decode_p50_ms),
                r.cache_bytes.to_string(),
                format!("{:.0} B", tm.bytes_per_decode(512)),
                format!("{:.1}x", tm.speedup_vs_fp16()),
            ]);
        }
    }
    table.emit("serve_throughput");
}

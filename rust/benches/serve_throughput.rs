//! Serving bench (paper §2.2 von-Neumann argument, extra to the tables):
//! decode-step latency, end-to-end throughput, cache footprint and modelled
//! memory traffic for the fp16 cache vs CQ caches — plus a serve-pool
//! worker sweep that isolates how each cache mode scales across replica
//! workers (each worker = its own PJRT engine + cache shard).
//!
//! On this CPU-interpret testbed the *measured* decode time is compute-bound
//! (XLA CPU is not bandwidth-starved at these sizes), so the table reports
//! both the measured times AND the bandwidth-bound traffic model that
//! governs real accelerators: bytes-touched-per-token ratios are exact.
//!
//!     cargo bench --bench serve_throughput \
//!         [-- --requests 16 --max-tokens 16 --workers 1,2,4 --clients 8 \
//!          --idle-clients 256 --check]
//!
//! `--check` enforces the committed `BENCH_serve.json` throughput floors
//! (>15% regression exits nonzero); without a runtime, or against an
//! unmeasured floor file, it establishes instead of enforcing.  The
//! idle-connection frontend scenario runs on the sim backend, so it
//! measures (and asserts) on every host, runtime or not.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use cq::bench_support::Pipeline;
use cq::coordinator::{Event, FaultPlan, Request, ServeConfig, ServePool, SimSpec, StreamHandle};
use cq::metrics::TrafficModel;
use cq::quant::cq::CqSpec;
use cq::server::{client_request_line, serve_tcp, StopSignal};
use cq::util::bench::{emit_json, workspace_file, Table, Timing};
use cq::util::cli::Args;
use cq::util::json::Json;

/// One machine-readable scenario row for `BENCH_serve.json`.
fn scenario_json(name: &str, tokens_per_s: f64, hit_rate: Option<f64>) -> Json {
    let us_per_token = if tokens_per_s > 0.0 { 1e6 / tokens_per_s } else { 0.0 };
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("tok_per_s", Json::Num(tokens_per_s)),
        ("us_per_token", Json::Num(us_per_token)),
    ];
    if let Some(h) = hit_rate {
        pairs.push(("hit_rate", Json::Num(h)));
    }
    Json::obj(pairs)
}

fn emit_serve_json(runtime: bool, scenarios: Vec<Json>) {
    emit_json(
        "BENCH_serve.json",
        &Json::obj(vec![
            ("bench", Json::Str("serve_throughput".into())),
            ("measured", Json::Bool(runtime)),
            ("runtime_available", Json::Bool(runtime)),
            ("scenarios", Json::Arr(scenarios)),
        ]),
    );
}

/// Allowed `--check` slack below a committed throughput floor before the
/// run fails (serving numbers are noisier than the quant microbench, but
/// 15% still catches any structural regression on the decode/prefill path).
const CHECK_TOLERANCE: f64 = 0.15;

/// `--check` floor enforcement against the committed `BENCH_serve.json`:
/// every scenario with a fresh `tok_per_s` and a committed counterpart must
/// stay above `floor * (1 - CHECK_TOLERANCE)`.  Missing or `measured:
/// false` floors establish instead of enforcing, so the first measured run
/// on real hardware sets the bar and later runs are held to it.
fn check_floors(committed: Option<&Json>, fresh: &[Json]) -> usize {
    let Some(c) = committed else {
        eprintln!("check: no parseable committed BENCH_serve.json; establishing floors");
        return 0;
    };
    if c.get("measured").and_then(Json::as_bool) != Some(true) {
        eprintln!("check: committed floors are unmeasured; establishing floors");
        return 0;
    }
    let floors = c.get("scenarios").and_then(Json::as_arr).unwrap_or(&[]);
    let mut regressions = 0;
    for s in fresh {
        let name = s.get("name").and_then(Json::as_str);
        let tps = s.get("tok_per_s").and_then(Json::as_f64);
        let (Some(name), Some(tps)) = (name, tps) else { continue };
        let floor = floors
            .iter()
            .find(|f| f.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|f| f.get("tok_per_s").and_then(Json::as_f64));
        let Some(floor) = floor else { continue };
        let limit = floor * (1.0 - CHECK_TOLERANCE);
        let ok = tps >= limit;
        if !ok {
            regressions += 1;
        }
        eprintln!(
            "check: {name}: {tps:.1} tok/s vs floor {floor:.1} (limit {limit:.1}) {}",
            if ok { "ok" } else { "REGRESSION" }
        );
    }
    regressions
}

struct ModeResult {
    label: String,
    bits: f64,
    tokens_per_s: f64,
    decode_p50_ms: f64,
    cache_bytes: usize,
    /// Per-worker tokens/s over the same wall window.
    per_worker: Vec<f64>,
}

fn mode_cfg(cq: Option<&str>, batch: usize) -> ServeConfig {
    ServeConfig {
        model: "small".into(),
        cq: cq.map(|s| s.to_string()),
        batch,
        cache_budget: None,
        codebook_path: cq.map(|t| cq::train::ckpt_dir("small").join(format!("cq_{t}.cqb"))),
        params_path: cq::train::ckpt_dir("small").join("params.bin"),
        kernel: ServeConfig::default_kernel(),
        block_tokens: ServeConfig::default_block_tokens(),
        prefix_sharing: true,
        sim: None,
        faults: None,
        worker_index: 0,
        session_cap: ServeConfig::default_session_cap(),
        session_ttl: None,
        prefill_chunk: ServeConfig::default_prefill_chunk(),
        ttft_slo_chunks: None,
        trace_ring: ServeConfig::default_trace_ring(),
        encode_threads: ServeConfig::default_encode_threads(),
        codec: None,
        policies: Vec::new(),
    }
}

fn bits_of(cq: Option<&str>) -> f64 {
    match cq {
        None => 16.0,
        Some(t) => {
            let spec: Vec<&str> = t.split('c').collect();
            let c: f64 = spec[0].parse().unwrap();
            let b: f64 = spec[1].trim_end_matches('b').parse().unwrap();
            b / c
        }
    }
}

fn run_mode(
    cq: Option<&str>,
    batch: usize,
    workers: usize,
    n_req: usize,
    max_new: usize,
) -> ModeResult {
    run_with_cfg(mode_cfg(cq, batch), cq, workers, n_req, max_new)
}

fn run_with_cfg(
    cfg: ServeConfig,
    cq: Option<&str>,
    workers: usize,
    n_req: usize,
    max_new: usize,
) -> ModeResult {
    let label = cq.unwrap_or("fp16").to_string();
    let pool = ServePool::start(cfg, workers);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            pool.submit_async(Request::greedy(i as u64, "The castle of Aldenport ", max_new))
                .unwrap()
        })
        .collect();
    let mut tokens = 0;
    let mut cache = 0;
    for rx in rxs {
        let r = rx.recv().unwrap();
        tokens += r.gen_tokens;
        cache += r.cache_bytes;
    }
    let wall = t0.elapsed().as_secs_f64();
    let per_worker: Vec<f64> = pool
        .metrics
        .workers()
        .iter()
        .map(|m| m.tokens_out.get() as f64 / wall)
        .collect();
    let res = ModeResult {
        label,
        bits: bits_of(cq),
        tokens_per_s: tokens as f64 / wall,
        decode_p50_ms: pool.metrics.merged_decode_latency().percentile_ms(0.5),
        cache_bytes: cache,
        per_worker,
    };
    pool.shutdown().unwrap();
    res
}

#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("/proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line")
}

#[cfg(not(target_os = "linux"))]
fn thread_count() -> usize {
    0 // no /proc; the flat-thread assertion degrades to a no-op
}

/// Frontend scenario: the reactor holds `--idle-clients` idle connections
/// on a **flat thread count** — threads stay O(reactor + workers), never
/// O(connections) — and the live request path threading through the idle
/// pile shows no tail-latency cliff.  Both ends live in this process (one
/// fd per side per conn), so the default stays under a 1024 soft fd limit;
/// pass `--idle-clients 10000` after `ulimit -n 25000` for the full-scale
/// run.  Sim backend: it measures on every host; both contracts are hard
/// asserts.
fn frontend_idle_scenario(args: &Args) -> Json {
    let idle_n = args.usize("idle-clients", 256);
    let plan = FaultPlan::new();
    let cfg = ServeConfig {
        model: "sim".into(),
        cq: None,
        batch: 8,
        cache_budget: None,
        codebook_path: None,
        params_path: "/nonexistent/sim-has-no-params.bin".into(),
        kernel: ServeConfig::default_kernel(),
        block_tokens: 4,
        prefix_sharing: true,
        sim: Some(SimSpec::tiny()),
        faults: Some(plan),
        worker_index: 0,
        session_cap: ServeConfig::default_session_cap(),
        session_ttl: None,
        prefill_chunk: ServeConfig::default_prefill_chunk(),
        ttft_slo_chunks: None,
        trace_ring: ServeConfig::default_trace_ring(),
        encode_threads: ServeConfig::default_encode_threads(),
        codec: None,
        policies: Vec::new(),
    };
    let pool = ServePool::start(cfg, 2);
    let stop = StopSignal::new();
    let addr = "127.0.0.1:17999";
    let row = std::thread::scope(|scope| {
        let p = &pool;
        let stop2 = stop.clone();
        let server = scope.spawn(move || serve_tcp(p, addr, stop2).unwrap());
        std::thread::sleep(Duration::from_millis(300)); // wait for bind

        // Sequential v1 probes: each is a fresh connect -> request ->
        // response -> close round trip through the reactor.
        let probe = |n: usize| -> (f64, f64) {
            let mut ms: Vec<f64> = (0..n)
                .map(|_| {
                    let t0 = Instant::now();
                    let r = client_request_line(addr, r#"{"prompt": "probe", "max_tokens": 4}"#)
                        .expect("probe");
                    assert_eq!(r.num_or("gen_tokens", -1.0) as i64, 4, "{}", r.dump());
                    t0.elapsed().as_secs_f64() * 1e3
                })
                .collect();
            ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (ms[ms.len() / 2], ms[ms.len() * 99 / 100])
        };
        let (p50_alone, p99_alone) = probe(40);
        let threads_before = thread_count();

        let idle: Vec<TcpStream> = (0..idle_n)
            .map(|_| TcpStream::connect(addr).expect("idle connect"))
            .collect();
        let deadline = Instant::now() + Duration::from_secs(30);
        while (pool.metrics.conns_open.get() as usize) < idle_n {
            assert!(Instant::now() < deadline, "reactor never admitted the idle pile");
            std::thread::sleep(Duration::from_millis(10));
        }
        let grown = thread_count().saturating_sub(threads_before);
        assert!(
            grown <= 4,
            "thread count grew by {grown} for {idle_n} idle connections; \
             the frontend must multiplex, not spawn"
        );

        let (p50_idle, p99_idle) = probe(40);
        assert!(
            p99_idle <= p99_alone * 5.0 + 25.0,
            "tail-latency cliff under idle pile: p99 {p99_idle:.2} ms vs {p99_alone:.2} ms alone"
        );
        eprintln!(
            "  frontend: {idle_n} idle conns, +{grown} threads, \
             p50 {p50_alone:.2}->{p50_idle:.2} ms, p99 {p99_alone:.2}->{p99_idle:.2} ms"
        );

        drop(idle);
        stop.raise();
        server.join().unwrap();
        Json::obj(vec![
            ("name", Json::Str(format!("frontend_idle,conns={idle_n}"))),
            ("idle_conns", Json::Num(idle_n as f64)),
            ("threads_grown", Json::Num(grown as f64)),
            ("req_p50_ms_alone", Json::Num(p50_alone)),
            ("req_p50_ms_idle", Json::Num(p50_idle)),
            ("req_p99_ms_alone", Json::Num(p99_alone)),
            ("req_p99_ms_idle", Json::Num(p99_idle)),
        ])
    });
    pool.shutdown().unwrap();
    row
}

fn main() {
    // Args::parse treats argv[0] as the subcommand; give it one so the
    // first real `--flag` is not swallowed (cargo's own --bench is dropped).
    let mut argv = vec!["serve_throughput".to_string()];
    argv.extend(std::env::args().skip(1).filter(|a| a != "--bench"));
    let args = Args::parse(&argv).unwrap();
    // Committed floors load BEFORE the run overwrites BENCH_serve.json.
    let committed = args
        .flag("check")
        .then(|| std::fs::read_to_string(workspace_file("BENCH_serve.json")).ok())
        .flatten()
        .and_then(|s| Json::parse(&s).ok());
    // --- Frontend: idle-connection pile (sim backend, runs everywhere) ---
    let mut scenario_rows: Vec<Json> = vec![frontend_idle_scenario(&args)];
    // Serving needs the AOT artifacts + a real PJRT engine; on build-only
    // hosts emit BENCH_serve.json with only the runtime-free scenarios
    // instead of panicking so CI can exercise the bench binary everywhere.
    // `--check` cannot enforce without measurements, so it degrades to
    // establishing.
    if !cq::runtime_available() {
        eprintln!("serve_throughput: PJRT runtime/artifacts unavailable; skipping measurements");
        emit_serve_json(false, scenario_rows);
        return;
    }
    let max_new = args.usize("max-tokens", 12);
    let mut worker_counts: Vec<usize> = args
        .str("workers", "1,2,4")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&w| w >= 1)
        .collect();
    if worker_counts.is_empty() {
        worker_counts = vec![1, 2, 4];
    }

    // Ensure checkpoint + all serve codebooks exist.
    {
        let pipe = Pipeline::ensure("small").expect("pipeline");
        for spec in [CqSpec::new(2, 8), CqSpec::new(4, 8), CqSpec::new(8, 8)] {
            pipe.cq_codec(spec, true, 40).expect("codebooks");
        }
    }

    // --- Table 1: cache modes at a single worker (paper comparison) ------
    let mut table = Table::new(
        "Serving: decode latency / throughput / cache bytes, fp16 vs CQ (1 worker)",
        &["cache", "bits/FPN", "batch", "tok/s", "decode p50 (ms)",
          "cache bytes", "traffic/token @T=512", "bw-bound speedup ceiling"],
    );
    for batch in [1usize, 8] {
        let n_req = args.usize("requests", batch.max(4));
        for mode in [None, Some("2c8b"), Some("4c8b"), Some("8c8b")] {
            let r = run_mode(mode, batch, 1, n_req, max_new);
            let tm = TrafficModel {
                n_layers: 4,
                n_heads: 4,
                head_dim: 64,
                bits_per_fpn: r.bits,
            };
            eprintln!(
                "  {:<5} b{batch}: {:.1} tok/s, p50 {:.1} ms, cache {}",
                r.label, r.tokens_per_s, r.decode_p50_ms, r.cache_bytes
            );
            table.row(vec![
                r.label.clone(),
                format!("{:.2}", r.bits),
                batch.to_string(),
                format!("{:.1}", r.tokens_per_s),
                format!("{:.2}", r.decode_p50_ms),
                r.cache_bytes.to_string(),
                format!("{:.0} B", tm.bytes_per_decode(512)),
                format!("{:.1}x", tm.speedup_vs_fp16()),
            ]);
            scenario_rows.push(scenario_json(
                &format!("cache={},batch={batch},workers=1", r.label),
                r.tokens_per_s,
                None,
            ));
        }
    }
    table.emit("serve_throughput");

    // --- Table 2: worker sweep — pool scaling of fp vs quantized cache ---
    let mut sweep = Table::new(
        "Serve pool scaling: aggregate + per-worker tok/s by worker count",
        &["cache", "workers", "agg tok/s", "per-worker tok/s", "speedup vs 1w",
          "decode p50 (ms)"],
    );
    for mode in [None, Some("8c8b")] {
        let results: Vec<(usize, ModeResult)> = worker_counts
            .iter()
            .map(|&workers| {
                // Enough requests to keep every worker's lanes busy.
                let n_req = args.usize("requests", 8 * workers).max(2 * workers);
                let r = run_mode(mode, 8, workers, n_req, max_new);
                eprintln!(
                    "  {:<5} {workers}w: {:.1} tok/s agg [{}]",
                    r.label,
                    r.tokens_per_s,
                    r.per_worker
                        .iter()
                        .map(|t| format!("{t:.1}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
                (workers, r)
            })
            .collect();
        // Baseline = the single-worker run when the sweep includes one
        // (whatever its position), else the first run.
        let base_tps = results
            .iter()
            .find(|(w, _)| *w == 1)
            .map(|(_, r)| r.tokens_per_s)
            .unwrap_or(results[0].1.tokens_per_s);
        for (workers, r) in &results {
            let per: Vec<String> =
                r.per_worker.iter().map(|t| format!("{t:.1}")).collect();
            sweep.row(vec![
                r.label.clone(),
                workers.to_string(),
                format!("{:.1}", r.tokens_per_s),
                per.join(" / "),
                format!("{:.2}x", r.tokens_per_s / base_tps.max(1e-9)),
                format!("{:.2}", r.decode_p50_ms),
            ]);
            scenario_rows.push(scenario_json(
                &format!("cache={},batch=8,workers={workers}", r.label),
                r.tokens_per_s,
                None,
            ));
        }
    }
    sweep.emit("serve_throughput_workers");

    // --- Table 3: prefix reuse — M clients share a 512-token prompt ------
    // The paged cache's headline serving win: with radix prefix sharing on,
    // every client after the first attaches to the already-quantized prompt
    // blocks (one stored copy, quantize+store skipped for the hit span).
    let m_clients = args.usize("clients", 8);
    let shared_prompt: String = "The castle of Aldenport stands upon the river. "
        .repeat(11)
        .chars()
        .take(512)
        .collect();
    let mut reuse = Table::new(
        "Prefix reuse: M clients x shared 512-token prompt (CQ-8c8b, 1 worker)",
        &["sharing", "clients", "tok/s", "prefill p50 (ms)", "hit rate",
          "hit tokens", "cached prefix bytes"],
    );
    for sharing in [false, true] {
        let mut cfg = mode_cfg(Some("8c8b"), 8);
        cfg.prefix_sharing = sharing;
        let pool = ServePool::start(cfg, 1);
        let t0 = Instant::now();
        // One warm-up client stores the prompt; the rest can only share it
        // when `sharing` is on.
        let first = pool
            .submit(Request::greedy(0, &shared_prompt, max_new))
            .unwrap();
        let rxs: Vec<_> = (1..m_clients as u64)
            .map(|i| {
                pool.submit_async(Request::greedy(i, &shared_prompt, max_new))
                    .unwrap()
            })
            .collect();
        let mut tokens = first.gen_tokens;
        for rx in rxs {
            tokens += rx.recv().unwrap().gen_tokens;
        }
        let wall = t0.elapsed().as_secs_f64();
        let hit_rate = pool.metrics.prefix_hit_rate();
        eprintln!(
            "  sharing={sharing:<5} {m_clients} clients: {:.1} tok/s, hit {:.0}%",
            tokens as f64 / wall,
            hit_rate * 100.0
        );
        reuse.row(vec![
            if sharing { "radix" } else { "off" }.to_string(),
            m_clients.to_string(),
            format!("{:.1}", tokens as f64 / wall),
            format!("{:.2}", pool.metrics.worker(0).prefill_latency.percentile_ms(0.5)),
            format!("{:.0}%", hit_rate * 100.0),
            pool.metrics.prefix_hit_tokens().to_string(),
            pool.metrics.cache_cached_bytes().to_string(),
        ]);
        scenario_rows.push(scenario_json(
            &format!(
                "prefix_reuse,sharing={},clients={m_clients}",
                if sharing { "radix" } else { "off" }
            ),
            tokens as f64 / wall,
            Some(hit_rate),
        ));
        pool.shutdown().unwrap();
    }
    reuse.emit("serve_prefix_reuse");

    // --- Table 4: streaming lifecycle — TTFT + cancel-reclaim latency ----
    // TTFT is the streaming API's headline number (arrival -> first Token
    // event); cancel-reclaim is how long a disconnecting client occupies a
    // lane + its cache reservation before the worker hands both back.
    let n_stream = args.usize("stream-requests", 8);
    let pool = ServePool::start(mode_cfg(Some("8c8b"), 8), 1);
    let mut ttft_ms: Vec<f64> = Vec::new();
    for i in 0..n_stream as u64 {
        let t0 = Instant::now();
        let handle = pool
            .submit_stream(Request::greedy(9000 + i, "The castle of Aldenport ", max_new))
            .expect("stream");
        let mut first: Option<f64> = None;
        for ev in handle {
            match ev {
                Event::Token { .. } => {
                    if first.is_none() {
                        first = Some(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                Event::Done(_) | Event::Failed { .. } => break,
                Event::Started { .. } => {}
            }
        }
        if let Some(ms) = first {
            ttft_ms.push(ms);
        }
    }
    let mut reclaim_ms: Vec<f64> = Vec::new();
    for i in 0..4u64 {
        let handle = pool
            .submit_stream(Request::greedy(9500 + i, "The castle of Aldenport ", 256))
            .expect("stream");
        // Wait for decode to be genuinely under way, then cancel and time
        // until the worker confirms (the Failed event is emitted only after
        // the lane, blocks and reservation were handed back).
        loop {
            match handle.recv() {
                Ok(Event::Token { index, .. }) if index >= 1 => break,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        let t0 = Instant::now();
        handle.cancel();
        let _ = handle.drain();
        reclaim_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    // Timing::from_samples asserts non-empty; an all-failed run (or
    // --stream-requests 0) must degrade to missing rows, not a panic that
    // loses the tables already measured above.
    let mut stream_tbl = Table::new(
        "Streaming lifecycle: TTFT and cancel-reclaim latency (CQ-8c8b, 1 worker)",
        &["metric", "samples", "p50 (ms)", "p95 (ms)", "mean (ms)"],
    );
    if !ttft_ms.is_empty() {
        let ttft = Timing::from_samples(ttft_ms);
        stream_tbl.row(vec![
            "ttft".into(),
            ttft.iters.to_string(),
            format!("{:.2}", ttft.p50),
            format!("{:.2}", ttft.p95),
            format!("{:.2}", ttft.mean),
        ]);
        eprintln!("  streaming: ttft p50 {:.1} ms", ttft.p50);
        scenario_rows.push(Json::obj(vec![
            ("name", Json::Str("streaming,ttft".into())),
            ("ttft_ms_p50", Json::Num(ttft.p50)),
            ("ttft_ms_p95", Json::Num(ttft.p95)),
        ]));
    }
    if !reclaim_ms.is_empty() {
        let reclaim = Timing::from_samples(reclaim_ms);
        stream_tbl.row(vec![
            "cancel_reclaim".into(),
            reclaim.iters.to_string(),
            format!("{:.2}", reclaim.p50),
            format!("{:.2}", reclaim.p95),
            format!("{:.2}", reclaim.mean),
        ]);
        eprintln!(
            "  streaming: cancel reclaim p50 {:.2} ms, cancelled={}",
            reclaim.p50,
            pool.metrics.requests_cancelled()
        );
        scenario_rows.push(Json::obj(vec![
            ("name", Json::Str("streaming,cancel_reclaim".into())),
            ("cancel_reclaim_ms_p50", Json::Num(reclaim.p50)),
            ("cancelled", Json::Num(pool.metrics.requests_cancelled() as f64)),
        ]));
    }
    stream_tbl.emit("serve_streaming");
    pool.shutdown().unwrap();

    // --- Table 5: mixed workload — interactive TTFT under batch prefill --
    // The chunked-prefill scheduler's headline: one long batch-priority
    // prompt is mid-prefill while N short interactive requests arrive, and
    // the interactive class must still see low TTFT because its chunks
    // preempt the pending batch chunks at every boundary.
    let n_inter = args.usize("interactive-requests", 8);
    let mut mixed_cfg = mode_cfg(Some("8c8b"), 8);
    mixed_cfg.prefill_chunk = args.usize("prefill-chunk", 64);
    let pool = ServePool::start(mixed_cfg, 1);
    let batch_handle = pool
        .submit_stream(Request::greedy(9800, &shared_prompt, max_new).batch_priority())
        .expect("batch stream");
    let interactives: Vec<(Instant, StreamHandle)> = (0..n_inter as u64)
        .map(|i| {
            let t0 = Instant::now();
            let h = pool
                .submit_stream(Request::greedy(9900 + i, "Quick turn. ", max_new))
                .expect("interactive stream");
            (t0, h)
        })
        .collect();
    let mut inter_ttft_ms: Vec<f64> = Vec::new();
    for (t0, h) in interactives {
        let mut first: Option<f64> = None;
        for ev in h {
            match ev {
                Event::Token { .. } => {
                    if first.is_none() {
                        first = Some(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                Event::Done(_) | Event::Failed { .. } => break,
                Event::Started { .. } => {}
            }
        }
        if let Some(ms) = first {
            inter_ttft_ms.push(ms);
        }
    }
    let _ = batch_handle.drain();
    let mut mixed_tbl = Table::new(
        "Mixed workload: N interactive under one long batch prefill (CQ-8c8b, 1 worker)",
        &["class", "requests", "ttft p50 (ms)", "ttft p95 (ms)", "preempted chunks"],
    );
    if !inter_ttft_ms.is_empty() {
        let t = Timing::from_samples(inter_ttft_ms);
        let preempts = pool.metrics.prefill_preemptions();
        mixed_tbl.row(vec![
            "interactive".into(),
            t.iters.to_string(),
            format!("{:.2}", t.p50),
            format!("{:.2}", t.p95),
            preempts.to_string(),
        ]);
        mixed_tbl.row(vec![
            "batch".into(),
            "1".into(),
            format!("{:.2}", pool.metrics.merged_ttft_batch().percentile_ms(0.5)),
            format!("{:.2}", pool.metrics.merged_ttft_batch().percentile_ms(0.95)),
            "-".into(),
        ]);
        eprintln!(
            "  mixed: interactive ttft p95 {:.1} ms under batch prefill, {preempts} preemptions",
            t.p95
        );
        scenario_rows.push(Json::obj(vec![
            ("name", Json::Str(format!("mixed,batch=1,interactive={n_inter}"))),
            ("ttft_ms_p50", Json::Num(t.p50)),
            ("ttft_ms_p95", Json::Num(t.p95)),
            ("batch_ttft_ms_p50", Json::Num(pool.metrics.merged_ttft_batch().percentile_ms(0.5))),
            ("prefill_preemptions", Json::Num(preempts as f64)),
        ]));
    }
    mixed_tbl.emit("serve_mixed_workload");
    pool.shutdown().unwrap();

    // --- Table 6: observability overhead — flight recorder on vs off -----
    // The trace ring, per-request span marks and loop-phase accounting must
    // be effectively free on the serving hot path: tok/s with tracing at
    // its default ring size must stay within 2% of tracing disabled.
    let n_req = args.usize("requests", 16);
    let mut off_cfg = mode_cfg(Some("8c8b"), 8);
    off_cfg.trace_ring = 0; // disables begin()/mark() entirely
    let off = run_with_cfg(off_cfg, Some("8c8b"), 1, n_req, max_new);
    let on = run_with_cfg(mode_cfg(Some("8c8b"), 8), Some("8c8b"), 1, n_req, max_new);
    let delta_pct = if off.tokens_per_s > 0.0 {
        (off.tokens_per_s - on.tokens_per_s) / off.tokens_per_s * 100.0
    } else {
        0.0
    };
    let mut obs_tbl = Table::new(
        "Observability overhead: flight recorder + phase tracing on vs off (CQ-8c8b, 1 worker)",
        &["tracing", "tok/s", "decode p50 (ms)", "tok/s delta"],
    );
    obs_tbl.row(vec![
        "off".into(),
        format!("{:.1}", off.tokens_per_s),
        format!("{:.2}", off.decode_p50_ms),
        "-".into(),
    ]);
    obs_tbl.row(vec![
        format!("ring={}", ServeConfig::default_trace_ring()),
        format!("{:.1}", on.tokens_per_s),
        format!("{:.2}", on.decode_p50_ms),
        format!("{delta_pct:+.2}%"),
    ]);
    obs_tbl.emit("serve_observability_overhead");
    if delta_pct >= 2.0 {
        eprintln!("  WARNING: tracing overhead {delta_pct:.2}% exceeds the 2% budget");
    } else {
        eprintln!("  observability overhead: {delta_pct:+.2}% tok/s (budget < 2%)");
    }
    scenario_rows.push(Json::obj(vec![
        ("name", Json::Str("observability_overhead,tracing=off".into())),
        ("tok_per_s", Json::Num(off.tokens_per_s)),
    ]));
    scenario_rows.push(Json::obj(vec![
        (
            "name",
            Json::Str(format!(
                "observability_overhead,tracing=ring{}",
                ServeConfig::default_trace_ring()
            )),
        ),
        ("tok_per_s", Json::Num(on.tokens_per_s)),
        ("overhead_pct", Json::Num(delta_pct)),
        ("within_2pct", Json::Bool(delta_pct < 2.0)),
    ]));

    let regressions = if args.flag("check") {
        check_floors(committed.as_ref(), &scenario_rows)
    } else {
        0
    };
    emit_serve_json(true, scenario_rows);
    if regressions > 0 {
        eprintln!(
            "serve_throughput: {regressions} scenario(s) regressed >{:.0}% below the \
             committed floor (--check)",
            CHECK_TOLERANCE * 100.0
        );
        std::process::exit(1);
    }
}

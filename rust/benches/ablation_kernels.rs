//! Kernel ablation (DESIGN.md §3.2): gather-dequant value path vs the
//! ADC-style value path (accumulate softmax mass per centroid bin, then mix
//! centroids once) in the fused CQ decode attention kernel, at 1 bit/FPN.
//!
//! Both artifacts compute identical attention (validated against ref.py in
//! python/tests); this bench checks numerical agreement through the full
//! stack and compares host wall-clock plus the analytical op counts that
//! decide the winner on real hardware (ADC value work: O(T·G + K·C) vs
//! gather O(T·D)).
//!
//!     cargo bench --bench ablation_kernels  [-- --steps 8]

use cq::bench_support::Pipeline;
use cq::quant::cq::CqSpec;
use cq::quant::KvKind;
use cq::runtime::Value;
use cq::tensor::{TensorF, TensorI};
use cq::util::bench::{fmt_secs, time_fn, Table};
use cq::util::cli::Args;
use cq::util::rng::Pcg64;

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let iters = args.usize("steps", 6);

    let pipe = Pipeline::ensure("small").expect("pipeline");
    let mm = pipe.engine.manifest.model("small").unwrap().clone();
    let spec = CqSpec::new(8, 8);
    let codec = pipe.cq_codec(spec, true, 40).expect("codebooks");
    let books = &codec.books;
    let (l, h, hd, tmax, b) = (mm.n_layers, mm.n_heads, mm.head_dim, mm.serve_ctx, 8);
    let g = spec.n_groups(hd);

    // Random-but-valid inputs: codes uniform over the codebook, positions
    // mid-cache so the kernels sweep half the lane.
    let mut rng = Pcg64::seed(7);
    let codes = |rng: &mut Pcg64| {
        TensorI::from_vec(
            &[l, b, h, tmax, g],
            (0..l * b * h * tmax * g)
                .map(|_| rng.below(spec.n_centroids()) as i32)
                .collect(),
        )
        .unwrap()
    };
    let k_codes = codes(&mut rng);
    let v_codes = codes(&mut rng);
    let pos = TensorI::from_vec(&[b], vec![(tmax / 2) as i32; b]).unwrap();
    let tok = TensorI::from_vec(&[b], (0..b as i32).collect()).unwrap();
    let inputs = vec![
        Value::F(pipe.params.clone()),
        Value::F(books.export_tensor(KvKind::Key)),
        Value::F(books.export_tensor(KvKind::Value)),
        Value::I(k_codes),
        Value::I(v_codes),
        Value::I(pos),
        Value::I(tok),
    ];

    let mut table = Table::new(
        "Kernel ablation: gather-dequant vs ADC value path (CQ-8c8b, B=8, T=512)",
        &["kernel", "decode step (p50)", "logits match",
          "value-path ops / (b,h)", "note"],
    );
    let mut logits: Vec<TensorF> = Vec::new();
    for (label, art) in [
        ("gather-dequant", "small.decode_cq_8c8b_b8"),
        ("ADC value path", "small.decode_cq_adc_8c8b_b8"),
    ] {
        let exe = pipe.engine.executable(art).expect("artifact");
        let mut out = None;
        let t = time_fn(2, iters, || {
            out = Some(exe.run(&inputs).expect("run"));
        });
        logits.push(out.unwrap()[0].as_f().unwrap().clone());
        let ops = if label.starts_with("ADC") {
            // mass accumulation T*G + centroid mix K*C
            format!("{} (T·G + 2^b·c)", tmax * g + spec.n_centroids() * spec.channels)
        } else {
            format!("{} (T·D)", tmax * hd)
        };
        eprintln!("  {label}: p50 {}", fmt_secs(t.p50));
        table.row(vec![
            label.to_string(),
            fmt_secs(t.p50),
            "-".into(),
            ops,
            if label.starts_with("ADC") {
                format!("wins when T >> 2^b·c/G = {}", spec.n_centroids() * spec.channels / g)
            } else {
                "baseline".into()
            },
        ]);
    }
    // Numerical agreement between the two kernels through the whole stack.
    let max_diff = logits[0]
        .data
        .iter()
        .zip(&logits[1].data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logit diff| gather vs ADC: {max_diff:.2e}");
    assert!(max_diff < 1e-3, "kernels must agree");
    table.emit("ablation_kernels");
}

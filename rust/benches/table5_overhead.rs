//! Table 5: centroid-learning wall time and centroid parameter counts for
//! CQ-2c8b / 4c8b / 8c8b on both models.
//!
//! The paper's structure holds by construction: parameter count
//! l × 2 × h × hd × 2^b is independent of c, and learning time *drops* as c
//! grows (fewer k-means problems of higher dimension, same total work per
//! Lloyd pass but better cache behaviour / earlier convergence).
//!
//!     cargo bench --bench table5_overhead  [-- --iters 100]

use cq::bench_support::Pipeline;
use cq::quant::cq::{CqCodebooks, CqSpec, LearnCfg};
use cq::util::bench::Table;
use cq::util::cli::Args;

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    // Paper §4.3 runs 100 k-means iterations; we keep that cap (early-stop
    // on converged assignments still applies).
    let iters = args.usize("iters", 100);

    let mut table = Table::new(
        "Table 5: CQ centroid learning time + storage overhead",
        &["model", "config", "learn time (s)", "kmeans problems",
          "centroid params", "% of model params"],
    );
    for model in ["small", "tiny"] {
        let pipe = Pipeline::ensure(model).expect("pipeline");
        let model_params = pipe.params.numel();
        for spec in [CqSpec::new(2, 8), CqSpec::new(4, 8), CqSpec::new(8, 8)] {
            let books = CqCodebooks::learn(
                spec,
                &pipe.calib.k,
                &pipe.calib.v,
                Some(&pipe.calib.gk),
                Some(&pipe.calib.gv),
                LearnCfg { fisher: true, max_iters: iters, seed: 0 },
            );
            let n_problems = books.n_layers * 2 * books.n_heads * spec.n_groups(books.head_dim);
            eprintln!(
                "  {model:<6} {:<5} {:>7.1}s  {} params",
                spec.tag(),
                books.learn_secs,
                books.centroid_param_count()
            );
            table.row(vec![
                model.to_string(),
                format!("CQ-{}", spec.tag()),
                format!("{:.1}", books.learn_secs),
                n_problems.to_string(),
                books.centroid_param_count().to_string(),
                format!("{:.2}%", 100.0 * books.centroid_param_count() as f64 / model_params as f64),
            ]);
        }
    }
    table.emit("table5_overhead");
}

//! Quantization hot-path microbench (PR 3 acceptance: ≥3× on prefill
//! encode and sequence reload vs the pre-PR scalar pipeline).
//!
//! Three scenarios, each measuring the OLD implementation (kept in-tree as
//! `assign_reference` / `pack_codes_ref` / the per-token load loop
//! reproduced here) against the batched kernels that replaced it:
//!
//! * `prefill_encode` — per-token brute-force centroid scan vs
//!   `CqCodebooks::encode_span_pooled` (book-major dot-product expansion
//!   with the 8-lane assignment kernel, fanned across a persistent
//!   [`WorkPool`] exactly like the serve loop's chunked prefill).
//! * `seq_reload`    — per-token `PagedSeqCache::token` + `write_token`
//!   staging vs `BatchStage::load_sequence` (whole-block bulk unpack,
//!   precomputed strides, zero-alloc scratch).
//! * `pack_roundtrip`— bit-at-a-time reference pack/unpack vs the word-level
//!   `pack_into`/`unpack_into` kernels (byte-aligned fast path at 8 bits,
//!   u64-window path at 5 bits).
//!
//! Emits the human table plus machine-readable `BENCH_quant.json` at the
//! workspace root (ROADMAP perf trajectory).
//!
//! `--check` enforces the committed `BENCH_quant.json` as a perf floor: any
//! scenario whose fresh `us_per_token_new` regresses more than 15% past the
//! committed measurement exits nonzero (CI's bench-floors job).  A missing
//! or `measured: false` floor file establishes instead of enforcing — the
//! freshly measured results are written for CI to commit, so the floor
//! ratchets on the first run on real hardware and is enforced thereafter.
//!
//!     cargo bench --bench quant_hot_path \
//!         [-- --tokens 192 --iters 30 --quick --strict --check]

use cq::kvcache::{BatchStage, BlockConfig, BlockPool, CacheGeom, PagedSeqCache};
use cq::quant::cq::{CqCodebooks, CqSpec};
use cq::quant::pack::{pack_codes_ref, pack_into, packed_len, unpack_codes_ref, unpack_into};
use cq::quant::{KvDims, KvKind};
use cq::tensor::TensorF;
use cq::util::bench::{emit_json, time_fn, workspace_file, Table};
use cq::util::cli::Args;
use cq::util::json::Json;
use cq::util::rng::Pcg64;
use cq::util::workpool::WorkPool;

/// The paper's headline serving config: CQ-8c8b on 4L/4H/hd64 (1 bit/FPN).
const L: usize = 4;
const H: usize = 4;
const HD: usize = 64;

struct Scenario {
    name: &'static str,
    us_per_token_ref: f64,
    us_per_token_new: f64,
}

impl Scenario {
    fn speedup(&self) -> f64 {
        self.us_per_token_ref / self.us_per_token_new.max(1e-12)
    }
}

fn random_kv(l: usize, h: usize, hd: usize, t: usize, seed: u64) -> TensorF {
    let mut rng = Pcg64::seed(seed);
    let mut out = TensorF::zeros(&[l, 1, h, t, hd]);
    for x in out.data.iter_mut() {
        *x = rng.normal() as f32;
    }
    out
}

/// The pre-PR prefill encode: per token, per (layer, head), a fresh Vec of
/// group codes from a brute-force `(x-c)²` scan over every centroid.
fn encode_reference(books: &CqCodebooks, k: &TensorF, v: &TensorF) -> (Vec<u32>, Vec<u32>) {
    let d = KvDims::of(k);
    let spec = books.spec;
    let c = spec.channels;
    let groups = spec.n_groups(d.hd);
    let per_side = d.l * d.h * groups;
    let mut k_all = Vec::with_capacity(d.t * per_side);
    let mut v_all = Vec::with_capacity(d.t * per_side);
    let encode_vec_ref = |kind: KvKind, l: usize, h: usize, x: &[f32], out: &mut Vec<u32>| {
        let side: Vec<u32> = (0..groups)
            .map(|g| books.book(l, kind, h, g).assign_reference(&x[g * c..(g + 1) * c]) as u32)
            .collect();
        out.extend(side);
    };
    for t in 0..d.t {
        for l in 0..d.l {
            for h in 0..d.h {
                let off = d.vec_off(l, 0, h, t);
                encode_vec_ref(KvKind::Key, l, h, &k.data[off..off + d.hd], &mut k_all);
                encode_vec_ref(KvKind::Value, l, h, &v.data[off..off + d.hd], &mut v_all);
            }
        }
    }
    (k_all, v_all)
}

fn bench_prefill_encode(tokens: usize, warmup: usize, iters: usize) -> Scenario {
    let spec = CqSpec::new(8, 8); // 8c8b: 256 centroids of 8 channels
    let books = CqCodebooks::synthetic(spec, L, H, HD, 1);
    let k = random_kv(L, H, HD, tokens, 2);
    let v = random_kv(L, H, HD, tokens, 3);
    // The serving hot path: one persistent pool per worker, borrowed per
    // chunk — sized like `build_encode_pool` so the bench times exactly
    // what `prefill_chunk_fill` runs.
    let avail = std::thread::available_parallelism().map_or(1, |n| n.get());
    let pool = WorkPool::new(L.min(avail));

    // Sanity: both paths must produce identical codes before timing them.
    let (kr, vr) = encode_reference(&books, &k, &v);
    let (kn, vn) = books.encode_span_pooled(&k, &v, 0, tokens, &pool);
    // assign_reference and the expansion can only disagree on near-exact
    // float ties; on random normal data that has measure ~0, and any drift
    // would invalidate the comparison.
    assert_eq!(kr.len(), kn.len());
    let diverged = kr.iter().zip(&kn).filter(|(a, b)| a != b).count()
        + vr.iter().zip(&vn).filter(|(a, b)| a != b).count();
    assert!(
        diverged * 1000 < 2 * kr.len(),
        "reference and batch encode diverge on {diverged}/{} codes",
        2 * kr.len()
    );

    let t_ref = time_fn(warmup, iters, || {
        std::hint::black_box(encode_reference(&books, &k, &v));
    });
    let t_new = time_fn(warmup, iters, || {
        std::hint::black_box(books.encode_span_pooled(&k, &v, 0, tokens, &pool));
    });
    Scenario {
        name: "prefill_encode",
        us_per_token_ref: t_ref.mean * 1e6 / tokens as f64,
        us_per_token_new: t_new.mean * 1e6 / tokens as f64,
    }
}

fn bench_seq_reload(tokens: usize, warmup: usize, iters: usize) -> Scenario {
    let geom = CacheGeom {
        n_layers: L,
        n_heads: H,
        groups: 8,
        bits: 8,
        tmax: tokens,
    };
    let mut pool = BlockPool::new(BlockConfig::new(16, geom.bytes_per_token()), None);
    let per_side = L * H * 8;
    let mut rng = Pcg64::seed(4);
    let mut seq = PagedSeqCache::new(geom);
    for _ in 0..tokens {
        let kc: Vec<u32> = (0..per_side).map(|_| rng.below(256) as u32).collect();
        let vc: Vec<u32> = (0..per_side).map(|_| rng.below(256) as u32).collect();
        seq.append(&mut pool, &kc, &vc).expect("append");
    }

    let mut stage_ref = BatchStage::new(geom, 1);
    let mut stage_new = BatchStage::new(geom, 1);
    // The pre-PR load_sequence: one token at a time, three allocations and a
    // bit-loop unpack per token (token_reference IS that old path, kept for
    // exactly this comparison), offsets re-derived per (l, h, t).
    let t_ref = time_fn(warmup, iters, || {
        for t in 0..seq.len {
            let (kc, vc) = seq.token_reference(&pool, t);
            stage_ref.write_token(0, t, &kc, &vc);
        }
    });
    let t_new = time_fn(warmup, iters, || {
        stage_new.load_sequence(0, &seq, &pool);
    });
    assert_eq!(
        stage_ref.k_codes.data, stage_new.k_codes.data,
        "bulk reload diverged from per-token staging"
    );
    assert_eq!(stage_ref.v_codes.data, stage_new.v_codes.data);
    seq.release(&mut pool);
    Scenario {
        name: "seq_reload",
        us_per_token_ref: t_ref.mean * 1e6 / tokens as f64,
        us_per_token_new: t_new.mean * 1e6 / tokens as f64,
    }
}

fn bench_pack_roundtrip(tokens: usize, warmup: usize, iters: usize, bits: u32) -> Scenario {
    // One "token" here is a 2-side CQ-8c8b record: 2 * L * H * G codes.
    let cpt = 2 * L * H * 8;
    let n = tokens * cpt;
    let mut rng = Pcg64::seed(5);
    let maxc = 1usize << bits;
    let codes: Vec<u32> = (0..n).map(|_| rng.below(maxc) as u32).collect();
    let t_ref = time_fn(warmup, iters, || {
        let packed = pack_codes_ref(&codes, bits);
        std::hint::black_box(unpack_codes_ref(&packed, bits, n));
    });
    let mut packed = vec![0u8; packed_len(n, bits)];
    let mut out = vec![0u32; n];
    let t_new = time_fn(warmup, iters, || {
        pack_into(&codes, bits, &mut packed);
        unpack_into(&packed, bits, &mut out);
        std::hint::black_box(&out);
    });
    assert_eq!(out, codes, "fast pack/unpack roundtrip broke");
    Scenario {
        name: if bits == 8 { "pack_roundtrip_8b" } else { "pack_roundtrip_5b" },
        us_per_token_ref: t_ref.mean * 1e6 / tokens as f64,
        us_per_token_new: t_new.mean * 1e6 / tokens as f64,
    }
}

/// Allowed `--check` slack over a committed floor before the run fails:
/// wide enough to absorb shared-runner noise at `--quick` iteration counts,
/// tight enough that an accidental O(k) regression in the assignment kernel
/// (the smallest real regression class, ~2x) can never slip through.
const CHECK_TOLERANCE: f64 = 0.15;

/// Enforce the committed floors against this run's scenarios.  Returns the
/// number of regressions; 0 when establishing (no committed measurement).
fn check_floors(committed: Option<&Json>, scenarios: &[Scenario]) -> usize {
    let Some(c) = committed else {
        eprintln!("check: no parseable committed BENCH_quant.json; establishing floors");
        return 0;
    };
    if c.get("measured").and_then(Json::as_bool) != Some(true) {
        eprintln!("check: committed floors are unmeasured; establishing floors");
        return 0;
    }
    let floors = c.get("scenarios").and_then(Json::as_arr).unwrap_or(&[]);
    let mut regressions = 0;
    for s in scenarios {
        let floor = floors
            .iter()
            .find(|f| f.get("name").and_then(Json::as_str) == Some(s.name))
            .map(|f| f.num_or("us_per_token_new", f64::INFINITY));
        match floor {
            None => eprintln!("check: {}: no committed floor (new scenario)", s.name),
            Some(floor) => {
                let limit = floor * (1.0 + CHECK_TOLERANCE);
                let ok = s.us_per_token_new <= limit;
                if !ok {
                    regressions += 1;
                }
                eprintln!(
                    "check: {}: {:.2} µs/token vs floor {:.2} (limit {:.2}) {}",
                    s.name,
                    s.us_per_token_new,
                    floor,
                    limit,
                    if ok { "ok" } else { "REGRESSION" }
                );
            }
        }
    }
    regressions
}

fn main() {
    // Args::parse treats argv[0] as the subcommand; give it one so the
    // first real `--flag` is not swallowed (cargo's own --bench is dropped).
    let mut argv = vec!["quant_hot_path".to_string()];
    argv.extend(std::env::args().skip(1).filter(|a| a != "--bench"));
    let args = Args::parse(&argv).unwrap();
    let quick = args.flag("quick");
    // Committed floors load BEFORE the run overwrites BENCH_quant.json.
    let committed = args
        .flag("check")
        .then(|| std::fs::read_to_string(workspace_file("BENCH_quant.json")).ok())
        .flatten()
        .and_then(|s| Json::parse(&s).ok());
    let tokens = args.usize("tokens", if quick { 32 } else { 192 });
    let iters = args.usize("iters", if quick { 3 } else { 25 });
    let warmup = if quick { 1 } else { 3 };

    eprintln!(
        "quant_hot_path: CQ-8c8b, {L}L x {H}H x hd{HD}, {tokens} tokens, {iters} iters{}",
        if quick { " (--quick)" } else { "" }
    );
    let scenarios = vec![
        bench_prefill_encode(tokens, warmup, iters),
        bench_seq_reload(tokens, warmup, iters),
        bench_pack_roundtrip(tokens, warmup, iters, 8),
        bench_pack_roundtrip(tokens, warmup, iters, 5),
    ];

    let mut table = Table::new(
        "Quant hot path: scalar reference vs batched kernels (CQ-8c8b)",
        &["scenario", "ref µs/token", "new µs/token", "speedup"],
    );
    let mut rows = Vec::new();
    for s in &scenarios {
        table.row(vec![
            s.name.to_string(),
            format!("{:.2}", s.us_per_token_ref),
            format!("{:.2}", s.us_per_token_new),
            format!("{:.2}x", s.speedup()),
        ]);
        rows.push(Json::obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("us_per_token_ref", Json::Num(s.us_per_token_ref)),
            ("us_per_token_new", Json::Num(s.us_per_token_new)),
            ("speedup", Json::Num(s.speedup())),
        ]));
    }
    table.emit("quant_hot_path");
    emit_json(
        "BENCH_quant.json",
        &Json::obj(vec![
            ("bench", Json::Str("quant_hot_path".into())),
            ("config", Json::Str(format!("CQ-8c8b {L}Lx{H}Hxhd{HD}"))),
            ("measured", Json::Bool(true)),
            ("quick", Json::Bool(quick)),
            ("tokens", Json::Num(tokens as f64)),
            ("iters", Json::Num(iters as f64)),
            ("scenarios", Json::Arr(rows)),
        ]),
    );

    // Acceptance gate: the two pipeline scenarios must clear 3x on a quiet
    // machine.  Informational by default (CI --quick runs on noisy shared
    // runners); `--strict` turns a miss into a nonzero exit for enforcement.
    let mut below = 0;
    for s in &scenarios[..2] {
        let ok = s.speedup() >= 3.0;
        if !ok {
            below += 1;
        }
        eprintln!(
            "  {} speedup {:.2}x {}",
            s.name,
            s.speedup(),
            if ok { "(>= 3x target)" } else { "(below 3x target)" }
        );
    }
    if args.flag("strict") && below > 0 {
        eprintln!("quant_hot_path: {below} scenario(s) below the 3x target (--strict)");
        std::process::exit(1);
    }
    if args.flag("check") {
        let regressions = check_floors(committed.as_ref(), &scenarios);
        if regressions > 0 {
            eprintln!(
                "quant_hot_path: {regressions} scenario(s) regressed >{:.0}% past the \
                 committed floor (--check)",
                CHECK_TOLERANCE * 100.0
            );
            std::process::exit(1);
        }
    }
}

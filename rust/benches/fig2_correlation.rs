//! Figure 2 (+ appendix Figs 5–6): Pearson correlation matrices of key and
//! value channels per layer.  Prints the scalar summary (mean |r| off the
//! diagonal) per layer and dumps the full first-32×32 matrices as CSV heat
//! maps under bench_out/.
//!
//! Expected shape: mean |r| well above the independent-channel baseline
//! (≈ 1/sqrt(n_samples)) in every layer, for both keys and values.
//!
//!     cargo bench --bench fig2_correlation

use cq::bench_support::Pipeline;
use cq::quant::corr::{corr_matrix, mean_abs_offdiag};
use cq::quant::{gather_channel, KvDims};
use cq::tensor::TensorF;
use cq::util::bench::Table;

fn dump_heatmap(m: &[f64], c: usize, path: &str) {
    let mut csv = String::new();
    for i in 0..c {
        let row: Vec<String> = (0..c).map(|j| format!("{:.4}", m[i * c + j])).collect();
        csv.push_str(&row.join(","));
        csv.push('\n');
    }
    let _ = std::fs::create_dir_all("bench_out");
    let _ = std::fs::write(path, csv);
    println!("[csv] {path}");
}

fn layer_summary(acts: &TensorF, label: &str, table: &mut Table) {
    let d = KvDims::of(acts);
    // First 32 channels across heads, matching the paper's "first 32
    // channels of the embedding" view: channel index = h * hd + ch.
    let want = 32.min(d.h * d.hd);
    for l in 0..d.l {
        let chans: Vec<Vec<f32>> = (0..want)
            .map(|i| gather_channel(acts, l, i / d.hd, i % d.hd))
            .collect();
        let m = corr_matrix(&chans);
        let s = mean_abs_offdiag(&m, want);
        let n = chans[0].len() as f64;
        eprintln!(
            "  {label} layer {l}: mean|r| {s:.3} (independence baseline ~{:.3})",
            1.0 / n.sqrt()
        );
        table.row(vec![
            label.to_string(),
            l.to_string(),
            format!("{s:.4}"),
            format!("{:.4}", 1.0 / n.sqrt()),
        ]);
        dump_heatmap(&m, want, &format!("bench_out/fig2_{label}_layer{l}.csv"));
    }
}

fn main() {
    let pipe = Pipeline::ensure("small").expect("pipeline");
    let mut table = Table::new(
        "Figure 2: channel correlation summary (first 32 channels per layer)",
        &["kind", "layer", "mean |r| offdiag", "independence baseline"],
    );
    layer_summary(&pipe.calib.k, "key", &mut table);
    layer_summary(&pipe.calib.v, "value", &mut table);
    table.emit("fig2_correlation");
    println!("Full 32x32 heat maps: bench_out/fig2_{{key,value}}_layer*.csv");
}

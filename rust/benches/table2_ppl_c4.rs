//! Table 2: perplexity on the C4-style corpus (distribution shift: codecs
//! stay calibrated on wiki2s-train, exactly as the paper calibrates on
//! WikiText-2 and evaluates on C4).
//!
//!     cargo bench --bench table2_ppl_c4

use cq::bench_support::run_ppl_table;
use cq::data::corpus::CorpusKind;

fn main() {
    run_ppl_table(
        CorpusKind::C4s,
        "table2_ppl_c4",
        "Table 2: perplexity on c4s (C4-style) by codec — calibrated on wiki2s",
    );
}

//! Table 1: perplexity on the WikiText-2-style corpus under every KV-cache
//! quantization method at 4 / 2 / 1 bits per FPN.
//!
//! Regenerates the paper's rows (INT, NF, KVQuant +/- 1% outliers, CQ)
//! through the shared eval harness; expected *shape* (DESIGN.md §4):
//! CQ-2c8b ~ FP16; INT2/NF2 collapse; CQ-4c8b <= KVQuant-2b-1% without the
//! sparse path; at 1 bit only CQ-8c8b and KVQuant-1b-1% stay usable, CQ
//! ahead.
//!
//!     cargo bench --bench table1_ppl_wiki  [-- --batches 6 --iters 40 --exact]

use cq::bench_support::run_ppl_table;
use cq::data::corpus::CorpusKind;

fn main() {
    run_ppl_table(
        CorpusKind::Wiki2s,
        "table1_ppl_wiki",
        "Table 1: perplexity on wiki2s (WikiText-2-style) by codec",
    );
}

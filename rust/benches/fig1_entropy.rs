//! Figure 1: growth of joint entropy vs sum of marginal entropies of
//! key/value channel groups (group size 1–4, 16 bins, Eq. 4) — the paper's
//! information-theoretic motivation.
//!
//! Expected shape: the marginal sum grows linearly in group size while the
//! joint entropy grows sub-linearly, and the gap widens with group size.
//!
//!     cargo bench --bench fig1_entropy

use cq::bench_support::Pipeline;
use cq::quant::entropy::{joint_entropy, sum_marginal_entropy};
use cq::quant::{gather_channel, KvDims, KvKind};
use cq::tensor::TensorF;
use cq::util::bench::Table;

/// Mean ± std of per-group entropies over all (layer, head, group) choices.
fn stats(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let m = xs.iter().sum::<f64>() / n;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
    (m, v.sqrt())
}

fn series(acts: &TensorF, label: &str, table: &mut Table) -> Vec<(f64, f64)> {
    let d = KvDims::of(acts);
    let bins = 16;
    let mut gaps = Vec::new();
    for group in 1..=4usize {
        let mut joints = Vec::new();
        let mut sums = Vec::new();
        for l in 0..d.l {
            for h in 0..d.h {
                for g0 in (0..d.hd - group + 1).step_by(group) {
                    let chans: Vec<Vec<f32>> =
                        (0..group).map(|c| gather_channel(acts, l, h, g0 + c)).collect();
                    let refs: Vec<&[f32]> = chans.iter().map(|c| c.as_slice()).collect();
                    joints.push(joint_entropy(&refs, bins));
                    sums.push(sum_marginal_entropy(&refs, bins));
                }
            }
        }
        let (jm, js) = stats(&joints);
        let (sm, ss) = stats(&sums);
        eprintln!(
            "  {label} group={group}: joint {jm:.2}±{js:.2}  sum {sm:.2}±{ss:.2}  gap {:.2}",
            sm - jm
        );
        table.row(vec![
            label.to_string(),
            group.to_string(),
            format!("{jm:.3}"),
            format!("{js:.3}"),
            format!("{sm:.3}"),
            format!("{ss:.3}"),
            format!("{:.3}", sm - jm),
        ]);
        gaps.push((jm, sm));
    }
    gaps
}

fn main() {
    let pipe = Pipeline::ensure("small").expect("pipeline");
    let mut table = Table::new(
        "Figure 1: joint vs sum-of-marginal entropy of KV channel groups (16 bins)",
        &["kind", "group size", "joint mean", "joint std", "marg-sum mean",
          "marg-sum std", "gap (bits)"],
    );
    let kseries = series(&pipe.calib.k, "key", &mut table);
    let vseries = series(&pipe.calib.v, "value", &mut table);
    table.emit("fig1_entropy");

    // Paper-shape check: sub-linear joint growth — the gap at group size 4
    // must exceed the gap at group size 2 for both keys and values.
    for (name, s) in [("key", &kseries), ("value", &vseries)] {
        let gap2 = s[1].1 - s[1].0;
        let gap4 = s[3].1 - s[3].0;
        println!(
            "{name}: gap@2 = {gap2:.2} bits, gap@4 = {gap4:.2} bits -> {}",
            if gap4 > gap2 { "SUB-LINEAR joint growth (matches paper Fig. 1)" } else { "UNEXPECTED" }
        );
    }
    let _ = KvKind::Key; // (axis doc anchor)
}

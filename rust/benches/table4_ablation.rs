//! Table 4 (ablation): at a fixed 2-bit/FPN budget, sweep the number of
//! coupled channels c ∈ {1, 2, 4} (CQ-1c2b / 2c4b / 4c8b) × {uniform,
//! Fisher-guided} centroids, on BOTH models (paper: Mistral-7b and
//! LLaMA-2-13b; here: `small` and `tiny`).
//!
//! Expected shape: perplexity improves monotonically with c under either
//! centroid scheme, and Fisher < uniform at every c (paper Table 4).
//!
//!     cargo bench --bench table4_ablation  [-- --batches 4]

use cq::bench_support::Pipeline;
use cq::data::corpus::CorpusKind;
use cq::eval::{perplexity, PplMode};
use cq::quant::cq::CqSpec;
use cq::util::bench::Table;
use cq::util::cli::Args;

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let n_batches = args.usize("batches", 4);
    let iters = args.usize("iters", 40);

    let mut table = Table::new(
        "Table 4: ablation — coupled channels × Fisher centroids @ 2 bits/FPN",
        &["model", "config", "coupled c", "fisher", "ppl", "k_err"],
    );
    for model in ["small", "tiny"] {
        let pipe = Pipeline::ensure(model).expect("pipeline");
        let batches = pipe.eval_set(CorpusKind::Wiki2s, n_batches);
        for fisher in [false, true] {
            for spec in [CqSpec::new(1, 2), CqSpec::new(2, 4), CqSpec::new(4, 8)] {
                let codec = pipe.cq_codec(spec, fisher, iters).expect("codec");
                let r = perplexity(
                    &pipe.engine, &pipe.model, &pipe.params,
                    &codec, &batches, PplMode::Fast,
                )
                .expect("ppl");
                eprintln!(
                    "  {model:<6} {:<6} fisher={fisher:<5} ppl {:>10.3}",
                    spec.tag(),
                    r.ppl()
                );
                table.row(vec![
                    model.to_string(),
                    format!("CQ-{}", spec.tag()),
                    spec.channels.to_string(),
                    if fisher { "yes".into() } else { "no".into() },
                    format!("{:.3}", r.ppl()),
                    format!("{:.1}", r.k_err),
                ]);
            }
        }
    }
    table.emit("table4_ablation");
}

//! Table 3: zero-shot accuracy on the three synthetic suites (WinoGrande /
//! PIQA / ARC analogues) under the paper's codec set at 4 / 2 / 1 bits.
//!
//! Expected shape: 4-bit rows ≈ FP16; KVQuant-2b degrades sharply while
//! KVQuant-2b-1% and CQ-4c8b hold; at 1 bit KVQuant-1b collapses to chance
//! and CQ-8c8b stays measurably above it; CQ-8c10b > CQ-8c8b.
//!
//!     cargo bench --bench table3_accuracy  [-- --items 120]

use cq::bench_support::Pipeline;
use cq::eval::tasks::{task_accuracy, TaskKind, TaskSet};
use cq::util::bench::Table;
use cq::util::cli::Args;

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let items = args.usize("items", 48);
    let iters = args.usize("iters", 40);

    let pipe = Pipeline::ensure("small").expect("pipeline");
    let rows = [
        "fp16",
        "kvquant-4b", "kvquant-4b-1%", "cq-2c8b",
        "kvquant-2b", "kvquant-2b-1%", "cq-4c8b",
        "kvquant-1b", "kvquant-1b-1%", "cq-8c8b", "cq-8c10b",
    ];
    let sets: Vec<TaskSet> = TaskKind::all()
        .into_iter()
        .map(|k| TaskSet::generate(k, items, 42))
        .collect();

    let mut table = Table::new(
        "Table 3: zero-shot accuracy by codec (small model)",
        &["codec", "bits/FPN", "agree%", "affinity%", "arith%"],
    );
    for name in rows {
        let codec = pipe.codec(name, true, iters).expect("codec");
        let mut accs = Vec::new();
        for set in &sets {
            let a = task_accuracy(&pipe.engine, &pipe.model, &pipe.params, codec.as_ref(), set)
                .expect("accuracy");
            accs.push(a);
        }
        eprintln!(
            "  {:<16} agree {:>5.1} affinity {:>5.1} arith {:>5.1}",
            codec.name(),
            accs[0] * 100.0,
            accs[1] * 100.0,
            accs[2] * 100.0
        );
        table.row(vec![
            codec.name(),
            format!("{:.2}", codec.bits_per_fpn()),
            format!("{:.1}", accs[0] * 100.0),
            format!("{:.1}", accs[1] * 100.0),
            format!("{:.1}", accs[2] * 100.0),
        ]);
    }
    println!("({} items/task, 2 options each; chance = 50%)", items);
    table.emit("table3_accuracy");
}

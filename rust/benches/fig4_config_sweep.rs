//! Figure 4: perplexity and key/value quantization errors across CQ
//! configurations at 1-bit and 2-bit budgets, uniform vs Fisher-guided
//! centroids.
//!
//! Expected shape (paper Fig. 4): at fixed bits/FPN, both ppl and quant
//! error fall as coupling grows; Fisher-guided centroids *raise* raw
//! quantization error slightly but *lower* perplexity (they spend precision
//! on salient activations).
//!
//!     cargo bench --bench fig4_config_sweep  [-- --batches 4]

use cq::bench_support::Pipeline;
use cq::data::corpus::CorpusKind;
use cq::eval::{perplexity, PplMode};
use cq::quant::cq::CqSpec;
use cq::util::bench::Table;
use cq::util::cli::Args;

fn main() {
    let args = Args::parse(
        &std::env::args().skip(1).filter(|a| a != "--bench").collect::<Vec<_>>(),
    )
    .unwrap();
    let n_batches = args.usize("batches", 3);
    let iters = args.usize("iters", 40);

    let pipe = Pipeline::ensure("small").expect("pipeline");
    let batches = pipe.eval_set(CorpusKind::Wiki2s, n_batches);

    // 1-bit series: 1c1b, 2c2b, 4c4b, 8c8b.  2-bit series: 1c2b, 2c4b, 4c8b.
    let one_bit = [CqSpec::new(1, 1), CqSpec::new(2, 2), CqSpec::new(4, 4), CqSpec::new(8, 8)];
    let two_bit = [CqSpec::new(1, 2), CqSpec::new(2, 4), CqSpec::new(4, 8)];

    let mut table = Table::new(
        "Figure 4: ppl + quant error vs CQ config (uniform vs Fisher)",
        &["bits/FPN", "config", "centroids", "ppl", "k_err", "v_err"],
    );
    for (budget, specs) in [("1.00", &one_bit[..]), ("2.00", &two_bit[..])] {
        for &spec in specs {
            for fisher in [false, true] {
                let codec = pipe.cq_codec(spec, fisher, iters).expect("codec");
                let r = perplexity(
                    &pipe.engine, &pipe.model, &pipe.params,
                    &codec, &batches, PplMode::Fast,
                )
                .expect("ppl");
                let cname = if fisher { "fisher" } else { "uniform" };
                eprintln!(
                    "  {budget}b {:<5} {cname:<8} ppl {:>10.3} kerr {:>9.1}",
                    spec.tag(),
                    r.ppl(),
                    r.k_err
                );
                table.row(vec![
                    budget.to_string(),
                    format!("CQ-{}", spec.tag()),
                    cname.to_string(),
                    format!("{:.3}", r.ppl()),
                    format!("{:.1}", r.k_err),
                    format!("{:.1}", r.v_err),
                ]);
            }
        }
    }
    table.emit("fig4_config_sweep");
}

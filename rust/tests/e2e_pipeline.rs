//! End-to-end integration over the tiny model: train → calibrate → learn
//! codebooks → quantized perplexity → zero-shot scoring, all through the
//! real artifacts.  This is the cheap CI-shaped version of
//! examples/e2e_reproduce.rs (fewer steps, looser thresholds).

use cq::calib::calibrate;
use cq::data::corpus::{CorpusKind, CorpusSpec, Split};
use cq::data::{eval_batches, Dataset};
use cq::eval::tasks::{task_accuracy, TaskKind, TaskSet};
use cq::eval::{perplexity, PplMode};
use cq::quant::factory::{build_codec, FactoryCfg};
use cq::runtime::Engine;
use cq::train::{train, TrainCfg};

/// One shared engine-heavy test: splitting these into separate #[test]s
/// would retrain the model once per test binary fork.
#[test]
fn pipeline_train_calibrate_quantize_eval() {
    if !cq::runtime_available() {
        eprintln!("skipping: PJRT runtime / artifacts unavailable (run `make artifacts`)");
        return;
    }
    let engine = Engine::load_default().expect("make artifacts first");
    let model = "tiny";
    let mm = engine.manifest.model(model).unwrap().clone();

    // -- train briefly (enough to get under ~2.2 nats/byte on this corpus) --
    let ds = Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Train), 500_000);
    let cfg = TrainCfg { steps: 120, log_every: 60, ..Default::default() };
    let r = train(&engine, model, engine.init_params(model).unwrap(), &ds, &cfg).unwrap();
    assert!(
        r.final_loss < 2.2,
        "training should make clear progress, got {}",
        r.final_loss
    );

    // -- calibrate --------------------------------------------------------
    let calib = calibrate(&engine, model, &r.params, &ds, 8).unwrap();
    assert_eq!(calib.k.shape[1], 8);
    let gnorm: f64 = calib.gk.data.iter().map(|x| (*x as f64).abs()).sum();
    assert!(gnorm > 0.0, "Fisher gradients must be non-trivial");

    // -- eval under codecs -------------------------------------------------
    let batches = eval_batches(
        &Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Test), 150_000),
        4,
        mm.eval_ctx,
        2,
    );
    let fcfg = FactoryCfg { fisher: true, max_iters: 20, seed: 0 };
    let ppl_of = |name: &str| {
        let codec = build_codec(name, Some(&calib), fcfg).unwrap();
        perplexity(&engine, model, &r.params, codec.as_ref(), &batches, PplMode::Fast)
            .unwrap()
            .ppl()
    };
    let fp = ppl_of("fp16");
    let cq8 = ppl_of("cq-8c8b");
    let cq4 = ppl_of("cq-4c8b");
    let int2 = ppl_of("int2");
    println!("fp {fp:.3}  cq-4c8b {cq4:.3}  cq-8c8b {cq8:.3}  int2 {int2:.3}");
    // Paper-shape invariants (loose, tiny model, short training):
    assert!(fp < cq4 * 1.01, "quantization can't beat fp meaningfully");
    assert!(cq4 < int2, "CQ @2bit must beat INT2");
    assert!(cq8 < int2, "CQ @1bit must beat INT2 @2bit");
    assert!(cq8.is_finite() && cq8 < 256.0, "1-bit cache stays usable");

    // -- exact (progressive) mode agrees with fast mode on FP --------------
    let fp_exact = {
        let codec = build_codec("fp16", None, fcfg).unwrap();
        perplexity(&engine, model, &r.params, codec.as_ref(), &batches, PplMode::Exact)
            .unwrap()
            .ppl()
    };
    assert!(
        (fp_exact - fp).abs() / fp < 1e-3,
        "identity codec: exact {fp_exact} vs fast {fp}"
    );

    // -- zero-shot scoring runs and beats chance on fp16 --------------------
    let codec = build_codec("fp16", None, fcfg).unwrap();
    let set = TaskSet::generate(TaskKind::Agree, 40, 1);
    let acc = task_accuracy(&engine, model, &r.params, codec.as_ref(), &set).unwrap();
    println!("agree accuracy fp16: {acc}");
    assert!(acc >= 0.5, "trained model must be at least at chance, got {acc}");
}

//! Frontend e2e: the epoll reactor, broadcast fan-out, and connection-path
//! behavior — all over real TCP against a sim-backend pool, **no XLA
//! runtime required**.
//!
//! Covers the v2.4 wire surface end to end: typed `line_too_long` and
//! `max_conns` errors, per-scraper metrics rate baselines, `watch` fan-out
//! sharing one upstream generation, the slow-reader buffer policy firing
//! without stalling decode lanes, worker death under a pile of idle
//! connections, and the flat-thread-count contract (threads are
//! O(reactor + workers), not O(connections)).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cq::coordinator::{FaultPlan, Request, ServeConfig, ServePool, SimSpec};
use cq::metrics::export::MetricsSnapshot;
use cq::server::{
    client_request_line, client_stream, serve_tcp, serve_tcp_cfg, BufferPolicy, OverflowPolicy,
    ServerConfig, StopSignal,
};
use cq::util::json::Json;

fn sim_cfg(plan: &Arc<FaultPlan>) -> ServeConfig {
    ServeConfig {
        model: "sim".into(),
        cq: None,
        batch: 4,
        cache_budget: None,
        codebook_path: None,
        params_path: "/nonexistent/sim-has-no-params.bin".into(),
        kernel: ServeConfig::default_kernel(),
        block_tokens: 4,
        prefix_sharing: true,
        sim: Some(SimSpec::tiny()),
        faults: Some(plan.clone()),
        worker_index: 0,
        session_cap: ServeConfig::default_session_cap(),
        session_ttl: None,
        prefill_chunk: ServeConfig::default_prefill_chunk(),
        ttft_slo_chunks: None,
        trace_ring: ServeConfig::default_trace_ring(),
        encode_threads: ServeConfig::default_encode_threads(),
        codec: None,
        policies: Vec::new(),
    }
}

/// One admin round-trip on a fresh connection; panics on a non-`ok` reply.
fn admin(addr: &str, line: &str) -> Json {
    let resp = client_request_line(addr, line).expect("admin roundtrip");
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "{}",
        resp.dump()
    );
    resp
}

/// Scrape `{"op":"metrics"}` and parse the frozen snapshot back.
fn scrape(addr: &str) -> MetricsSnapshot {
    let m = admin(addr, r#"{"op": "metrics"}"#);
    MetricsSnapshot::from_json(m.get("snapshot").expect("snapshot"))
        .expect("snapshot parses back into a MetricsSnapshot")
}

/// A raw NDJSON connection: write half + buffered read half on one socket.
struct Wire {
    tx: TcpStream,
    rx: BufReader<TcpStream>,
}

impl Wire {
    fn connect(addr: &str) -> Wire {
        let tx = TcpStream::connect(addr).expect("connect");
        tx.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
        let rx = BufReader::new(tx.try_clone().expect("clone"));
        Wire { tx, rx }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.tx, "{line}").expect("send");
    }

    /// Read one NDJSON frame; panics on EOF or a read timeout.
    fn frame(&mut self) -> Json {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.rx.read_line(&mut line).expect("read frame");
            assert!(n > 0, "peer closed before a frame arrived");
            if !line.trim().is_empty() {
                return Json::parse(line.trim()).expect("frame parses");
            }
        }
    }

    /// Read frames until a terminal (`done`/`failed`) one; returns all of
    /// them, terminal last.
    fn drain_stream(&mut self) -> Vec<Json> {
        let mut frames = Vec::new();
        loop {
            let f = self.frame();
            let ev = f.str_or("event", "");
            frames.push(f);
            if ev == "done" || ev == "failed" {
                return frames;
            }
        }
    }
}

#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("/proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line")
}

/// Satellite 1 — a request line over `--max-line-bytes` gets one typed
/// `line_too_long` error, and the connection resyncs at the next newline
/// instead of dying (or worse, parsing the tail as a fresh request).
#[test]
fn oversized_request_line_gets_typed_error_and_connection_survives() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 1);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17941";
    let srv = ServerConfig { max_line_bytes: 256, ..ServerConfig::default() };

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp_cfg(p, addr, stop2, srv).unwrap());
        std::thread::sleep(Duration::from_millis(300)); // wait for bind

        let mut w = Wire::connect(addr);
        w.send(&"x".repeat(1000));
        let err = w.frame();
        assert_eq!(err.str_or("code", ""), "line_too_long", "{}", err.dump());
        assert!(err.str_or("error", "").contains("256"), "{}", err.dump());

        // The oversized line was discarded through its newline; the same
        // connection keeps answering.
        w.send(r#"{"op": "health"}"#);
        let h = w.frame();
        assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true), "{}", h.dump());

        // And a well-formed inference request still flows on this conn.
        w.send(r#"{"prompt": "still alive", "max_tokens": 3, "stream": true}"#);
        let frames = w.drain_stream();
        assert_eq!(frames.last().unwrap().str_or("event", ""), "done");

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

/// Satellite 3 — two interleaved scrapers with distinct `"scraper"` tags
/// keep independent rate baselines: each scraper's first scrape is
/// baseline-less (null rates) even when another scraper already scraped,
/// and each derives rates over its *own* window afterwards.
#[test]
fn interleaved_scrapers_keep_independent_rate_baselines() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 2);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17942";

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp(p, addr, stop2).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        for id in 1..=4u64 {
            pool.submit(Request::greedy(id, "scrape load", 4)).unwrap();
        }
        let a1 = admin(addr, r#"{"op": "metrics", "scraper": "a"}"#);
        assert!(
            matches!(a1.get("rates"), None | Some(Json::Null)),
            "a's first scrape has no baseline: {}",
            a1.dump()
        );

        std::thread::sleep(Duration::from_millis(40));
        for id in 5..=6u64 {
            pool.submit(Request::greedy(id, "scrape load", 4)).unwrap();
        }
        // b's FIRST scrape lands after a's: with a single shared baseline
        // slot it would inherit a's snapshot and report rates here.
        let b1 = admin(addr, r#"{"op": "metrics", "scraper": "b"}"#);
        assert!(
            matches!(b1.get("rates"), None | Some(Json::Null)),
            "b's first scrape has no baseline of its own: {}",
            b1.dump()
        );

        std::thread::sleep(Duration::from_millis(40));
        for id in 7..=8u64 {
            pool.submit(Request::greedy(id, "scrape load", 4)).unwrap();
        }
        let a2 = admin(addr, r#"{"op": "metrics", "scraper": "a"}"#);
        let ra = a2.get("rates").expect("a's second scrape derives rates");
        assert!(ra.num_or("window_s", -1.0) > 0.0, "{}", a2.dump());
        assert!(ra.num_or("tok_per_s", -1.0) > 0.0, "{}", a2.dump());

        std::thread::sleep(Duration::from_millis(40));
        for id in 9..=10u64 {
            pool.submit(Request::greedy(id, "scrape load", 4)).unwrap();
        }
        let b2 = admin(addr, r#"{"op": "metrics", "scraper": "b"}"#);
        let rb = b2.get("rates").expect("b's second scrape derives rates");
        assert!(rb.num_or("window_s", -1.0) > 0.0, "{}", b2.dump());
        assert!(rb.num_or("tok_per_s", -1.0) > 0.0, "{}", b2.dump());

        // An untagged scraper is a third independent slot, not b's.
        let u1 = admin(addr, r#"{"op": "metrics"}"#);
        assert!(
            matches!(u1.get("rates"), None | Some(Json::Null)),
            "untagged scraper starts from its own baseline: {}",
            u1.dump()
        );

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

/// Tentpole — broadcast fan-out: a `watch` subscriber attaches to a live
/// generation and both connections receive the identical frame stream from
/// one upstream, terminal included; the fan-out gauge sees both.
#[test]
fn watchers_share_one_generation_and_all_get_the_terminal() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 1);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17943";

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp(p, addr, stop2).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        // Freeze the only worker so the generation is provably still live
        // while the watcher attaches.
        plan.hold_worker(0);
        plan.await_paused(0);

        let mut a = Wire::connect(addr);
        a.send(r#"{"prompt": "watch me", "max_tokens": 4, "stream": true}"#);

        // Request ids are assigned per server starting at 1, so the first
        // request is id 1.  Retry until the reactor has processed A's line.
        let mut b = Wire::connect(addr);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            b.send(r#"{"op": "watch", "id": 1}"#);
            let r = b.frame();
            if r.get("ok").and_then(Json::as_bool) == Some(true) {
                break;
            }
            assert!(Instant::now() < deadline, "watch never attached: {}", r.dump());
            std::thread::sleep(Duration::from_millis(20));
        }

        // Both subscribers are on the fan-out gauge.
        assert_eq!(scrape(addr).pool_scalar("fanout_subscribers"), 2);

        plan.release_worker(0);
        let a_frames = a.drain_stream();
        let b_frames = b.drain_stream();
        for frames in [&a_frames, &b_frames] {
            let done = frames.last().unwrap();
            assert_eq!(done.str_or("event", ""), "done", "{}", done.dump());
            assert_eq!(done.num_or("id", -1.0) as u64, 1);
            let toks = frames.iter().filter(|f| f.str_or("event", "") == "token").count();
            assert_eq!(toks, 4, "every token frame reached this subscriber");
        }
        assert_eq!(
            a_frames.len(),
            b_frames.len(),
            "the watcher saw the identical stream, not a resynthesized one"
        );

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

/// Satellite 4 (chaos half) — kill the only worker mid-decode while 100
/// idle connections sit registered: the reactor survives, the in-flight
/// stream gets its terminal retryable `failed` frame, admin ops still
/// answer, and the idle pile stays connected.  Also pins the tentpole's
/// thread contract: 100 extra connections add ~zero threads.
#[test]
fn reactor_survives_worker_death_under_idle_connections() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 1);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17944";

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp(p, addr, stop2).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        #[cfg(target_os = "linux")]
        let threads_before = thread_count();

        // 100 idle connections: accepted, registered, never written to.
        let idle: Vec<TcpStream> =
            (0..100).map(|_| TcpStream::connect(addr).expect("idle connect")).collect();
        let deadline = Instant::now() + Duration::from_secs(5);
        while scrape(addr).pool_scalar("conns_open") < 101 {
            assert!(Instant::now() < deadline, "reactor never admitted the idle pile");
            std::thread::sleep(Duration::from_millis(20));
        }

        // Thread-per-connection would add >= 100 here.  Allow generous
        // slack for concurrent tests in this process spawning pools.
        #[cfg(target_os = "linux")]
        {
            let grown = thread_count().saturating_sub(threads_before);
            assert!(grown < 32, "thread count grew by {grown} for 100 idle connections");
        }

        // Kill the only worker just before its 4th decode step, mid-stream.
        plan.kill_worker_at_step(0, 3);
        let mut a = Wire::connect(addr);
        a.send(r#"{"prompt": "chaos stream", "max_tokens": 64, "stream": true}"#);
        let frames = a.drain_stream();
        let term = frames.last().unwrap();
        assert_eq!(term.str_or("event", ""), "failed", "{}", term.dump());
        assert!(term.str_or("error", "").contains("serve worker died"), "{}", term.dump());
        assert_eq!(term.get("retryable").and_then(Json::as_bool), Some(true));
        let toks = frames.iter().filter(|f| f.str_or("event", "") == "token").count();
        assert_eq!(toks, 4, "prefill token + exactly 3 decode steps before the kill");

        // The reactor outlives the worker: admin ops answer, idle pile is
        // still registered.
        let h = admin(addr, r#"{"op": "health"}"#);
        assert_eq!(h.num_or("live_workers", -1.0) as i64, 0, "{}", h.dump());
        assert!(scrape(addr).pool_scalar("conns_open") >= 101);

        drop(idle);
        stop.raise();
        server.join().unwrap();
    });
    assert!(pool.shutdown().is_err(), "panicked worker surfaces at shutdown");
}

/// Tentpole — slow-reader handling: a watcher that never reads trips the
/// `disconnect` buffer policy (bounded queue, typed goodbye, close) while a
/// concurrent fast stream completes untouched.  No worker or reactor
/// thread ever blocks on the dead socket.
#[test]
fn slow_reader_hits_disconnect_policy_without_stalling_decode() {
    let plan = FaultPlan::new();
    let mut cfg = sim_cfg(&plan);
    // Big lanes: the stream must outrun kernel socket buffering (hundreds
    // of KB) so the userspace outbound queue genuinely fills.
    cfg.sim = Some(SimSpec { tmax: 60_000, max_prompt: 48, ..SimSpec::tiny() });
    let pool = ServePool::start(cfg, 1);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17945";
    let srv = ServerConfig {
        buffer: BufferPolicy { max_bytes: 8 * 1024, on_full: OverflowPolicy::Disconnect },
        ..ServerConfig::default()
    };

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp_cfg(p, addr, stop2, srv).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        // The slow reader: starts a huge stream, then never reads.
        let mut slow = TcpStream::connect(addr).expect("connect");
        let line = r#"{"prompt": "slow", "max_tokens": 50000, "stream": true}"#;
        writeln!(slow, "{line}").unwrap();

        // A concurrent fast client completes while the slow stream jams:
        // the buffer policy, not a blocked thread, absorbs the lag.
        std::thread::sleep(Duration::from_millis(100));
        let done = client_stream(
            addr,
            r#"{"prompt": "fast", "max_tokens": 6, "stream": true}"#,
            |_| {},
        )
        .expect("fast stream");
        assert_eq!(done.str_or("event", ""), "done", "{}", done.dump());

        // The reactor kills the slow conn once its queue tops max_bytes.
        let deadline = Instant::now() + Duration::from_secs(20);
        while pool.metrics.conns_dropped_slow.get() == 0 {
            assert!(Instant::now() < deadline, "slow reader was never disconnected");
            std::thread::sleep(Duration::from_millis(50));
        }

        // The server-side close reaches the client once it finally reads:
        // buffered frames, then EOF (or a reset, if data was in flight).
        slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut sink = [0u8; 64 * 1024];
        loop {
            match slow.read(&mut sink) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(_) => break,
            }
        }

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

/// Satellite (admission control) — the `--max-conns` cap rejects the
/// excess connection with a typed `max_conns` error and closes it; closing
/// an admitted connection frees its slot.
#[test]
fn max_conns_rejection_is_typed_and_slots_free_on_close() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 1);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17946";
    let srv = ServerConfig { max_conns: 2, ..ServerConfig::default() };

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp_cfg(p, addr, stop2, srv).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        let c1 = Wire::connect(addr);
        let mut c2 = Wire::connect(addr);
        std::thread::sleep(Duration::from_millis(100)); // both admitted

        let mut c3 = Wire::connect(addr);
        let rej = c3.frame();
        assert_eq!(rej.str_or("code", ""), "max_conns", "{}", rej.dump());
        let mut rest = String::new();
        match c3.rx.read_to_string(&mut rest) {
            Ok(0) => {}
            Ok(n) => panic!("unexpected {n} bytes after rejection: {rest:?}"),
            Err(_) => {} // a reset is also a close
        }

        // Freeing a slot re-opens the door.
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Ok(h) = client_request_line(addr, r#"{"op": "health"}"#) {
                if h.get("ok").and_then(Json::as_bool) == Some(true) {
                    break;
                }
            }
            assert!(Instant::now() < deadline, "slot never freed after c1 closed");
            std::thread::sleep(Duration::from_millis(20));
        }

        // c2 was admitted normally all along.
        c2.send(r#"{"op": "health"}"#);
        assert_eq!(c2.frame().get("ok").and_then(Json::as_bool), Some(true));

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

/// Tentpole — one shared event channel multiplexes concurrent streams:
/// every frame routes to the connection that owns its id, nothing bleeds
/// across, both terminals arrive.
#[test]
fn one_event_channel_multiplexes_concurrent_streams_by_id() {
    let plan = FaultPlan::new();
    let pool = ServePool::start(sim_cfg(&plan), 2);
    let stop = StopSignal::new();
    let stop2 = stop.clone();
    let addr = "127.0.0.1:17947";

    std::thread::scope(|scope| {
        let p = &pool;
        let server = scope.spawn(move || serve_tcp(p, addr, stop2).unwrap());
        std::thread::sleep(Duration::from_millis(300));

        let run = |max_tokens: usize| {
            let line =
                format!(r#"{{"prompt": "mux", "max_tokens": {max_tokens}, "stream": true}}"#);
            move || {
                let mut frames = Vec::new();
                let done = client_stream(addr, &line, |f| frames.push(f.clone()))
                    .expect("multiplexed stream");
                assert_eq!(done.str_or("event", ""), "done", "{}", done.dump());
                frames
            }
        };
        let ta = scope.spawn(run(6));
        let tb = scope.spawn(run(3));
        let fa = ta.join().unwrap();
        let fb = tb.join().unwrap();

        let id_of = |frames: &[Json]| {
            let ids: Vec<u64> = frames.iter().map(|f| f.num_or("id", -1.0) as u64).collect();
            assert!(ids.windows(2).all(|w| w[0] == w[1]), "mixed ids on one conn: {ids:?}");
            ids[0]
        };
        assert_ne!(id_of(&fa), id_of(&fb), "each request got its own id");
        let toks =
            |frames: &[Json]| frames.iter().filter(|f| f.str_or("event", "") == "token").count();
        assert_eq!(toks(&fa), 6);
        assert_eq!(toks(&fb), 3);

        stop.raise();
        server.join().unwrap();
    });
    pool.shutdown().unwrap();
}

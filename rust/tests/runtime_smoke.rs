//! Integration: the PJRT runtime against real AOT artifacts.
//!
//! Requires `make artifacts`.  Exercises artifact loading, shape validation,
//! and the numerical contracts between entry points (eval_kv identity,
//! train_step progress, prefill/decode agreement is covered in e2e_pipeline).

use cq::data::corpus::{CorpusKind, CorpusSpec, Split};
use cq::data::{eval_batches, Dataset};
use cq::eval::{perplexity, PplMode};
use cq::quant::Fp16;
use cq::runtime::{Engine, Value};
use cq::tensor::{TensorF, TensorI};

fn engine() -> Engine {
    Engine::load_default().expect("artifacts missing — run `make artifacts`")
}

/// Skip (returning false) when the PJRT runtime or artifacts are missing.
fn ready() -> bool {
    if !cq::runtime_available() {
        eprintln!("skipping: PJRT runtime / artifacts unavailable (run `make artifacts`)");
        return false;
    }
    true
}

#[test]
fn manifest_lists_expected_artifacts() {
    if !ready() {
        return;
    }
    let e = engine();
    for name in [
        "small.train_step",
        "small.eval_kv",
        "small.calib_grads",
        "small.prefill",
        "small.decode_fp_b1",
        "small.decode_cq_8c8b_b8",
        "tiny.train_step",
        "tiny.eval_kv",
    ] {
        assert!(e.manifest.artifacts.contains_key(name), "{name} missing");
    }
}

#[test]
fn input_validation_rejects_bad_shapes() {
    if !ready() {
        return;
    }
    let e = engine();
    let exe = e.executable("tiny.eval_kv").unwrap();
    let err = exe.run(&[Value::scalar_f(1.0)]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
}

#[test]
fn tiny_eval_kv_runs_and_is_finite() {
    if !ready() {
        return;
    }
    let e = engine();
    let mm = e.manifest.model("tiny").unwrap().clone();
    let params = e.init_params("tiny").unwrap();
    let spec = e.manifest.artifact("tiny.eval_kv").unwrap().clone();
    let (b, t) = (spec.inputs[1].shape[0], spec.inputs[1].shape[1]);
    let kv = spec.inputs[2].shape.clone();
    let tokens = TensorI::from_vec(
        &[b, t],
        (0..b * t).map(|i| (i % 251) as i32).collect(),
    )
    .unwrap();
    let out = e
        .run(
            "tiny.eval_kv",
            &[
                Value::F(params),
                Value::I(tokens),
                Value::F(TensorF::zeros(&kv)),
                Value::F(TensorF::zeros(&kv)),
                Value::F(TensorF::zeros(&[mm.n_layers])),
            ],
        )
        .unwrap();
    let nll = out[0].as_f().unwrap();
    assert_eq!(nll.shape, vec![b, t - 1]);
    assert!(nll.data.iter().all(|x| x.is_finite() && *x > 0.0));
    // Random-init model over 256-way vocab: mean nll near ln(256).
    let mean = nll.mean();
    assert!(
        (mean - (256f64).ln()).abs() < 1.5,
        "random-init nll {mean} should be near ln(256)"
    );
}

#[test]
fn eval_kv_override_identity_through_runtime() {
    // Feeding extracted K/V back with use_q=1 must reproduce the clean nll —
    // the invariant the whole quantized-eval harness rests on, checked here
    // end-to-end through HLO text + PJRT (not just in the python tests).
    if !ready() {
        return;
    }
    let e = engine();
    let mm = e.manifest.model("tiny").unwrap().clone();
    let params = e.init_params("tiny").unwrap();
    let spec = e.manifest.artifact("tiny.eval_kv").unwrap().clone();
    let (b, t) = (spec.inputs[1].shape[0], spec.inputs[1].shape[1]);
    let kv = spec.inputs[2].shape.clone();
    let tokens =
        TensorI::from_vec(&[b, t], (0..b * t).map(|i| (i * 7 % 256) as i32).collect()).unwrap();
    let zeros = TensorF::zeros(&kv);
    let run = |khat: &TensorF, vhat: &TensorF, u: f32| {
        e.run(
            "tiny.eval_kv",
            &[
                Value::F(params.clone()),
                Value::I(tokens.clone()),
                Value::F(khat.clone()),
                Value::F(vhat.clone()),
                Value::F(TensorF::from_vec(&[mm.n_layers], vec![u; mm.n_layers]).unwrap()),
            ],
        )
        .unwrap()
    };
    let out0 = run(&zeros, &zeros, 0.0);
    let (nll0, k, v) = (
        out0[0].as_f().unwrap().clone(),
        out0[1].as_f().unwrap().clone(),
        out0[2].as_f().unwrap().clone(),
    );
    let out1 = run(&k, &v, 1.0);
    let nll1 = out1[0].as_f().unwrap();
    for (a, b) in nll0.data.iter().zip(&nll1.data) {
        assert!((a - b).abs() < 2e-4, "{a} vs {b}");
    }
}

#[test]
fn train_step_reduces_loss_through_runtime() {
    if !ready() {
        return;
    }
    let e = engine();
    let params0 = e.init_params("tiny").unwrap();
    let spec = e.manifest.artifact("tiny.train_step").unwrap().clone();
    let (b, t) = (spec.inputs[5].shape[0], spec.inputs[5].shape[1]);
    let ds = Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Train), 60_000);
    let mut rng = cq::util::rng::Pcg64::seed(0);
    let tokens = cq::data::train_batch(&ds, b, t, &mut rng);
    let n = params0.numel();
    let mut params = params0;
    let mut m = TensorF::zeros(&[n]);
    let mut v = TensorF::zeros(&[n]);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=12 {
        let out = e
            .run(
                "tiny.train_step",
                &[
                    Value::F(params),
                    Value::F(m),
                    Value::F(v),
                    Value::scalar_f(step as f32),
                    Value::scalar_f(5e-3),
                    Value::I(tokens.clone()),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        params = it.next().unwrap().into_f().unwrap();
        m = it.next().unwrap().into_f().unwrap();
        v = it.next().unwrap().into_f().unwrap();
        let loss = it.next().unwrap().into_f().unwrap().data[0];
        if step == 1 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.85,
        "overfitting one batch must reduce loss: {first} -> {last}"
    );
}

#[test]
fn fp_perplexity_of_random_init_is_near_vocab() {
    if !ready() {
        return;
    }
    let e = engine();
    let params = e.init_params("tiny").unwrap();
    let mm = e.manifest.model("tiny").unwrap();
    let ds = Dataset::from_corpus(CorpusSpec::new(CorpusKind::Wiki2s, Split::Test), 40_000);
    let batches = eval_batches(&ds, 4, mm.eval_ctx, 1);
    let r = perplexity(&e, "tiny", &params, &Fp16, &batches, PplMode::Fast).unwrap();
    assert!(r.ppl() > 100.0 && r.ppl() < 600.0, "ppl={}", r.ppl());
}
